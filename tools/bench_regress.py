#!/usr/bin/env python3
"""Bench-trajectory regression gate over results/history.jsonl.

Compares the **latest** history record of every bench against the
**median of its trailing window** (up to the 10 prior records) and fails
— exit 1, for CI — when either:

* wall regression: ``us_per_call`` grew by more than ``--wall-limit``
  (default 15%) over the trailing median; or
* ratio regression: any deterministic higher-is-better derived value
  (``ratio``/``gain``/``speedup``-family keys, see ``RATIO_KEYS``)
  dropped below the trailing median by more than ``--ratio-limit``
  (default 1% — float/derived-metric jitter allowance, not a budget;
  compression ratios are deterministic, so any real regression clears
  it).

Benches with fewer than 2 records pass vacuously (a fresh trajectory
cannot regress), as does a missing history file — the gate tightens as
the trajectory accumulates. Quick (--quick) and full runs are compared
only against records of the same mode: their workloads differ, so their
timings are not one trajectory.

Usage::

    python tools/bench_regress.py [--history results/history.jsonl]
                                  [--wall-limit 0.15] [--ratio-limit 0.01]
"""
from __future__ import annotations

import argparse
import pathlib
import statistics
import sys

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402
from repro.obs.bench_history import BenchHistory  # noqa: E402

DEFAULT_HISTORY = pathlib.Path(__file__).resolve().parents[1] / \
    "results" / "history.jsonl"
WALL_LIMIT = 0.15
RATIO_LIMIT = 0.01
TRAILING = 10

#: derived-value key fragments treated as higher-is-better quality
#: metrics (compression ratio, routed gain, prefill savings). Timing
#: noise lives in us_per_call and the *speedup* keys — speedups are
#: wall-derived, so they ride the wall rule's 15%, not the ratio rule.
RATIO_KEYS = ("ratio", "gain", "bpt_improvement", "savings")


def is_ratio_key(key: str) -> bool:
    k = key.lower()
    return any(frag in k for frag in RATIO_KEYS)


def check_bench(bench: str, latest: dict, trailing: list,
                wall_limit: float, ratio_limit: float) -> list:
    """Regression messages for one bench ([] = pass)."""
    problems = []
    same_mode = [r for r in trailing if r["quick"] == latest["quick"]]
    if not same_mode:
        return problems
    med_wall = statistics.median(r["us_per_call"] for r in same_mode)
    wall = latest["us_per_call"]
    if med_wall > 0 and wall > med_wall * (1.0 + wall_limit):
        problems.append(
            f"{bench}: wall {wall:.1f}us/call vs trailing median "
            f"{med_wall:.1f}us (+{(wall / med_wall - 1) * 100:.1f}% > "
            f"{wall_limit * 100:.0f}%)")
    for key, val in latest.get("values", {}).items():
        if not is_ratio_key(key):
            continue
        prior = [r["values"][key] for r in same_mode
                 if key in r.get("values", {})]
        if not prior:
            continue
        med = statistics.median(prior)
        if med > 0 and val < med * (1.0 - ratio_limit):
            problems.append(
                f"{bench}: {key} {val:.4f} vs trailing median {med:.4f} "
                f"({(val / med - 1) * 100:+.2f}% < -{ratio_limit * 100:.0f}%)")
    return problems


def run_gate(history_path, wall_limit: float = WALL_LIMIT,
             ratio_limit: float = RATIO_LIMIT,
             trailing_n: int = TRAILING, log=console) -> list:
    """All regression messages across the trajectory ([] = gate passes)."""
    hist = BenchHistory(history_path)
    problems = []
    benches = hist.benches()
    if not benches:
        log(f"bench_regress: no history at {history_path} — pass (empty "
            f"trajectory)")
        return problems
    for bench in benches:
        latest = hist.latest(bench)
        trailing = hist.trailing(bench, trailing_n)
        msgs = check_bench(bench, latest, trailing, wall_limit, ratio_limit)
        n = len(hist.load(bench))
        verdict = "REGRESSED" if msgs else "ok"
        log(f"bench_regress: {bench}: {n} record(s), latest "
            f"{latest['us_per_call']:.1f}us/call [{latest['commit'] or '?'}]"
            f" — {verdict}")
        problems.extend(msgs)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default=str(DEFAULT_HISTORY))
    ap.add_argument("--wall-limit", type=float, default=WALL_LIMIT,
                    help="max allowed us_per_call growth vs trailing "
                         "median (fraction, default 0.15)")
    ap.add_argument("--ratio-limit", type=float, default=RATIO_LIMIT,
                    help="max allowed drop in ratio-family derived values "
                         "(fraction, default 0.01)")
    ap.add_argument("--trailing", type=int, default=TRAILING,
                    help="trailing-window size medianed as the baseline")
    args = ap.parse_args(argv)
    problems = run_gate(args.history, args.wall_limit, args.ratio_limit,
                        args.trailing)
    for p in problems:
        console(f"FAIL: {p}", err=True)
    if problems:
        console(f"bench_regress: {len(problems)} regression(s)", err=True)
        return 1
    console("bench_regress: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
