#!/usr/bin/env python3
"""Repo lint: forbid bare ``print(`` calls in src/repro, benchmarks,
and tools.

Operational output must go through ``repro.obs`` (structured events with
a level, a logger name, and an error counter — see DESIGN.md §10) or the
``repro.obs.console`` funnel for deliberate human-facing table/report
output (benchmarks, CLI gates — see DESIGN.md §13), not ad-hoc prints
that vanish under services and can't be filtered.  The one exemption is
the CLI front end (``src/repro/cli.py``): its stdout *is* its user
interface.

AST-based, not grep-based, so ``"print("`` inside a string literal (e.g.
data/synthetic.py's corpus text) never false-positives.  Only direct
calls to the builtin name ``print`` are flagged — a method named
``.print`` on some object is not the builtin.

Usage::

    python tools/lint_no_print.py [ROOT ...]  # default: src/repro,
                                              # benchmarks, tools

Exits 0 when clean, 1 with a ``file:line: message`` list otherwise.
Wired into CI (.github/workflows/ci.yml) next to the test jobs.
"""
from __future__ import annotations

import ast
import pathlib
import sys

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

_REPO = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_ROOTS = (_REPO / "src" / "repro", _REPO / "benchmarks",
                 _REPO / "tools")

ALLOWED = {"cli.py"}    # paths relative to a ROOT allowed to print


def find_prints(tree: ast.AST) -> list[int]:
    """Line numbers of direct builtin ``print(...)`` calls."""
    return [node.lineno for node in ast.walk(tree)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "print"]


def lint(root: pathlib.Path) -> list[str]:
    problems = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel in ALLOWED:
            continue
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:
            problems.append(f"{path}:{e.lineno}: syntax error: {e.msg}")
            continue
        problems.extend(
            f"{path}:{line}: print() call — use repro.obs.log / "
            f"repro.obs.console instead"
            for line in find_prints(tree))
    return problems


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    roots = [pathlib.Path(a) for a in argv] if argv else list(DEFAULT_ROOTS)
    problems = []
    for root in roots:
        problems.extend(lint(root))
    for p in problems:
        console(p, err=True)
    if problems:
        console(f"lint_no_print: {len(problems)} problem(s)", err=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
