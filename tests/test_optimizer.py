"""Optimizer + gradient compression numerics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.grad_compress import compress_decompress, quantize_int8
from repro.train.optimizer import (AdamWConfig, adamw_update, global_norm,
                                   init_opt_state, lr_at)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = AdamWConfig(learning_rate=0.2, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, clip_norm=None)
    state = init_opt_state(params, opt)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, g, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shape():
    opt = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_at(opt, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert lrs[4] >= 0.099                   # floor


def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_preserves_mean_gradient():
    """Sum of compressed grads over many steps ~= sum of true grads
    (error feedback cancels quantization bias)."""
    rng = np.random.default_rng(1)
    err = {"w": jnp.zeros(64)}
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64) * 0.1, jnp.float32)}
        comp, err = compress_decompress(g, err)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(comp["w"])
    resid = np.abs(total_true - total_comp).max()
    scale = np.abs(total_true).max()
    assert resid < 0.05 * scale + 0.05, (resid, scale)


def test_grad_compress_training_still_converges():
    from helpers import rand_batch, tiny
    from repro.launch.mesh import local_mesh
    from repro.models import init_params
    from repro.train.train_loop import make_train_step
    cfg = tiny("dense")
    opt = AdamWConfig(learning_rate=2e-3, grad_compress=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_opt_state(params, opt)
    step = make_train_step(cfg, local_mesh(), opt=opt, global_batch=4)
    batch = rand_batch(cfg, B=4, S=33)
    losses = []
    for _ in range(12):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
