"""Property tests for the batched interleaved rANS coder (codec id 1).

Covers: random + adversarial quantized CDFs (single-quantum symbols,
total == 2**precision extremes), ragged/empty/1-token streams, masked
escape interleaving, AC↔rANS equivalence on identical CDF sequences, and
end-to-end LLMCompressor round trips for codec=rans with/without top-K.
"""
import numpy as np
import pytest

from _hypo import given, settings, st
from helpers import GoldenPredictor, golden_tokens
from repro.core import ac, rans
from repro.core.compressor import (CODEC_RANS, VERSION_V3,
                                   VERSION_V4, LLMCompressor)
from repro.core.cdf import pmf_to_cdf, quantize_pmf


def _rand_cdf(rng, n, bits):
    """Random quantized CDF: total == 2**bits, every symbol >= 1 quantum."""
    pmf = rng.random(n) + 1e-4
    q = (pmf / pmf.sum() * ((1 << bits) - n)).astype(np.int64) + 1
    q[int(rng.integers(0, n))] += (1 << bits) - q.sum()
    cdf = np.zeros(n + 1, np.int64)
    np.cumsum(q, out=cdf[1:])
    return cdf


def _adversarial_cdf(n, bits, hot):
    """All mass on one symbol; every other symbol a single quantum."""
    q = np.ones(n, np.int64)
    q[hot] = (1 << bits) - (n - 1)
    cdf = np.zeros(n + 1, np.int64)
    np.cumsum(q, out=cdf[1:])
    return cdf


# ------------------------------------------------------------ single stream
@settings(max_examples=30, deadline=None)
@given(st.integers(2, 300), st.integers(0, 100), st.integers(0, 10_000),
       st.integers(8, 16))
def test_roundtrip_random_cdfs(vocab, n, seed, bits):
    if (1 << bits) <= vocab:
        return
    rng = np.random.default_rng(seed)
    syms = [int(s) for s in rng.integers(0, vocab, n)]
    cdfs = [_rand_cdf(rng, vocab, bits) for _ in range(n)]
    blob = rans.encode_sequence(syms, cdfs, bits)
    assert rans.decode_sequence(blob, cdfs, bits) == syms


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 500), st.integers(0, 10_000))
def test_single_quantum_symbols(vocab, seed):
    """Adversarial: code symbols that hold exactly one quantum of a
    2**16-total CDF — the worst case for coder state growth."""
    rng = np.random.default_rng(seed)
    hot = int(rng.integers(0, vocab))
    cdf = _adversarial_cdf(vocab, 16, hot)
    cold = [s for s in (0, vocab - 1, (hot + 1) % vocab)]
    syms = [hot] + cold * 3 + [hot]
    cdfs = [cdf] * len(syms)
    blob = rans.encode_sequence(syms, cdfs, bits=16)
    assert rans.decode_sequence(blob, cdfs, bits=16) == syms


def test_empty_and_one_token_streams():
    assert rans.encode_sequence([], [], bits=16) == b""
    cdf = _rand_cdf(np.random.default_rng(0), 10, 16)
    blob = rans.encode_sequence([7], [cdf], bits=16)
    assert len(blob) >= 4  # state flush
    assert rans.decode_sequence(blob, [cdf], bits=16) == [7]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_skewed_efficiency(seed):
    """Measured rANS bits within 3% + flush overhead of the quantized
    entropy (same bound the AC suite enforces)."""
    rng = np.random.default_rng(seed)
    pmf = np.array([0.97, 0.01, 0.01, 0.01])
    n = 2000
    syms = [int(s) for s in rng.choice(4, n, p=pmf)]
    cdf = pmf_to_cdf(np.asarray(quantize_pmf(pmf, 16)))
    blob = rans.encode_sequence(syms, [cdf] * n, bits=16)
    counts = np.bincount(syms, minlength=4)
    q = np.diff(cdf) / cdf[-1]
    ideal = -(counts * np.log2(q)).sum()
    assert len(blob) * 8 <= ideal * 1.03 + 8 * 8


# ------------------------------------------------------------ batched coder
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 24), st.integers(0, 10_000))
def test_batched_ragged_streams_with_escapes(batch, seed):
    """B streams of different lengths advance through shared masked steps,
    with a second uniform (escape) step interleaved for some lanes."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 40, batch)
    enc = rans.BatchedRansEncoder(batch)
    script = []
    for t in range(int(lens.max(initial=0))):
        m = lens > t
        cdfs = np.stack([_rand_cdf(rng, 12, 16) for _ in range(batch)])
        syms = rng.integers(0, 12, batch)
        enc.put_symbols(syms, cdfs, 16, m)
        em = m & (syms == 11)
        esc = rng.integers(0, 300, batch)
        if em.any():
            enc.put_uniform(esc, rans.uniform_bits(300), em)
        script.append((m, cdfs, syms, em, esc))
    streams = enc.finish()
    assert all(len(streams[b]) == 0 for b in range(batch) if lens[b] == 0)
    dec = rans.BatchedRansDecoder(streams)
    for m, cdfs, syms, em, esc in script:
        got = dec.get(cdfs, 16, m)
        assert np.array_equal(got[m], syms[m])
        if em.any():
            gu = dec.get_uniform(rans.uniform_bits(300), em)
            assert np.array_equal(gu[em], esc[em])


def test_batched_matches_single_stream_bytes():
    """A batch of B streams must produce byte-identical output to coding
    each stream alone — interleaving is over *state vectors*, not bytes."""
    rng = np.random.default_rng(42)
    B, T = 5, 30
    cdfs = [[_rand_cdf(rng, 20, 16) for _ in range(T)] for _ in range(B)]
    syms = [[int(s) for s in rng.integers(0, 20, T)] for _ in range(B)]
    enc = rans.BatchedRansEncoder(B)
    for t in range(T):
        enc.put_symbols(np.array([syms[b][t] for b in range(B)]),
                        np.stack([cdfs[b][t] for b in range(B)]), 16)
    batched = enc.finish()
    for b in range(B):
        assert batched[b] == rans.encode_sequence(syms[b], cdfs[b], 16)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(0, 10_000))
def test_slot_encoder_matches_batched_bytes(batch, seed):
    """SlotRansEncoder (per-slot LIFO recording + out-of-order flush, the
    scheduler's encoder) must emit byte-identical streams to the batched
    encoder for the same masked step script."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 30, batch)
    enc = rans.BatchedRansEncoder(batch)
    slot_enc = rans.SlotRansEncoder(batch)
    for t in range(int(lens.max(initial=0))):
        m = lens > t
        cdfs = np.stack([_rand_cdf(rng, 9, 14) for _ in range(batch)])
        syms = rng.integers(0, 9, batch)
        enc.put_symbols(syms, cdfs, 14, m)
        slot_enc.put_symbols(syms, cdfs, 14, m)
        em = m & (syms == 8)
        esc = rng.integers(0, 100, batch)
        if em.any():
            enc.put_uniform(esc, rans.uniform_bits(100), em)
            slot_enc.put_uniform(esc, rans.uniform_bits(100), em)
    batched = enc.finish()
    # flush in scrambled order — slots are independent
    for b in rng.permutation(batch):
        assert slot_enc.flush_slot(int(b)) == batched[b]
        assert slot_enc.pending(int(b)) == 0


def test_decoder_attach_detach_exhausted():
    """Per-slot re-attachment: one decoder instance serves a sequence of
    streams per slot, and `exhausted` certifies clean end-of-stream."""
    rng = np.random.default_rng(7)
    cdf = _rand_cdf(rng, 16, 16)
    dec = rans.BatchedRansDecoder([b""] * 3)
    assert dec.exhausted(0)
    for trip in range(3):
        syms = [int(s) for s in rng.integers(0, 16, 10 + trip)]
        blob = rans.encode_sequence(syms, [cdf] * len(syms), 16)
        slot = trip % 3
        dec.attach(slot, blob)
        m = np.zeros(3, bool)
        m[slot] = True
        got = [int(dec.get(np.broadcast_to(cdf, (3,) + cdf.shape), 16, m)[slot])
               for _ in syms]
        assert got == syms
        assert dec.exhausted(slot)
        dec.detach(slot)
        assert dec.exhausted(slot)


def test_zero_frequency_symbol_rejected():
    cdf = np.array([0, 5, 5, 1 << 16], np.int64)  # symbol 1 has zero mass
    enc = rans.BatchedRansEncoder(1)
    with pytest.raises(ValueError):
        enc.put_symbols(np.array([1]), cdf[None, :], 16)


# --------------------------------------------------------- AC equivalence
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 200), st.integers(1, 120), st.integers(0, 10_000))
def test_ac_rans_equivalence_on_identical_cdfs(vocab, n, seed):
    """Both codecs must decode the identical symbol sequence from the
    identical CDF sequence — the portability contract of the container."""
    rng = np.random.default_rng(seed)
    syms = [int(s) for s in rng.integers(0, vocab, n)]
    cdfs = [_rand_cdf(rng, vocab, 16) for _ in range(n)]
    ac_blob = ac.encode_sequence(syms, cdfs)
    rans_blob = rans.encode_sequence(syms, cdfs, 16)
    assert ac.decode_sequence(ac_blob, cdfs) == syms
    assert rans.decode_sequence(rans_blob, cdfs, 16) == syms
    # same entropy model => sizes agree to within per-stream overhead
    assert abs(len(ac_blob) - len(rans_blob)) <= 8


# ----------------------------------------------------- end-to-end compressor
@pytest.mark.parametrize("topk", [0, 8])
def test_compressor_roundtrip_rans(topk):
    pred = GoldenPredictor()
    toks = golden_tokens(100, seed=5)
    comp = LLMCompressor(pred, chunk_size=16, topk=topk, decode_batch=4,
                         codec="rans")
    blob, stats = comp.compress(toks)
    assert blob[4] == VERSION_V3   # default write: wire-minimal v3
    assert blob[19] == CODEC_RANS
    assert np.array_equal(comp.decompress(blob), toks)
    if topk:
        assert stats.n_escapes > 0  # random tokens under a fixed table


def test_compressor_rans_escape_free_and_escape_heavy():
    pred = GoldenPredictor()
    # escape-free: every chunk is the model's own argmax chain from BOS
    # (chunks restart from a fresh context, so the chain must too)
    chunk = [int(pred.bos_id)]
    for _ in range(16):
        chunk.append(int(np.argmax(pred._table[chunk[-1]])))
    toks = np.array(chunk[1:] * 4, np.int32)
    comp = LLMCompressor(pred, chunk_size=16, topk=8, decode_batch=4)
    blob, stats = comp.compress(toks)
    assert stats.n_escapes == 0
    assert np.array_equal(comp.decompress(blob), toks)
    # escape-heavy: uniform random tokens, tiny top-k
    toks = golden_tokens(60, seed=8)
    comp = LLMCompressor(pred, chunk_size=16, topk=2, decode_batch=4)
    blob, stats = comp.compress(toks)
    assert stats.n_escapes > 30
    assert np.array_equal(comp.decompress(blob), toks)


def test_compressor_rans_empty_and_single_token():
    pred = GoldenPredictor()
    for n in (0, 1):
        toks = golden_tokens(n, seed=n)
        comp = LLMCompressor(pred, chunk_size=16, topk=8, decode_batch=4)
        blob, _ = comp.compress(toks)
        assert np.array_equal(comp.decompress(blob), toks)
