"""Model-zoo behaviour: forward/grads finite, decode == teacher forcing
(the lossless-compression invariant), SSD == naive recurrence, MoE
dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import rand_batch, tiny
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)

FAMILIES = ["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@pytest.mark.parametrize("family", FAMILIES)
def test_forward_and_grads_finite(family):
    cfg = tiny(family)
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = rand_batch(cfg)
    logits = forward(p, cfg, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    g = jax.grad(lambda p: loss_fn(p, cfg, batch))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("family,kw", [
    ("dense", {}), ("dense", {"qk_norm": True, "sliding_window": 6}),
    ("moe", {"capacity_factor": 8.0}), ("ssm", {}), ("hybrid", {}),
    ("encdec", {}),
])
def test_decode_matches_teacher_forcing(family, kw):
    cfg = tiny(family, **kw)
    p = init_params(cfg, jax.random.PRNGKey(0))
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    init_kw = {}
    if family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
        batch["frames"] = frames
        init_kw["source_len"] = 8
    want = forward(p, cfg, batch)
    cache = init_cache(cfg, 2, S, **init_kw)
    if family == "encdec":
        from repro.models.encdec import precompute_cross_kv
        cache["xk"], cache["xv"] = precompute_cross_kv(p, cfg, frames)
    outs = []
    for t in range(S):
        lg, cache = decode_step(p, cfg, cache, toks[:, t])
        outs.append(lg)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_ssd_chunked_equals_naive_recurrence():
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(1)
    B, S, H, P, N = 2, 24, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A))
        h = h * dA[:, :, None, None] + \
            np.asarray(dt[:, t])[:, :, None, None] * \
            np.asarray(x[:, t])[..., None] * \
            np.asarray(Bm[:, t])[:, None, None, :]
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(Cm[:, t])))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), h, atol=1e-4)


def test_moe_dropless_group_invariance():
    """Dropless dispatch must not depend on the dispatch grouping — the
    lossless-serving requirement."""
    cfg = tiny("moe")
    p = init_params(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 257)
    a = forward(p, cfg, {"tokens": t}, dropless=True)
    b = forward(p, cfg, {"tokens": t}, dropless=True, dispatch_group=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must lose expert outputs
    (training path); the layer still runs and is finite."""
    cfg = tiny("moe", capacity_factor=0.05)
    p = init_params(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 257)
    drop = forward(p, cfg, {"tokens": t}, dropless=False)
    full = forward(p, cfg, {"tokens": t}, dropless=True)
    assert np.isfinite(np.asarray(drop, np.float32)).all()
    assert np.abs(np.asarray(drop) - np.asarray(full)).max() > 1e-6


def test_scan_vs_unrolled_equivalence():
    for family in ("dense", "ssm", "hybrid"):
        cfg = tiny(family)
        p = init_params(cfg, jax.random.PRNGKey(0))
        t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 257)
        a = forward(p, cfg, {"tokens": t})
        b = forward(p, cfg.with_(scan_layers=False), {"tokens": t})
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_attention_impls_agree():
    from repro.models.layers import (attention_block_causal, attention_dense,
                                     attention_masked)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 33, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 33, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 33, 2, 16)), jnp.float32)
    for window in (None, 7):
        a = attention_masked(q, k, v, causal=True, window=window, q_chunk=8)
        b = attention_block_causal(q, k, v, causal=True, window=window,
                                   q_chunk=8)
        c = attention_dense(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=2e-5)
