"""Shared tiny-config builders for tests."""
import jax

from repro.configs.base import ModelConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=257, head_pad_multiple=1, vocab_pad_multiple=1,
            dtype="float32", remat=False)


def tiny(family="dense", **kw):
    base = dict(BASE)
    if family == "moe":
        base.update(n_experts=4, top_k=2)
    if family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
    if family == "hybrid":
        base.update(n_layers=3, hybrid_ssm_per_block=1)
    if family == "encdec":
        base.update(n_enc_layers=2, max_source_len=8)
    if family == "vlm":
        base.update(n_img_tokens=4)
    base.update(kw)
    return ModelConfig(name=f"tiny-{family}", family=family, **base)


def rand_batch(cfg, B=2, S=16, key=0):
    import jax.numpy as jnp
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(k, (B, cfg.n_img_tokens,
                                                    cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k, (B, 8, cfg.d_model))
    return batch
