"""Shared tiny-config builders for tests."""
import jax
import numpy as np

from repro.configs.base import ModelConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=257, head_pad_multiple=1, vocab_pad_multiple=1,
            dtype="float32", remat=False)


def tiny(family="dense", **kw):
    base = dict(BASE)
    if family == "moe":
        base.update(n_experts=4, top_k=2)
    if family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
    if family == "hybrid":
        base.update(n_layers=3, hybrid_ssm_per_block=1)
    if family == "encdec":
        base.update(n_enc_layers=2, max_source_len=8)
    if family == "vlm":
        base.update(n_img_tokens=4)
    base.update(kw)
    return ModelConfig(name=f"tiny-{family}", family=family, **base)


class GoldenPredictor:
    """Deterministic, model-free PredictorAdapter for golden-container tests.

    Next-token logits are a fixed (V, V) table indexed by the previous
    token, so both the teacher-forced and incremental scoring paths
    produce bit-identical distributions with no jitted model involved.
    The table is well-separated (scaled normals) so CDF quantization is
    robust to float rounding differences across BLAS builds.
    """

    def __init__(self, vocab_size=64, seed=0):
        self.vocab_size = int(vocab_size)
        self.bos_id = self.vocab_size - 1
        rng = np.random.default_rng(seed)
        self._table = (rng.standard_normal(
            (self.vocab_size, self.vocab_size)) * 2.0).astype(np.float32)

    def score_chunks(self, tokens):
        tokens = np.asarray(tokens, np.int32)
        prev = np.concatenate(
            [np.full((tokens.shape[0], 1), self.bos_id, np.int32),
             tokens[:, :-1]], axis=1)
        return self._table[prev]

    def begin_decode(self, batch):
        return None

    def decode_step(self, state, prev_tokens):
        return self._table[np.asarray(prev_tokens, np.int32)], state

    # speculative decode hooks: the model is stateless (logits depend on
    # the previous token only), so verify is a pure table gather and
    # rollback is the identity
    def verify_steps(self, state, seq):
        return self._table[np.asarray(seq, np.int32)], state

    def rollback(self, snapshots, accepted):
        return snapshots

    # prefix-cache hooks (v6): state is None, so a per-lane snapshot is
    # trivially empty and restore is the identity — which lets scheduler
    # tests exercise the radix-cache bookkeeping (hits, skipped prefill
    # steps) without a jitted model
    def snapshot_slot(self, state, lane):
        return ("golden-snap",)

    def restore_slot(self, state, snapshot, mask):
        return state


def golden_tokens(n=45, seed=1234, vocab=63):
    """The fixed token stream the golden containers were built from.
    Uniform random — the GoldenPredictor table model genuinely *loses*
    to raw store on this stream (~9.5 model bits/token vs 8 packed), so
    it doubles as the router's adversarial input."""
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


def golden_self_tokens(n=45, seed=5678, vocab=64):
    """Tokens softmax-sampled from the GoldenPredictor's own table — the
    stream that model predicts well, i.e. the paper's LLM-generated-text
    regime where the entropy path wins and the router must keep it."""
    pred = GoldenPredictor(vocab_size=vocab)
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.int32)
    prev = pred.bos_id
    for i in range(n):
        logits = pred._table[prev].astype(np.float64)
        p = np.exp(logits - logits.max())
        prev = out[i] = rng.choice(vocab, p=p / p.sum())
    return out


def golden_mixed_tokens():
    """The fixed mixed-regime stream behind the v5 routed golden: at
    chunk_size 16 it splits into 4 chunks alternating model-friendly
    (self-generated -> rans tag) and adversarial (uniform random -> raw
    tag), the last one a 13-token tail."""
    return np.concatenate([golden_self_tokens(16, seed=11),
                           golden_tokens(16, seed=22),
                           golden_self_tokens(16, seed=33),
                           golden_tokens(13, seed=44)])


def golden_text_tokens(n=140, vocab=63):
    """Highly repetitive 'text-like' stream: a dictionary codec (lzma /
    zstd) beats both raw store and the table model on it — the forced-
    fallback goldens use it so the fallback codec actually wins."""
    motif = np.array([5, 6, 7, 5, 6, 7, 9, 9, 5, 6], np.int32) % vocab
    return np.tile(motif, n // motif.size + 1)[:n].astype(np.int32)


def rand_batch(cfg, B=2, S=16, key=0):
    import jax.numpy as jnp
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(k, (B, cfg.n_img_tokens,
                                                    cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k, (B, 8, cfg.d_model))
    return batch
