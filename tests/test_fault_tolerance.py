"""Checkpoint/restart, corruption fallback, bitwise resume, watchdog."""
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.train.checkpoint import (load_checkpoint, restore_latest,
                                    save_checkpoint)

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32)}}
    save_checkpoint(tmp_path, 3, tree)
    like = {"a": np.zeros((3, 4), np.float32), "b": {"c": np.zeros(5, np.int32)}}
    out, step = restore_latest(tmp_path, like)
    assert step == 3
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_corrupt_checkpoint_skipped(tmp_path):
    tree = {"a": np.arange(4, dtype=np.float32)}
    save_checkpoint(tmp_path, 1, tree)
    save_checkpoint(tmp_path, 2, {"a": tree["a"] * 2})
    newest = sorted(tmp_path.glob("step-*"))[-1]
    raw = (newest / "arrays.msgpack").read_bytes()
    (newest / "arrays.msgpack").write_bytes(raw[: len(raw) // 2])
    out, step = restore_latest(tmp_path, {"a": np.zeros(4, np.float32)})
    assert step == 1
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_retention_gc(tmp_path):
    for s in range(6):
        save_checkpoint(tmp_path, s, {"a": np.zeros(2)}, keep=2)
    assert len(list(tmp_path.glob("step-*"))) == 2


def test_bitwise_resume(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical
    parameters bit for bit (pipeline cursor is part of the state)."""
    from helpers import tiny
    from repro.data.pipeline import TokenPipeline
    from repro.launch.mesh import local_mesh
    from repro.models import init_params
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_loop import make_train_step

    cfg = tiny("dense")
    toks = (np.arange(20000) * 7919) % 250
    opt = AdamWConfig(learning_rate=1e-3)

    def fresh():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return params, init_opt_state(params, opt), \
            TokenPipeline(toks, global_batch=4, seq_len=32, seed=5)

    step_fn = make_train_step(cfg, local_mesh(), opt=opt, global_batch=4,
                              donate=False)

    params, state, pipe = fresh()
    for s in range(6):
        params, state, _ = step_fn(params, state,
                                   {"tokens": pipe.global_batch_array(s)})
    straight = params

    params, state, pipe = fresh()
    for s in range(3):
        params, state, _ = step_fn(params, state,
                                   {"tokens": pipe.global_batch_array(s)})
    save_checkpoint(tmp_path, 3, {"params": params, "opt": state})
    like = {"params": params, "opt": state}
    restored, _ = restore_latest(tmp_path, like)
    params, state = restored["params"], restored["opt"]
    for s in range(3, 6):
        params, state, _ = step_fn(params, state,
                                   {"tokens": pipe.global_batch_array(s)})
    for a, b in zip(jax.tree_util.tree_leaves(straight),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_watchdog_restart_end_to_end(tmp_path):
    """Fault injection: crash mid-run, watchdog respawns, training reaches
    the target step and reports a final loss."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3_1_7b",
           "--smoke", "--steps", "16", "--batch", "2", "--seq-len", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
           "--crash-at", "7", "--watchdog", "--log-every", "5"]
    env = {"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=600)
    assert "train.fault_injection" in out.stdout
    assert "train.resume" in out.stdout
    assert "train.done" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-2000:]
