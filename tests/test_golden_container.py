"""Golden-container regression matrix: frozen byte blobs guard the format.

``tests/golden/`` holds one container per cell of the cross-version
matrix (version × codec × cdf-mode/route), produced by known-good code:

* ``v2_*.llmc`` — written by the SEED compressor (container version 2,
  implicit AC codec, no codec byte). Frozen forever; they can no longer
  be regenerated, which is the point — new code must keep decoding old
  archives bit-exactly.
* ``v3_*.llmc`` — codec byte (0=AC, 1=rANS); the default write version
  for the pure-LLM route. Encode must stay byte-stable: any
  container-format or coder drift shows up as a byte diff here before it
  silently corrupts archives in the wild.
* ``v4_*.llmc`` — the seekable format (index footer + xxh64 checksums)
  written by ``container_version=4`` and the default service path.
  Byte-stable like v3, and additionally the index must keep verifying.
* ``v5_*.llmc`` — v4 plus a hash-covered per-chunk codec tag
  (DESIGN.md §11). Three routing regimes are pinned: pure-LLM
  (``v5_rans_*``: every tag is the header entropy codec), adaptive
  mixed (``v5_mixed_raw``: the fixed interleaved stream routes to
  exactly [rans, raw, rans, raw]), and forced-fallback
  (``v5_fallback_lzma``: repetitive text under ``route="lzma"``, no
  chunk touches the model). The lzma cell is decode-only — its payload
  bytes depend on the liblzma build, so like v2 it guards decode, not
  re-encode.
* ``v6_*.llmc`` — v5 plus a hash-covered per-chunk context recipe and a
  shared-prefix dictionary section (DESIGN.md §12). Three regimes:
  carried context (``v6_carry_topk``: striped carry chains), shared
  prefix (``v6_shared_full``: every chunk conditioned on a dictionary
  prefix), and routed+carried (``v6_mixed_raw``: fallback chunks get
  their recipes zeroed by format law). Byte-stable like v3–v5.

All goldens use the deterministic, model-free ``GoldenPredictor`` and
fixed token streams (tests/helpers.py), so no model weights are
involved; routing decisions are deterministic because the probe scores
through the same table.
"""
import pathlib

import numpy as np
import pytest

from helpers import (GoldenPredictor, golden_mixed_tokens,
                     golden_self_tokens, golden_text_tokens, golden_tokens)
from repro.core import LLMCompressor, RouterConfig, read_header

GOLDEN = pathlib.Path(__file__).parent / "golden"

# The matrix: name -> (version, constructor kwargs, token stream). The
# file name spells the cell: version, codec, and cdf mode (topk/full)
# or routing regime (mixed/fallback).
CASES = {
    "v2_topk.llmc": (2, dict(topk=8), golden_tokens()),
    "v2_full.llmc": (2, dict(topk=0), golden_tokens(37, seed=77)),
    "v3_rans_topk.llmc": (3, dict(topk=8, codec="rans"), golden_tokens()),
    "v3_rans_full.llmc": (3, dict(topk=0, codec="rans"),
                          golden_tokens(37, seed=77)),
    "v3_ac_topk.llmc": (3, dict(topk=8, codec="ac"), golden_tokens()),
    "v4_rans_topk.llmc": (4, dict(topk=8, codec="rans",
                                  container_version=4), golden_tokens()),
    "v4_rans_full.llmc": (4, dict(topk=0, codec="rans",
                                  container_version=4),
                          golden_tokens(37, seed=77)),
    "v4_ac_topk.llmc": (4, dict(topk=8, codec="ac", container_version=4),
                        golden_tokens()),
    "v5_rans_topk.llmc": (5, dict(topk=8, codec="rans",
                                  container_version=5), golden_tokens()),
    "v5_rans_full.llmc": (5, dict(topk=0, codec="rans",
                                  container_version=5),
                          golden_tokens(37, seed=77)),
    "v5_mixed_raw.llmc": (5, dict(topk=8, codec="rans",
                                  container_version=5, route="auto",
                                  router=RouterConfig(fallbacks=("raw",))),
                          golden_mixed_tokens()),
    "v5_fallback_lzma.llmc": (5, dict(topk=8, codec="rans",
                                      container_version=5, route="lzma",
                                      chunk_size=64),
                              golden_text_tokens()),
    "v6_carry_topk.llmc": (6, dict(topk=8, codec="rans",
                                   container_version=6, context_window=8,
                                   context_stripes=2),
                           golden_self_tokens()),
    "v6_shared_full.llmc": (6, dict(topk=0, codec="rans",
                                    container_version=6,
                                    shared_prefix=golden_self_tokens(
                                        12, seed=9)),
                            golden_self_tokens(37, seed=321)),
    "v6_mixed_raw.llmc": (6, dict(topk=8, codec="rans",
                                  container_version=6, route="auto",
                                  router=RouterConfig(fallbacks=("raw",)),
                                  context_window=8, context_stripes=1),
                          golden_mixed_tokens()),
}

# Cells whose bytes must decode but are NOT re-encoded for identity:
# v2 because the seed writer is gone; the lzma cell because liblzma
# builds may legally differ byte-for-byte (raw/rans/zstd-free cells
# depend only on this repo's own coders and numpy, so they are stable).
DECODE_ONLY = {"v2_topk.llmc", "v2_full.llmc", "v5_fallback_lzma.llmc"}


def _comp(kw):
    base = dict(chunk_size=16, decode_batch=4)
    base.update(kw)
    return LLMCompressor(GoldenPredictor(), **base)


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_decodes(name):
    """Every checked-in container — all four versions, every codec and
    routing regime — decodes to its original token stream through the
    current path."""
    _, kw, toks = CASES[name]
    blob = (GOLDEN / name).read_bytes()
    assert np.array_equal(_comp(kw).decompress(blob), toks)


@pytest.mark.parametrize("name",
                         [n for n in sorted(CASES) if n not in DECODE_ONLY])
def test_encode_byte_stable(name):
    """Re-encoding the golden inputs must reproduce the golden bytes.
    For the routed v5 cells this also freezes the router's *decisions*:
    a policy drift that re-routes a chunk changes the tag byte and the
    stream, and fails here before it ships."""
    _, kw, toks = CASES[name]
    blob, _ = _comp(kw).compress(toks)
    assert blob == (GOLDEN / name).read_bytes()


@pytest.mark.parametrize("name", sorted(CASES))
def test_header_version_matches_cell(name):
    """The matrix is honest: each blob's parsed header version equals
    the version its file name (and CASES row) claims."""
    version, _, _ = CASES[name]
    assert read_header((GOLDEN / name).read_bytes()).version == version


def test_v2_header_shape_frozen():
    """The v2 goldens really are version-2, codec-less containers."""
    for name in ("v2_topk.llmc", "v2_full.llmc"):
        blob = (GOLDEN / name).read_bytes()
        assert blob[:4] == b"LLMC" and blob[4] == 2


def test_v3_header_carries_codec():
    assert (GOLDEN / "v3_rans_topk.llmc").read_bytes()[19] == 1
    assert (GOLDEN / "v3_ac_topk.llmc").read_bytes()[19] == 0


@pytest.mark.parametrize("name",
                         [n for n in sorted(CASES) if not n.startswith(
                             ("v2", "v3"))])
def test_indexed_goldens_carry_verified_index(name):
    from repro.core import read_index
    _, kw, toks = CASES[name]
    blob = (GOLDEN / name).read_bytes()
    info = read_index(blob)             # verifies footer checksum
    assert blob[-4:] == {"v4": b"LC4F", "v5": b"LC5F",
                         "v6": b"LC6F"}[name[:2]]
    assert info.n_chunks == len(info.entries)
    assert sum(e.n_tokens for e in info.entries) == toks.size
    # the encoder's batch shape is part of the coding geometry on
    # non-batch-invariant models; v4+ records the lane count the model
    # program actually ran at. That counts chunks that ENTERED the model
    # batch, which can exceed the surviving LLM tags (a chunk may flip
    # to its fallback after encode) but never falls below them, and is 0
    # when no chunk touched the model at all (forced-fallback cell). The
    # mixed golden pins 3: the probe skipped one random chunk, kept the
    # other (it flipped to raw only after the realized-size compare).
    # Carried v6 containers batch one lane per carry CHAIN instead, so
    # their lane count is min(stripes, n_chains): 2 for the striped
    # carry cell, 3 chains (= chunks, all heads) for the shared cell,
    # and 1 for the single-stripe mixed cell.
    if name.startswith("v6"):
        assert info.encode_batch == {"v6_carry_topk.llmc": 2,
                                     "v6_shared_full.llmc": 3,
                                     "v6_mixed_raw.llmc": 1}[name]
    else:
        assert info.ctx_budget == 0     # pre-v6 wire has no budget field
        n_llm = sum(e.is_llm for e in info.entries)
        assert min(4, n_llm) <= info.encode_batch <= min(4, info.n_chunks)
        if name == "v5_mixed_raw.llmc":
            assert info.encode_batch == 3
        elif name == "v5_fallback_lzma.llmc":
            assert info.encode_batch == 0
        else:
            assert info.encode_batch == min(4, info.n_chunks)
    if info.n_chunks:
        # random access: last chunk alone (works across mixed codecs)
        C = info.chunk_size
        last = _comp(kw).decompress_range(blob, info.n_chunks - 1,
                                          info.n_chunks)
        assert np.array_equal(last, toks[(info.n_chunks - 1) * C:])


def test_v5_pure_llm_tags_are_entropy_codec():
    """The pure-LLM v5 cells tag every chunk with the header codec —
    decoders may treat them exactly like a v4 container."""
    from repro.core import read_index
    for name in ("v5_rans_topk.llmc", "v5_rans_full.llmc"):
        info = read_index((GOLDEN / name).read_bytes())
        assert [e.codec_name for e in info.entries] == \
            ["rans"] * info.n_chunks
        assert all(e.is_llm for e in info.entries)


def test_v5_mixed_golden_routing_frozen():
    """The mixed golden's routing is pinned chunk by chunk: the
    self-generated chunks stayed on the entropy path, the uniform-random
    chunks fell back to raw store."""
    from repro.core import read_index
    info = read_index((GOLDEN / "v5_mixed_raw.llmc").read_bytes())
    assert [e.codec_name for e in info.entries] == \
        ["rans", "raw", "rans", "raw"]


def test_v5_fallback_golden_never_touches_model():
    """The forced-lzma golden: every chunk carries a fallback tag (lzma
    where it wins, raw for the short tail) and encode_batch is 0 — no
    model lanes ran. Decode must not need the model either: a predictor
    whose table differs still reconstructs the stream."""
    from repro.core import read_index
    blob = (GOLDEN / "v5_fallback_lzma.llmc").read_bytes()
    info = read_index(blob)
    assert info.encode_batch == 0
    names = [e.codec_name for e in info.entries]
    assert names == ["lzma", "lzma", "raw"]
    other = LLMCompressor(GoldenPredictor(seed=999), chunk_size=64,
                          decode_batch=4, topk=8)
    assert np.array_equal(other.decompress(blob), golden_text_tokens())


def test_v6_golden_recipes_frozen():
    """The v6 cells pin the recipe plan chunk by chunk, including the
    two format laws that matter most: a routed-to-fallback chunk has its
    recipe zeroed (mixed cell, chunks 1 and 3), and a carry may survive
    across a fallback-coded *predecessor* (mixed cell, chunk 2 — its
    context tokens come from decoded output, not from any codec)."""
    from repro.core import read_index
    # third element: the recorded decode-length budget (ctx_budget) —
    # coding geometry, computed from the PRE-routing context plan, so
    # the mixed cell records 8 even though routing later zeroed some
    # carries (the model groups still encoded at chunk_size + 8)
    expect = {
        "v6_carry_topk.llmc": (["none", "carry(8)", "none"], 0, 8),
        "v6_shared_full.llmc": (["shared[0]"] * 3, 1, 12),
        "v6_mixed_raw.llmc": (["none", "none", "carry(8)", "none"], 0, 8),
    }
    for name, (recipes, n_prefixes, budget) in expect.items():
        info = read_index((GOLDEN / name).read_bytes())
        assert [e.recipe_name for e in info.entries] == recipes, name
        assert len(info.shared_prefixes) == n_prefixes, name
        assert info.ctx_budget == budget, name
    info = read_index((GOLDEN / "v6_shared_full.llmc").read_bytes())
    name, toks = info.shared_prefixes[0]
    assert name == "shared" and np.array_equal(
        toks, golden_self_tokens(12, seed=9))
    # and the mixed cell's tag row matches the v5 mixed regime
    info = read_index((GOLDEN / "v6_mixed_raw.llmc").read_bytes())
    assert [e.codec_name for e in info.entries] == \
        ["rans", "raw", "rans", "raw"]


def test_v6_goldens_range_matches_full_decode():
    """Every chunk interval of every v6 golden — carried, shared, and
    routed — equals the matching slice of a full decode. This is the
    format's core promise: a recipe never makes a chunk depend on
    anything `decompress_range` can't reconstruct."""
    from repro.core import read_index
    for name in ("v6_carry_topk.llmc", "v6_shared_full.llmc",
                 "v6_mixed_raw.llmc"):
        _, kw, toks = CASES[name]
        comp = _comp(kw)
        blob = (GOLDEN / name).read_bytes()
        full = comp.decompress(blob)
        assert np.array_equal(full, toks)
        info = read_index(blob)
        C = info.chunk_size
        for lo in range(info.n_chunks):
            for hi in range(lo + 1, info.n_chunks + 1):
                part = comp.decompress_range(blob, lo, hi)
                assert np.array_equal(
                    part, full[lo * C:min(hi * C, toks.size)]), (name, lo, hi)


def test_v5_mixed_range_matches_full_decode():
    """Random access stays exact across mixed codecs: every chunk
    interval of the routed golden equals the matching slice of a full
    decode."""
    _, kw, toks = CASES["v5_mixed_raw.llmc"]
    comp = _comp(kw)
    blob = (GOLDEN / "v5_mixed_raw.llmc").read_bytes()
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    from repro.core import read_index
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(
                part, full[lo * C:min(hi * C, toks.size)]), (lo, hi)
