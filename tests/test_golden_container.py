"""Golden-container regression: frozen byte blobs guard the format.

``tests/golden/`` holds containers produced by known-good code:

* ``v2_*.llmc`` — written by the SEED compressor (container version 2,
  implicit AC codec, no codec byte). Frozen forever; they can no longer
  be regenerated, which is the point — new code must keep decoding old
  archives bit-exactly.
* ``v3_*.llmc`` — written by the current compressor (codec byte: 0=AC,
  1=rANS). Encode must stay byte-stable: any container-format or coder
  drift shows up as a byte diff here before it silently corrupts
  archives in the wild.

All goldens use the deterministic, model-free ``GoldenPredictor`` and
the fixed ``golden_tokens`` streams (tests/helpers.py), so no model
weights are involved.
"""
import pathlib

import numpy as np
import pytest

from helpers import GoldenPredictor, golden_tokens
from repro.core import LLMCompressor

GOLDEN = pathlib.Path(__file__).parent / "golden"

# name -> (constructor kwargs, token stream)
CASES = {
    "v2_topk.llmc": (dict(topk=8), golden_tokens()),
    "v2_full.llmc": (dict(topk=0), golden_tokens(37, seed=77)),
    "v3_rans_topk.llmc": (dict(topk=8, codec="rans"), golden_tokens()),
    "v3_rans_full.llmc": (dict(topk=0, codec="rans"),
                          golden_tokens(37, seed=77)),
    "v3_ac_topk.llmc": (dict(topk=8, codec="ac"), golden_tokens()),
}


def _comp(kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=16, decode_batch=4,
                         **kw)


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_decodes(name):
    """Every checked-in container — seed v2 and current v3, both codecs —
    decodes to its original token stream through the current path."""
    kw, toks = CASES[name]
    blob = (GOLDEN / name).read_bytes()
    assert np.array_equal(_comp(kw).decompress(blob), toks)


@pytest.mark.parametrize("name", [n for n in sorted(CASES)
                                  if n.startswith("v3")])
def test_v3_encode_byte_stable(name):
    """Re-encoding the golden inputs must reproduce the golden bytes."""
    kw, toks = CASES[name]
    blob, _ = _comp(kw).compress(toks)
    assert blob == (GOLDEN / name).read_bytes()


def test_v2_header_shape_frozen():
    """The v2 goldens really are version-2, codec-less containers."""
    for name in ("v2_topk.llmc", "v2_full.llmc"):
        blob = (GOLDEN / name).read_bytes()
        assert blob[:4] == b"LLMC" and blob[4] == 2


def test_v3_header_carries_codec():
    assert (GOLDEN / "v3_rans_topk.llmc").read_bytes()[19] == 1
    assert (GOLDEN / "v3_ac_topk.llmc").read_bytes()[19] == 0
