"""Golden-container regression: frozen byte blobs guard the format.

``tests/golden/`` holds containers produced by known-good code:

* ``v2_*.llmc`` — written by the SEED compressor (container version 2,
  implicit AC codec, no codec byte). Frozen forever; they can no longer
  be regenerated, which is the point — new code must keep decoding old
  archives bit-exactly.
* ``v3_*.llmc`` — written by the current compressor (codec byte: 0=AC,
  1=rANS; the default write version). Encode must stay byte-stable: any
  container-format or coder drift shows up as a byte diff here before it
  silently corrupts archives in the wild.
* ``v4_*.llmc`` — the seekable format (index footer + xxh64 checksums)
  written by ``container_version=4`` and by the compression service.
  Byte-stable like v3, and additionally the index must keep verifying.

All goldens use the deterministic, model-free ``GoldenPredictor`` and
the fixed ``golden_tokens`` streams (tests/helpers.py), so no model
weights are involved.
"""
import pathlib

import numpy as np
import pytest

from helpers import GoldenPredictor, golden_tokens
from repro.core import LLMCompressor

GOLDEN = pathlib.Path(__file__).parent / "golden"

# name -> (constructor kwargs, token stream)
CASES = {
    "v2_topk.llmc": (dict(topk=8), golden_tokens()),
    "v2_full.llmc": (dict(topk=0), golden_tokens(37, seed=77)),
    "v3_rans_topk.llmc": (dict(topk=8, codec="rans"), golden_tokens()),
    "v3_rans_full.llmc": (dict(topk=0, codec="rans"),
                          golden_tokens(37, seed=77)),
    "v3_ac_topk.llmc": (dict(topk=8, codec="ac"), golden_tokens()),
    "v4_rans_topk.llmc": (dict(topk=8, codec="rans", container_version=4),
                          golden_tokens()),
    "v4_rans_full.llmc": (dict(topk=0, codec="rans", container_version=4),
                          golden_tokens(37, seed=77)),
    "v4_ac_topk.llmc": (dict(topk=8, codec="ac", container_version=4),
                        golden_tokens()),
}


def _comp(kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=16, decode_batch=4,
                         **kw)


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_decodes(name):
    """Every checked-in container — seed v2 and current v3, both codecs —
    decodes to its original token stream through the current path."""
    kw, toks = CASES[name]
    blob = (GOLDEN / name).read_bytes()
    assert np.array_equal(_comp(kw).decompress(blob), toks)


@pytest.mark.parametrize("name", [n for n in sorted(CASES)
                                  if not n.startswith("v2")])
def test_encode_byte_stable(name):
    """Re-encoding the golden inputs must reproduce the golden bytes
    (v3 and v4 — v2 is read-only and can no longer be written)."""
    kw, toks = CASES[name]
    blob, _ = _comp(kw).compress(toks)
    assert blob == (GOLDEN / name).read_bytes()


def test_v2_header_shape_frozen():
    """The v2 goldens really are version-2, codec-less containers."""
    for name in ("v2_topk.llmc", "v2_full.llmc"):
        blob = (GOLDEN / name).read_bytes()
        assert blob[:4] == b"LLMC" and blob[4] == 2


def test_v3_header_carries_codec():
    assert (GOLDEN / "v3_rans_topk.llmc").read_bytes()[19] == 1
    assert (GOLDEN / "v3_ac_topk.llmc").read_bytes()[19] == 0


def test_v4_goldens_carry_verified_index():
    from repro.core import read_index
    for name in sorted(CASES):
        if not name.startswith("v4"):
            continue
        kw, toks = CASES[name]
        blob = (GOLDEN / name).read_bytes()
        info = read_index(blob)             # verifies footer checksum
        assert blob[-4:] == b"LC4F"
        assert info.n_chunks == len(info.entries)
        assert sum(e.n_tokens for e in info.entries) == toks.size
        # the encoder's batch shape is part of the coding geometry on
        # non-batch-invariant models; v4 records the lane count every
        # chunk ran at — min(decode_batch=4, n_chunks) for the grouped path
        assert info.encode_batch == min(4, info.n_chunks)
        # random access: last chunk alone
        last = _comp(kw).decompress_range(blob, info.n_chunks - 1,
                                          info.n_chunks)
        assert np.array_equal(last, toks[(info.n_chunks - 1) * 16:])
