"""End-to-end behaviour of the paper's system: train a predictor, generate
"LLM text" with it, compress losslessly, beat gzip; serve steps and
compressor agree (prefill scoring is a calibrated estimate of the exact
decode-path coder)."""
import numpy as np
import pytest

import jax

from helpers import tiny
from repro.core import LLMCompressor
from repro.core.baselines import gzip_ratio
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import human_like
from repro.data.tokenizer import BOS_ID, encode
from repro.launch.mesh import local_mesh
from repro.models import init_params
from repro.serve.engine import ModelPredictor
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step


@pytest.fixture(scope="module")
def trained_predictor():
    """The benchmark-suite predictor (cached in results/bench_cache after
    the first run) — the validated generation/compression protocol."""
    from benchmarks.prep import predictor
    return predictor("pred-small")


def _gen_corpus(pred, n_bytes, seed=7):
    from benchmarks.prep import llm_dataset
    # per-document prompt+continuation protocol (benchmarks/prep.py)
    return llm_dataset("wiki", n_bytes, gen_model="pred-small", seed=seed)


@pytest.mark.slow
def test_end_to_end_llm_compression(trained_predictor):
    pred = trained_predictor
    raw = _gen_corpus(pred, 4096, seed=7)
    data = encode(raw)
    comp = LLMCompressor(pred, chunk_size=64, topk=32, decode_batch=16)
    blob, stats = comp.compress(data)
    out = comp.decompress(blob)
    assert np.array_equal(out, data), "LOSSLESS VIOLATION"
    ratio = len(raw) / len(blob)
    gz = gzip_ratio(raw)
    assert ratio > gz, (ratio, gz)   # the paper's headline claim, micro-scale
    assert ratio > 2.0, ratio


@pytest.mark.slow
def test_chunk_size_improves_ratio(trained_predictor):
    pred = trained_predictor
    data = encode(_gen_corpus(pred, 3072, seed=3))
    r = {}
    for c in (16, 128):
        comp = LLMCompressor(pred, chunk_size=c, topk=32, decode_batch=16)
        blob, _ = comp.compress(data)
        r[c] = data.size / len(blob)
    assert r[128] > r[16], r    # paper §5.4


@pytest.mark.slow
def test_own_text_more_compressible_than_human(trained_predictor):
    pred = trained_predictor
    own = encode(_gen_corpus(pred, 3072, seed=5))
    from repro.data.synthetic import human_like_ood
    # realistic human condition: out-of-training-distribution lexical mass
    human = encode(human_like_ood("wiki", 3072, seed=99))
    comp = LLMCompressor(pred, chunk_size=64, topk=32, decode_batch=16)
    b_own, _ = comp.compress(own)
    b_hum, _ = comp.compress(human)
    r_own = own.size / len(b_own)
    r_hum = human.size / len(b_hum)
    assert r_own > r_hum, (r_own, r_hum)   # paper Fig 9


def test_prefill_estimate_close_to_exact(trained_predictor):
    """exact=False (prefill scoring) must produce ~the same SIZE as the
    exact decode-path coder (it is the dry-run's prefill shape)."""
    pred = trained_predictor
    data = encode(_gen_corpus(pred, 2048, seed=11))
    comp = LLMCompressor(pred, chunk_size=64, topk=32, decode_batch=8)
    exact, _ = comp.compress(data, exact=True)
    est, _ = comp.compress(data, exact=False)
    assert abs(len(est) - len(exact)) / len(exact) < 0.02
