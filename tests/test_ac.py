"""Property tests for the arithmetic coder + CDF quantization (hypothesis)."""
import numpy as np
from _hypo import given, settings, st

from repro.core import ac
from repro.core.cdf import pmf_to_cdf, quantize_cdf_points, quantize_pmf


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 200), st.integers(1, 120), st.integers(0, 10_000))
def test_roundtrip_random_cdfs(vocab, n, seed):
    rng = np.random.default_rng(seed)
    syms = rng.integers(0, vocab, n)
    cdfs = []
    enc = ac.ArithmeticEncoder()
    for s in syms:
        pmf = rng.random(vocab) + 1e-4
        q = np.asarray(quantize_pmf(pmf / pmf.sum(), 16))
        cdf = pmf_to_cdf(q)
        cdfs.append(cdf)
        enc.encode(int(s), cdf)
    blob = enc.finish()
    dec = ac.ArithmeticDecoder(blob)
    assert [dec.decode(c) for c in cdfs] == list(syms)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 2000), st.integers(0, 10_000), st.integers(12, 20))
def test_quantization_invariants(vocab, seed, precision):
    if (1 << precision) <= vocab:
        return
    rng = np.random.default_rng(seed)
    pmf = rng.random(vocab) ** 4 + 1e-9  # peaky
    pmf /= pmf.sum()
    pts = np.asarray(quantize_cdf_points(pmf, precision))
    assert pts[-1] == 1 << precision          # exact total
    assert (np.diff(pts) >= 1).all()           # every symbol codable
    assert pts[0] >= 1
    q = np.asarray(quantize_pmf(pmf, precision))
    assert q.sum() == 1 << precision and q.min() >= 1


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_skewed_distribution_efficiency(seed):
    """Measured bits within 3% + 32 bits of the quantized entropy."""
    rng = np.random.default_rng(seed)
    pmf = np.array([0.97, 0.01, 0.01, 0.01])
    n = 2000
    syms = rng.choice(4, n, p=pmf)
    cdf = pmf_to_cdf(np.asarray(quantize_pmf(pmf, 16)))
    enc = ac.ArithmeticEncoder()
    for s in syms:
        enc.encode(int(s), cdf)
    bits = len(enc.finish()) * 8
    counts = np.bincount(syms, minlength=4)
    q = np.diff(cdf) / cdf[-1]
    ideal = -(counts * np.log2(q)).sum()
    assert bits <= ideal * 1.03 + 32


def test_uniform_cdf_escape_path():
    cdf = ac.uniform_cdf(1000)
    enc = ac.ArithmeticEncoder()
    for s in (0, 999, 123):
        enc.encode(s, cdf)
    dec = ac.ArithmeticDecoder(enc.finish())
    assert [dec.decode(cdf) for _ in range(3)] == [0, 999, 123]
