"""Performance attribution layer (DESIGN.md §13): timeline recorder,
Chrome-trace export, per-job phase reports, bench history + regression
gate, and the roofline attainment math.
"""
import importlib.util
import json
import pathlib
import threading

import numpy as np
import pytest

from helpers import GoldenPredictor
from repro import obs
from repro.obs.bench_history import (BenchHistory, BenchRecord,
                                     parse_derived, validate_record)
from repro.obs.metrics import MetricsRegistry
from repro.obs.timeline import (PhaseReport, SpanEvent, TimelineRecorder,
                                phase_of, phases_from_registry)
from repro.service import CompressionService

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------ timeline recorder
def test_ring_buffer_bounds_and_drop_counter():
    rec = TimelineRecorder(capacity=8)
    for i in range(20):
        rec.record(f"s{i}", f"s{i}", t0=float(i), dur=0.5)
    assert len(rec) == 8
    assert rec.dropped == 12
    evs = rec.events()
    assert len(evs) == 8
    # the ring keeps the NEWEST events, oldest-first
    assert [e.name for e in evs] == [f"s{i}" for i in range(12, 20)]
    with pytest.raises(ValueError):
        TimelineRecorder(capacity=0)


def test_spans_feed_installed_recorder():
    reg = MetricsRegistry()
    with TimelineRecorder() as rec:
        with obs.span("outer", reg, tags={"job": 1}):
            with obs.span("model.step", reg):
                pass
    assert obs.timeline.active() is None        # context exit uninstalls
    evs = rec.events()
    assert [e.name for e in evs] == ["outer", "model.step"]
    assert evs[1].path == "outer/model.step"
    assert evs[0].tags == {"job": 1}
    # nesting invariant the phase sweep relies on: child inside parent
    assert evs[0].t0 <= evs[1].t0 and evs[1].t1 <= evs[0].t1 + 1e-9
    # uninstalled -> no further events
    with obs.span("after", reg):
        pass
    assert len(rec.events()) == 2


def test_timeline_only_span_overrides_registry_gate():
    """With a recorder installed, spans against a DISABLED registry still
    land on the timeline (the process-wide recorder must see coder/model
    spans recording against the global registry) — but never observe into
    the disabled registry."""
    reg = MetricsRegistry(enabled=False)
    assert obs.span("quiet", reg) is obs.trace.NULL     # no recorder
    with TimelineRecorder() as rec:
        sp = obs.span("quiet", reg)
        assert sp is not obs.trace.NULL
        with sp:
            pass
    assert [e.name for e in rec.events()] == ["quiet"]
    assert reg.get("span.quiet.seconds") is None


def test_chrome_trace_structure(tmp_path):
    reg = MetricsRegistry()
    with TimelineRecorder() as rec:
        with obs.span("service.step", reg):
            with obs.span("model.decode_step", reg):
                pass
    path = tmp_path / "trace.json"
    rec.save(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped_events"] == 0
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "process_name"
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"service.step", "model.decode_step"}
    for e in xs:
        # complete events: µs ts/dur, pid/tid, category = phase bucket
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["tid"], int) and e["pid"] == 1
        assert e["cat"] == phase_of(e["name"])
        assert "path" in e["args"]


# --------------------------------------------------- span failure safety
def test_span_exception_restores_nesting_path():
    reg = MetricsRegistry()
    with pytest.raises(RuntimeError):
        with obs.span("outer", reg):
            with obs.span("inner", reg):
                raise RuntimeError("boom")
    assert obs.trace.current() == ""
    # both spans still closed into their histograms
    assert reg.get("span.outer.seconds").count == 1
    assert reg.get("span.outer/inner.seconds").count == 1


def test_span_stack_is_per_thread():
    reg = MetricsRegistry()
    paths = {}

    def worker(tag):
        with obs.span(tag, reg):
            paths[tag] = obs.trace.current()

    with obs.span("main_outer", reg):
        ts = [threading.Thread(target=worker, args=(f"t{i}",))
              for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert obs.trace.current() == "main_outer"
    # worker threads never saw the main thread's open span
    assert paths == {f"t{i}": f"t{i}" for i in range(4)}


def test_recorder_safe_from_many_threads():
    rec = TimelineRecorder(capacity=64)
    barrier = threading.Barrier(8)

    def pound():
        barrier.wait()
        for i in range(100):
            rec.record("x", "x", t0=float(i), dur=0.1)

    ts = [threading.Thread(target=pound) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(rec) == 64                       # never exceeds capacity
    assert rec.dropped == 8 * 100 - 64
    assert len(rec.events()) == 64


# -------------------------------------------------------- phase rollup
def test_phase_report_exclusive_attribution():
    """Synthetic nest: 10s window, scheduler step [1,9] containing model
    [2,5] and coder [6,8] -> exclusive scheduler 3s, model 3s, coder 2s,
    unattributed 2s ([0,1] + [9,10])."""
    evs = [
        SpanEvent("service.step", "service.step", 1.0, 8.0, tid=1),
        SpanEvent("model.decode_step", "service.step/model.decode_step",
                  2.0, 3.0, tid=1),
        SpanEvent("rans.flush_slot", "service.step/rans.flush_slot",
                  6.0, 2.0, tid=1),
    ]
    rep = PhaseReport.from_events(evs, t0=0.0, t1=10.0)
    assert rep.total_s == 10.0
    assert rep.phases["scheduler"] == pytest.approx(3.0)
    assert rep.phases["model"] == pytest.approx(3.0)
    assert rep.phases["coder"] == pytest.approx(2.0)
    assert rep.phases["unattributed"] == pytest.approx(2.0)
    assert sum(rep.phases.values()) == pytest.approx(rep.total_s)
    assert rep.coverage == pytest.approx(0.8)
    # window clipping: an event straddling t0 contributes only its
    # in-window part
    clipped = PhaseReport.from_events(evs, t0=3.0, t1=10.0)
    assert clipped.phases["model"] == pytest.approx(2.0)   # [3,5] of [2,5]
    d = rep.to_dict()
    assert d["coverage"] == pytest.approx(0.8)
    json.dumps(d)


def test_phase_report_empty_window():
    rep = PhaseReport.from_events([], t0=0.0, t1=0.0)
    assert rep.total_s == 0.0 and rep.coverage == 0.0
    assert sum(rep.phases.values()) == 0.0


def test_phases_from_registry_direct_child_subtraction():
    reg = MetricsRegistry()
    reg.histogram("span.service.step.seconds").observe(10.0)
    reg.histogram("span.service.step/model.decode_step.seconds").observe(6.0)
    reg.histogram(
        "span.service.step/model.decode_step/host.pack.seconds").observe(1.0)
    ph = phases_from_registry(reg)
    assert ph["scheduler"] == pytest.approx(4.0)    # 10 - direct child 6
    assert ph["model"] == pytest.approx(5.0)        # 6 - direct child 1
    assert ph["host"] == pytest.approx(1.0)


# ------------------------------------- traced service run (end to end)
def _traced_roundtrip(tmp_path, n=300, chunk=16):
    toks = np.random.default_rng(21).integers(0, 63, n).astype(np.int32)
    out_path = tmp_path / "svc.trace.json"
    svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=chunk,
                             topk=8, trace=str(out_path))
    try:
        ch = svc.submit_compress(toks)
        blob, _ = ch.result()
        dh = svc.submit_decompress(blob)
        assert np.array_equal(dh.result(), toks)
        # reports and diagnostics must be taken while the recorder is
        # attached — close() detaches it (the CLI does the same dance)
        reports = [h.phase_report() for h in (ch, dh)]
        diags = [h.diagnostics for h in (ch, dh)]
    finally:
        svc.close()
    return blob, out_path, reports, diags


def test_service_trace_export_and_phase_report(tmp_path):
    blob, out_path, reports, diags = _traced_roundtrip(tmp_path)
    # close() wrote the Chrome-trace file to the trace= path
    doc = json.loads(out_path.read_text())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) > 10
    cats = {e["cat"] for e in xs}
    assert {"scheduler", "model", "coder"} <= cats
    # per-job attribution: phases sum to job wall within 5%, and spans
    # cover >=90% of the wall (the ISSUE acceptance bar)
    for rep, diag in zip(reports, diags):
        assert rep.total_s > 0
        assert sum(rep.phases.values()) == pytest.approx(
            rep.total_s, rel=0.05)
        assert rep.coverage >= 0.90, \
            f"coverage {rep.coverage:.3f} < 0.90 ({rep.phases})"
        assert rep.phases.get("model", 0.0) > 0
        # diagnostics sidecar carries the same breakdown
        assert diag.phases is not None
        assert diag.wall_s > 0
    # recorder uninstalled by close(): later spans don't leak in
    assert obs.timeline.active() is None


def test_trace_keeps_bytes_identical(tmp_path):
    """Recording a timeline must never change container bytes."""
    toks = np.random.default_rng(21).integers(0, 63, 200).astype(np.int32)
    svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=16,
                             topk=8)
    plain, _ = svc.submit_compress(toks).result()
    traced, *_ = _traced_roundtrip(tmp_path, n=200)
    assert traced == plain


def test_snapshot_quantiles_and_phases():
    toks = np.random.default_rng(23).integers(0, 63, 150).astype(np.int32)
    svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=16,
                             topk=8)
    blob, _ = svc.submit_compress(toks).result()
    assert np.array_equal(svc.submit_decompress(blob).result(), toks)
    snap = svc.snapshot()
    bpt = snap["chunk_bits_per_token"]
    for k in ("p50", "p95", "p99"):
        assert k in bpt and bpt[k] >= 0
    assert bpt["p50"] <= bpt["p95"] <= bpt["p99"]
    # span-derived phase breakdown rides the snapshot (cheap signal)
    assert "phases" in snap
    assert all(v >= 0 for v in snap["phases"].values())
    json.dumps(snap, default=str)


# ------------------------------------ Prometheus exposition conformance
def _parse_prometheus(text):
    """Minimal exposition-format parser: {metric: {labels_str: value}},
    plus declared TYPEs. Raises on lines that don't parse."""
    samples, types = {}, {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, typ = line.split()
            types[name] = typ
            continue
        if line.startswith("#"):
            continue
        name_part, _, val = line.rpartition(" ")
        assert name_part, f"unparseable sample line: {line!r}"
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            labels = rest.rstrip("}")
        else:
            name, labels = name_part, ""
        float(val)      # every sample value must be a number
        samples.setdefault(name, {})[labels] = float(val)
    return samples, types


def test_prometheus_exposition_conformance():
    reg = MetricsRegistry(name="t")
    reg.counter("jobs.total", "jobs").inc(3)
    reg.gauge("queue.depth").set(2)
    h = reg.histogram("step.seconds", "step wall")
    for v in (0.001, 0.002, 0.004, 0.1, 1.5, 30.0):
        h.observe(v)
    samples, types = _parse_prometheus(reg.to_prometheus())
    assert types["repro_jobs_total"] == "counter"
    assert types["repro_queue_depth"] == "gauge"
    assert types["repro_step_seconds"] == "histogram"
    # histogram series: buckets cumulative + monotone, +Inf == _count,
    # _sum present and consistent
    buckets = samples["repro_step_seconds_bucket"]
    assert '+Inf' in str(buckets)
    pairs = []
    for labels, v in buckets.items():
        le = labels.split('le="')[1].rstrip('"')
        pairs.append((float("inf") if le == "+Inf" else float(le), v))
    pairs.sort()
    counts = [v for _, v in pairs]
    assert counts == sorted(counts), "buckets must be cumulative"
    assert pairs[-1][0] == float("inf")
    assert pairs[-1][1] == samples["repro_step_seconds_count"][""] == 6
    assert samples["repro_step_seconds_sum"][""] == pytest.approx(31.607)
    # quantile companion gauges for scrapers without histogram_quantile()
    for q in ("p50", "p95", "p99"):
        assert samples[f"repro_step_seconds_{q}"][""] >= 0
    assert samples["repro_step_seconds_p50"][""] \
        <= samples["repro_step_seconds_p99"][""]


# -------------------------------------------------------- bench history
def _record(bench, us, derived="", quick=True, **kw):
    return BenchRecord.build(bench, us, derived, quick=quick, commit="test",
                             ts="2026-08-08T00:00:00+00:00", **kw)


def test_bench_history_append_and_validate(tmp_path):
    hist = BenchHistory(tmp_path / "history.jsonl")
    reg = MetricsRegistry()
    reg.counter("n.total").inc(7)
    reg.histogram("span.service.step.seconds").observe(0.5)
    hist.append(_record("svc", 100.0, "jobs_s=81.0;speedup=5.02x",
                        registry=reg))
    hist.append(_record("svc", 105.0, "jobs_s=80.0"))
    # two appends -> two schema-valid rows (the acceptance criterion)
    rows = [json.loads(ln) for ln in
            hist.path.read_text().splitlines()]
    assert len(rows) == 2
    assert all(validate_record(r) == [] for r in rows)
    assert rows[0]["values"] == {"jobs_s": 81.0, "speedup": 5.02}
    assert rows[0]["metrics"]["n.total"] == 7
    assert "bucket" not in json.dumps(rows[0]["metrics"])  # compacted
    assert rows[0]["phases"]["scheduler"] == pytest.approx(0.5)
    assert hist.latest("svc")["us_per_call"] == 105.0
    assert [r["us_per_call"] for r in hist.trailing("svc")] == [100.0]


def test_bench_history_skips_corrupt_lines(tmp_path):
    hist = BenchHistory(tmp_path / "history.jsonl")
    hist.append(_record("b", 10.0))
    with open(hist.path, "a") as f:
        f.write("{truncated mid-wr\n")
        f.write('{"schema": 1, "bench": "b"}\n')      # missing fields
    hist.append(_record("b", 11.0))
    assert [r["us_per_call"] for r in hist.load("b")] == [10.0, 11.0]
    assert hist.benches() == ["b"]


def test_parse_derived_forms():
    assert parse_derived("a=1;b=2.5x; c = 3 ;skip;d=oops") == \
        {"a": 1.0, "b": 2.5, "c": 3.0}
    assert parse_derived("") == {}


def test_validate_record_rejects_malformed():
    good = _record("b", 1.0).to_dict()
    assert validate_record(good) == []
    assert validate_record("nope") != []
    bad = dict(good)
    del bad["us_per_call"]
    assert any("us_per_call" in p for p in validate_record(bad))
    bad = dict(good, values={"r": "high"})
    assert any("not numeric" in p for p in validate_record(bad))
    bad = dict(good, schema=99)
    assert any("newer" in p for p in validate_record(bad))


# ------------------------------------------------- regression gate (CI)
def test_bench_regress_fails_on_wall_regression(tmp_path):
    regress = _load_tool("bench_regress")
    hist = BenchHistory(tmp_path / "history.jsonl")
    for _ in range(5):
        hist.append(_record("svc", 100.0, "ratio=4.0"))
    hist.append(_record("svc", 120.0, "ratio=4.0"))   # +20% wall
    problems = regress.run_gate(hist.path, log=lambda *a, **k: None)
    assert len(problems) == 1 and "wall" in problems[0]
    # the CLI entrypoint exits nonzero on it
    assert regress.main(["--history", str(hist.path)]) == 1


def test_bench_regress_fails_on_ratio_regression(tmp_path):
    regress = _load_tool("bench_regress")
    hist = BenchHistory(tmp_path / "history.jsonl")
    for _ in range(3):
        hist.append(_record("router", 50.0, "bpt_improvement=0.30"))
    hist.append(_record("router", 50.0, "bpt_improvement=0.20"))
    problems = regress.run_gate(hist.path, log=lambda *a, **k: None)
    assert len(problems) == 1 and "bpt_improvement" in problems[0]
    # speedups are wall-derived noise: they ride the 15% wall rule,
    # not the 1% ratio rule
    assert not regress.is_ratio_key("speedup")
    assert regress.is_ratio_key("compression_ratio")


def test_bench_regress_passes_within_budget_and_vacuously(tmp_path):
    regress = _load_tool("bench_regress")
    # missing file: empty trajectory passes
    assert regress.run_gate(tmp_path / "none.jsonl",
                            log=lambda *a, **k: None) == []
    hist = BenchHistory(tmp_path / "history.jsonl")
    hist.append(_record("b", 100.0))            # single record: vacuous
    assert regress.run_gate(hist.path, log=lambda *a, **k: None) == []
    hist.append(_record("b", 110.0))            # +10% < 15% budget
    assert regress.run_gate(hist.path, log=lambda *a, **k: None) == []
    assert regress.main(["--history", str(hist.path)]) == 0


def test_bench_regress_separates_quick_and_full(tmp_path):
    """Quick and full runs are different workloads — a full run 10x the
    quick wall must not read as a regression."""
    regress = _load_tool("bench_regress")
    hist = BenchHistory(tmp_path / "history.jsonl")
    for _ in range(3):
        hist.append(_record("b", 100.0, quick=True))
    hist.append(_record("b", 1000.0, quick=False))
    assert regress.run_gate(hist.path, log=lambda *a, **k: None) == []


# ----------------------------------------------------- roofline attainment
def test_roofline_t_star_and_attainment():
    from repro.launch.hlo_analysis import Roofline
    r = Roofline(hlo_flops=1e12, hlo_bytes=1e9, collective_bytes=0.0,
                 n_chips=1)
    assert r.t_star == pytest.approx(
        max(r.t_compute, r.t_memory, r.t_collective))
    assert r.attainment(r.t_star * 2) == pytest.approx(0.5)
    # missing/invalid measurements read as 0.0 ("no attainment shown"),
    # never a crash
    assert r.attainment(None) == 0.0
    assert r.attainment(0.0) == 0.0
    assert r.to_dict()["t_star_s"] == pytest.approx(r.t_star)


def test_attainment_rows_from_stored_cells():
    spec = importlib.util.spec_from_file_location(
        "roofline_bench", REPO / "benchmarks" / "roofline.py")
    roofline = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(roofline)
    arch, shape = roofline.ARCH_ORDER[0], roofline.SHAPE_ORDER[0]
    # pre-§13 cell: no t_star_s recorded -> derived from the three terms
    cells = {(arch, shape): {"roofline": {
        "t_compute_s": 0.004, "t_memory_s": 0.002, "t_collective_s": 0.001,
        "bottleneck": "compute"}}}
    rows = roofline.attainment_rows(cells, {f"{arch}/{shape}": 0.008})
    assert len(rows) == 1
    a, s, t_star, measured, att, bn = rows[0]
    assert (a, s, bn) == (arch, shape, "compute")
    assert t_star == pytest.approx(0.004)
    assert att == pytest.approx(0.5)
    # cells without a measurement are skipped, not zero-attainment
    assert roofline.attainment_rows(cells, {}) == []
    table = roofline.attainment_table(cells, {f"{arch}/{shape}": 0.008})
    assert "attainment" in table and "0.500" in table
