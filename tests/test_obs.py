"""Telemetry layer (DESIGN.md §10): registry, spans, logs, diagnostics,
and the load-bearing guarantee — telemetry NEVER changes output bytes.
"""
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from _hypo import given, settings, st
from helpers import GoldenPredictor
from repro import obs
from repro.core import LLMCompressor
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.service import CompressionService
from repro.service.scheduler import SchedulerStats

REPO = pathlib.Path(__file__).resolve().parents[1]


# ------------------------------------------------------------- registry
def test_counter_gauge_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("x.count", "help text")
    c.inc()
    c.inc(4)
    assert c.value == 5 and reg.value("x.count") == 5
    g = reg.gauge("x.level")
    g.set(2.5)
    assert reg.get("x.level").value == 2.5
    assert reg.value("missing", default=-1) == -1
    # same name + same type -> same instrument; wrong type -> TypeError
    assert reg.counter("x.count") is c
    with pytest.raises(TypeError):
        reg.gauge("x.count")


def test_histogram_buckets_quantiles():
    h = Histogram("h")
    for v in (0.0, 0.75, 1.5, 3.0, 3.9, 100.0):
        h.observe(v)
    assert h.count == 6
    assert h.sum == pytest.approx(109.15)
    # v in (2**(e-1), 2**e]: 0.75 -> le 1, 1.5 -> le 2, 3.0/3.9 -> le 4
    assert h.nonzero_buckets() == {0.0: 1, 1.0: 1, 2.0: 1, 4.0: 2, 128.0: 1}
    assert h.quantile(0.5) == 2.0
    assert h.quantile(1.0) == 128.0
    assert h.mean == pytest.approx(109.15 / 6)


def test_snapshot_and_prometheus():
    reg = MetricsRegistry(name="t")
    reg.counter("a.total", "things").inc(3)
    reg.histogram("b.seconds").observe(0.5)
    snap = reg.snapshot()
    assert snap["a.total"] == {"type": "counter", "value": 3}
    assert snap["b.seconds"]["count"] == 1
    json.loads(reg.to_json())            # JSON-serializable end to end
    prom = reg.to_prometheus()
    assert "# TYPE repro_a_total counter" in prom
    assert "repro_a_total 3" in prom
    assert 'repro_b_seconds_bucket{le="+Inf"} 1' in prom
    assert "repro_b_seconds_count 1" in prom


# ---------------------------------------------------------------- spans
def test_span_nesting_records_path_histogram():
    reg = MetricsRegistry()
    with obs.span("outer", reg):
        assert obs.trace.current() == "outer"
        with obs.span("inner", reg):
            assert obs.trace.current() == "outer/inner"
    assert obs.trace.current() == ""
    assert reg.get("span.outer.seconds").count == 1
    assert reg.get("span.outer/inner.seconds").count == 1


def test_span_disabled_is_noop():
    reg = MetricsRegistry(enabled=False)
    sp = obs.span("quiet", reg)
    assert sp is obs.trace.NULL
    with sp:
        pass
    assert reg.get("span.quiet.seconds") is None


# ----------------------------------------------------------------- logs
def test_log_error_increments_counters(capsys):
    prev = obs.set_registry(MetricsRegistry())
    try:
        obs.log_error("unit.test_event", detail="x y")
        reg = obs.registry()
        assert reg.value("errors.total") == 1
        assert reg.value("errors.unit.test_event") == 1
    finally:
        obs.set_registry(prev)
    assert obs.format_event("e", {"a": 1, "b": "x y"}) == "e a=1 b='x y'"


def test_exception_record_structure():
    try:
        raise ValueError("boom")
    except ValueError as e:
        rec = obs.exception_record(e)
    assert rec["type"] == "ValueError" and rec["message"] == "boom"
    assert rec["traceback"][-1]["func"] == "test_exception_record_structure"
    json.dumps(rec)


# -------------------------------------------------- SchedulerStats view
def test_scheduler_stats_attribute_compat():
    s = SchedulerStats()
    assert s.occupancy == 0.0            # no steps -> no division
    s.model_steps += 3
    s.lane_steps += 12
    s.token_steps += 9
    assert (s.model_steps, s.steps) == (3, 3)
    assert s.occupancy == pytest.approx(0.75)
    # the attributes ARE registry counters
    assert s.registry.value("scheduler.model_steps") == 3
    assert s.snapshot()["occupancy"] == pytest.approx(0.75)
    # standalone instances are isolated
    assert SchedulerStats().model_steps == 0


# --------------------------------------------------- service stats surface
def _roundtrip_service(toks, enabled=True, topk=8, slots=4, chunk=16):
    pred = GoldenPredictor()
    svc = CompressionService(pred, slots=slots, chunk_size=chunk, topk=topk)
    svc.registry.enabled = enabled
    ch = svc.submit_compress(toks)
    blob, _ = ch.result()
    dh = svc.submit_decompress(blob)
    out = dh.result()
    assert np.array_equal(out, toks)
    return svc, ch, dh, blob


def test_service_stats_dual_api():
    toks = np.random.default_rng(7).integers(0, 63, 150).astype(np.int32)
    svc, *_ = _roundtrip_service(toks)
    # attribute view (pre-PR-7 API)
    assert svc.stats.model_steps > 0
    assert 0.0 < svc.stats.occupancy <= 1.0
    # callable view: structured snapshot
    snap = svc.stats()
    assert snap == svc.snapshot()
    assert snap["jobs"] == {"submitted": 2, "failed": 0,
                            "compress": 1, "decompress": 1}
    assert snap["occupancy"] == pytest.approx(svc.stats.occupancy)
    assert snap["chunk_bits_per_token"]["count"] == 2 * 10  # 150/16 chunks
    assert snap["draft_acceptance"] is None   # no speculative decode ran
    assert snap["metrics"]["scheduler.model_steps"]["value"] \
        == svc.stats.model_steps
    json.dumps(snap, default=str)


def test_service_stats_prometheus_exposition():
    toks = np.random.default_rng(8).integers(0, 63, 40).astype(np.int32)
    svc, *_ = _roundtrip_service(toks)
    prom = svc.registry.to_prometheus()
    assert "repro_scheduler_model_steps" in prom
    assert "repro_chunk_bits_per_token_count" in prom


# -------------------------------------------------------- job diagnostics
def test_job_diagnostics_and_sidecar(tmp_path):
    n, chunk = 150, 16
    toks = np.random.default_rng(9).integers(0, 63, n).astype(np.int32)
    svc, ch, dh, blob = _roundtrip_service(toks, chunk=chunk)
    for h, kind in ((ch, "compress"), (dh, "decompress")):
        diag = h.diagnostics
        assert diag.kind == kind and diag.codec == "rans"
        assert diag.n_tokens == n
        assert len(diag.chunks) == -(-n // chunk)
        assert [c.chunk_index for c in diag.chunks] == list(range(10))
        assert sum(c.n_tokens for c in diag.chunks) == n
        assert all(c.bits_per_token > 0 for c in diag.chunks)
        # coded_bits is the quantized information content; the realized
        # stream adds only the coder state flush + byte rounding
        for c in diag.chunks:
            assert 0 < c.coded_bits <= 8 * c.stream_bytes
        assert diag.draft_acceptance is None
    assert ch.diagnostics.container_bytes == len(blob)
    # compress-side and decode-side accruals price the SAME code
    for cc, dc in zip(ch.diagnostics.chunks, dh.diagnostics.chunks):
        assert cc.coded_bits == pytest.approx(dc.coded_bits, rel=1e-9)
        assert cc.n_escapes == dc.n_escapes
    # sidecar: JSON next to the container, never inside it
    target = tmp_path / "a.llmc"
    target.write_bytes(blob)
    path = dh.write_sidecar(target)
    assert path == tmp_path / "a.llmc.diag.json"
    rec = obs.read_sidecar(target)
    assert rec["kind"] == "decompress" and rec["n_tokens"] == n
    assert len(rec["chunks"]) == 10


def test_diagnostics_empty_when_disabled():
    toks = np.random.default_rng(10).integers(0, 63, 50).astype(np.int32)
    svc, ch, dh, _ = _roundtrip_service(toks, enabled=False)
    assert ch.diagnostics.chunks == []
    assert dh.diagnostics.chunks == []
    # load-bearing counters still ran (disabled gates only extras)
    assert svc.stats.model_steps > 0
    assert svc.snapshot()["chunk_bits_per_token"] is None


def test_job_failure_counted_once():
    """A mid-flight chunk failure increments chunk_failures AND the job
    failure counter exactly once (v3: no checksums, so the corruption
    reaches the scheduler's exhaustion check instead of failing at
    submit)."""
    from repro.core import ContainerError
    pred = GoldenPredictor()
    comp = LLMCompressor(pred, chunk_size=16, topk=8, decode_batch=4,
                         container_version=3)
    toks = np.random.default_rng(11).integers(0, 63, 64).astype(np.int32)
    blob, _ = comp.compress(toks)
    bad = bytearray(blob)
    bad[len(bad) // 2] ^= 0x10              # flip inside a coded stream
    svc = CompressionService(pred, slots=4, chunk_size=16, topk=8)
    with pytest.raises(ContainerError):
        svc.submit_decompress(bytes(bad)).result()
    assert svc.stats.chunk_failures >= 1
    assert svc.registry.value("service.jobs_failed") == 1
    assert svc.snapshot()["jobs"]["failed"] == 1
    # errors are also countable in the process-global registry
    assert obs.registry().value("errors.scheduler.chunk_failed") >= 1


# --------------------------------------- byte-identity: the hard invariant
def _compress_blob(pred, toks, enabled, *, topk, codec, draft_k=0):
    reg = MetricsRegistry(enabled=enabled)
    comp = LLMCompressor(pred, chunk_size=16, topk=topk, decode_batch=4,
                         codec=codec, draft_k=draft_k, registry=reg)
    blob, _ = comp.compress(toks)
    out = comp.decompress(blob)
    assert np.array_equal(out, toks), "LOSSLESS VIOLATION"
    return blob


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 120))
def test_byte_identity_enabled_vs_disabled(seed, n):
    """Property: the container bytes are identical with telemetry on and
    off, across codecs, top-k modes, and the speculative decode path."""
    pred = GoldenPredictor()
    toks = np.random.default_rng(seed).integers(0, 63, n).astype(np.int32)
    for codec, topk, draft_k in (("rans", 0, 0), ("rans", 8, 0),
                                 ("rans", 8, 4), ("ac", 8, 0)):
        on = _compress_blob(pred, toks, True, topk=topk, codec=codec,
                            draft_k=draft_k)
        off = _compress_blob(pred, toks, False, topk=topk, codec=codec,
                             draft_k=draft_k)
        assert on == off, f"telemetry changed bytes ({codec}, k={topk})"


def test_byte_identity_service_paths():
    toks = np.random.default_rng(13).integers(0, 63, 300).astype(np.int32)
    _, _, _, blob_on = _roundtrip_service(toks, enabled=True)
    _, _, _, blob_off = _roundtrip_service(toks, enabled=False)
    assert blob_on == blob_off
    # and the service container matches the grouped compressor's
    ref = LLMCompressor(GoldenPredictor(), chunk_size=16, topk=8,
                        decode_batch=4, container_version=4)
    assert blob_on == ref.compress(toks)[0]


def test_speculative_diagnostics_counters():
    """Speculative decode records rounds / acceptance / rollbacks."""
    pred = GoldenPredictor()
    # argmax-following stream: the suffix draft gets real acceptance
    argmax = pred._table.argmax(axis=-1)
    toks = np.zeros(256, np.int32)
    prev = pred.bos_id
    for i in range(256):
        prev = toks[i] = argmax[prev]
    reg = MetricsRegistry()
    comp = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4,
                         draft_k=4, registry=reg)
    blob, _ = comp.compress(toks)
    out = comp.decompress(blob)
    assert np.array_equal(out, toks)
    assert reg.value("spec.rounds") > 0
    assert reg.value("spec.drafted_tokens") > 0
    assert 0 <= reg.value("spec.drafted_accepted") \
        <= reg.value("spec.drafted_tokens")
    h = reg.get("spec.accept_depth")
    assert h is not None and h.count > 0


# ------------------------------------------------------------- repo lint
def _load_lint():
    spec = importlib.util.spec_from_file_location(
        "lint_no_print", REPO / "tools" / "lint_no_print.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_lint_tool_flags_calls_not_strings(tmp_path):
    lint = _load_lint()
    (tmp_path / "bad.py").write_text(
        's = "print(this is a string literal)"\n'
        "obj.print()\n"                      # method, not the builtin
        "print('flagged')\n")
    (tmp_path / "cli.py").write_text("print('allowed')\n")
    problems = lint.lint(tmp_path)
    assert len(problems) == 1 and "bad.py:3" in problems[0]


def test_repo_tree_passes_lint():
    lint = _load_lint()
    assert lint.lint(REPO / "src" / "repro") == []
