"""Corrupt/truncated/bit-flipped container fuzzing + v4 random access.

Guarantees under test (ISSUE 2 satellites):

* every strict prefix of a container raises ``ContainerError`` — never a
  bare IndexError/struct.error from running off the end of the blob;
* a v4 container detects **every** single-bit flip: the footer checksum
  covers header + index, each chunk stream carries its own xxh64, and
  the trailer is structurally validated — so any flip anywhere raises
  ContainerError before the entropy coder sees garbage;
* v2/v3 header corruption is caught by field validation (codec id,
  precision bounds, config match) or decodes to the original bytes when
  it hits dead bits — silent *wrong* output from header damage is the
  failure mode being excluded;
* v4 range decode of any chunk interval equals the corresponding slice
  of a full decompress, touching only that interval's bytes;
* a container whose header claims rANS at a precision above the coder
  limit is rejected at parse (the *container*, not the compressor
  object, selects the codec — satellite fix).
"""
import pathlib
import struct

import numpy as np
import pytest

from helpers import GoldenPredictor, golden_tokens
from repro.core import ContainerError, LLMCompressor, read_index
from repro.core.compressor import MAGIC, _V3_HEADER, CODEC_RANS

GOLDEN = pathlib.Path(__file__).parent / "golden"


def _comp(**kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=16, decode_batch=4,
                         **kw)


@pytest.fixture(scope="module")
def v4_case():
    comp = _comp(topk=8, container_version=4)
    toks = golden_tokens(100)
    blob, _ = comp.compress(toks)
    return comp, toks, blob


# ------------------------------------------------------------- truncation
@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc",
                                  "v3_ac_topk.llmc"])
def test_every_truncation_raises_container_error(name):
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_every_v4_truncation_raises_container_error(v4_case):
    comp, _, blob = v4_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


# --------------------------------------------------------------- bit flips
def test_v4_detects_every_single_bit_flip(v4_case):
    """Exhaustive: flip each bit of the container; decompress must raise
    ContainerError every time (header+index covered by the footer hash,
    streams by per-chunk hashes, trailer by structural checks)."""
    comp, _, blob = v4_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc"])
def test_header_bit_flips_never_crash(name):
    """v2/v3 have no checksums, so a handful of header flips (e.g. the
    low bits of n_tokens) decode silently wrong — the limitation that
    motivates v4, where the footer hash covers the header and the
    exhaustive-flip test above proves detection. What v2/v3 must still
    guarantee: every header flip either raises ContainerError or decodes
    *something* — never an uncontrolled IndexError/struct.error."""
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    hsize = 4 + struct.calcsize(_V3_HEADER)
    for i in range(min(hsize, len(blob))):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            try:
                comp.decompress(bytes(bad))
            except ContainerError:
                continue


def test_varint_bomb_rejected():
    """A length varint that never terminates (or overflows 64 bits) must
    raise ContainerError, not hang or IndexError."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 16, 1)
    with pytest.raises(ContainerError):
        comp.decompress(hdr + b"\xff" * 64)


def test_rans_precision_validated_from_container():
    """Satellite: a container header that selects rANS at precision 24
    (> rans.MAX_PRECISION) is rejected at parse even though the decoder
    object was built with a legal precision."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24,
                              CODEC_RANS)
    with pytest.raises(ContainerError, match="rANS"):
        comp.decompress(hdr + b"\x00" * 32)
    # the same precision under the AC codec is structurally legal and
    # must fail only on the config match, not the rANS limit
    hdr_ac = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24, 0)
    with pytest.raises(ContainerError, match="mismatch"):
        comp.decompress(hdr_ac + b"\x00" * 32)


def test_unknown_version_and_codec_rejected():
    comp = _comp(topk=8)
    blob, _ = _comp(topk=8).compress(golden_tokens(20))
    bad = bytearray(blob)
    bad[4] = 9
    with pytest.raises(ContainerError, match="version"):
        comp.decompress(bytes(bad))
    bad = bytearray(blob)
    bad[19] = 7
    with pytest.raises(ContainerError, match="codec"):
        comp.decompress(bytes(bad))


# ------------------------------------------------------------ random access
def test_v4_range_decode_matches_full_decode(v4_case):
    comp, toks, blob = v4_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_range_decode_detects_chunk_corruption(v4_case):
    comp, _, blob = v4_case
    info = read_index(blob)
    e = info.entries[2]
    bad = bytearray(blob)
    bad[e.offset] ^= 0x01                  # corrupt only chunk 2's stream
    with pytest.raises(ContainerError, match="chunk 2"):
        comp.decompress_range(bytes(bad), 2, 3)
    # other chunks remain independently readable
    assert np.array_equal(comp.decompress_range(bytes(bad), 0, 2),
                          comp.decompress_range(blob, 0, 2))


def test_range_decode_requires_v4_and_bounds():
    comp = _comp(topk=8)
    v3, _ = comp.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="v4"):
        comp.decompress_range(v3, 0, 1)
    comp4 = _comp(topk=8, container_version=4)
    v4, _ = comp4.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="out of bounds"):
        comp4.decompress_range(v4, 0, 99)
    with pytest.raises(ContainerError, match="empty"):
        comp4.decompress_range(v4, 1, 1)
    with pytest.raises(ContainerError, match="reversed"):
        comp4.decompress_range(v4, 3, 1)


def test_empty_and_garbage_blobs():
    comp = _comp(topk=8)
    for blob in (b"", b"LL", b"XXXX" + b"\x00" * 40, MAGIC):
        with pytest.raises(ContainerError):
            comp.decompress(blob)
