"""Corrupt/truncated/bit-flipped container fuzzing + v4 random access.

Guarantees under test (ISSUE 2 satellites; extended to the v5
mixed-codec container for ISSUE 8):

* every strict prefix of a container raises ``ContainerError`` — never a
  bare IndexError/struct.error from running off the end of the blob;
* a v4 container detects **every** single-bit flip: the footer checksum
  covers header + index, each chunk stream carries its own xxh64, and
  the trailer is structurally validated — so any flip anywhere raises
  ContainerError before the entropy coder sees garbage;
* v2/v3 header corruption is caught by field validation (codec id,
  precision bounds, config match) or decodes to the original bytes when
  it hits dead bits — silent *wrong* output from header damage is the
  failure mode being excluded;
* v4 range decode of any chunk interval equals the corresponding slice
  of a full decompress, touching only that interval's bytes;
* a container whose header claims rANS at a precision above the coder
  limit is rejected at parse (the *container*, not the compressor
  object, selects the codec — satellite fix);
* a routed **v5** container detects every single-bit flip too — the
  footer hash additionally covers the per-chunk codec tags, and the
  per-chunk xxh64 covers fallback streams exactly like entropy streams;
* v5 semantic validation holds even when an attacker *recomputes* the
  checksums after tampering: unknown/mismatched codec tags and
  structurally broken fallback streams raise ContainerError, never a
  silent wrong decode.
"""
import pathlib
import struct

import numpy as np
import pytest

from helpers import GoldenPredictor, golden_self_tokens, golden_tokens
from repro.core import (ContainerError, LLMCompressor, RouterConfig,
                        read_header, read_index)
from repro.core.checksum import xxh64
from repro.core.compressor import (MAGIC, _V3_HEADER, _V4_TRAILER, _V5_ENTRY,
                                   _V5_ENTRY_SIZE, _V5_END_MAGIC, CODEC_RANS)

GOLDEN = pathlib.Path(__file__).parent / "golden"


def _comp(**kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=16, decode_batch=4,
                         **kw)


@pytest.fixture(scope="module")
def v4_case():
    comp = _comp(topk=8, container_version=4)
    toks = golden_tokens(100)
    blob, _ = comp.compress(toks)
    return comp, toks, blob


# ------------------------------------------------------------- truncation
@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc",
                                  "v3_ac_topk.llmc"])
def test_every_truncation_raises_container_error(name):
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_every_v4_truncation_raises_container_error(v4_case):
    comp, _, blob = v4_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


# --------------------------------------------------------------- bit flips
def test_v4_detects_every_single_bit_flip(v4_case):
    """Exhaustive: flip each bit of the container; decompress must raise
    ContainerError every time (header+index covered by the footer hash,
    streams by per-chunk hashes, trailer by structural checks)."""
    comp, _, blob = v4_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc"])
def test_header_bit_flips_never_crash(name):
    """v2/v3 have no checksums, so a handful of header flips (e.g. the
    low bits of n_tokens) decode silently wrong — the limitation that
    motivates v4, where the footer hash covers the header and the
    exhaustive-flip test above proves detection. What v2/v3 must still
    guarantee: every header flip either raises ContainerError or decodes
    *something* — never an uncontrolled IndexError/struct.error."""
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    hsize = 4 + struct.calcsize(_V3_HEADER)
    for i in range(min(hsize, len(blob))):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            try:
                comp.decompress(bytes(bad))
            except ContainerError:
                continue


def test_varint_bomb_rejected():
    """A length varint that never terminates (or overflows 64 bits) must
    raise ContainerError, not hang or IndexError."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 16, 1)
    with pytest.raises(ContainerError):
        comp.decompress(hdr + b"\xff" * 64)


def test_rans_precision_validated_from_container():
    """Satellite: a container header that selects rANS at precision 24
    (> rans.MAX_PRECISION) is rejected at parse even though the decoder
    object was built with a legal precision."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24,
                              CODEC_RANS)
    with pytest.raises(ContainerError, match="rANS"):
        comp.decompress(hdr + b"\x00" * 32)
    # the same precision under the AC codec is structurally legal and
    # must fail only on the config match, not the rANS limit
    hdr_ac = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24, 0)
    with pytest.raises(ContainerError, match="mismatch"):
        comp.decompress(hdr_ac + b"\x00" * 32)


def test_unknown_version_and_codec_rejected():
    comp = _comp(topk=8)
    blob, _ = _comp(topk=8).compress(golden_tokens(20))
    bad = bytearray(blob)
    bad[4] = 9
    with pytest.raises(ContainerError, match="version"):
        comp.decompress(bytes(bad))
    bad = bytearray(blob)
    bad[19] = 7
    with pytest.raises(ContainerError, match="codec"):
        comp.decompress(bytes(bad))


# ------------------------------------------------------------ random access
def test_v4_range_decode_matches_full_decode(v4_case):
    comp, toks, blob = v4_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_range_decode_detects_chunk_corruption(v4_case):
    comp, _, blob = v4_case
    info = read_index(blob)
    e = info.entries[2]
    bad = bytearray(blob)
    bad[e.offset] ^= 0x01                  # corrupt only chunk 2's stream
    with pytest.raises(ContainerError, match="chunk 2"):
        comp.decompress_range(bytes(bad), 2, 3)
    # other chunks remain independently readable
    assert np.array_equal(comp.decompress_range(bytes(bad), 0, 2),
                          comp.decompress_range(blob, 0, 2))


def test_range_decode_requires_v4_and_bounds():
    comp = _comp(topk=8)
    v3, _ = comp.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="v4"):
        comp.decompress_range(v3, 0, 1)
    comp4 = _comp(topk=8, container_version=4)
    v4, _ = comp4.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="out of bounds"):
        comp4.decompress_range(v4, 0, 99)
    with pytest.raises(ContainerError, match="empty"):
        comp4.decompress_range(v4, 1, 1)
    with pytest.raises(ContainerError, match="reversed"):
        comp4.decompress_range(v4, 3, 1)


def test_empty_and_garbage_blobs():
    comp = _comp(topk=8)
    for blob in (b"", b"LL", b"XXXX" + b"\x00" * 40, MAGIC):
        with pytest.raises(ContainerError):
            comp.decompress(blob)


# ----------------------------------------------------- v5 mixed containers
@pytest.fixture(scope="module")
def v5_case():
    """A routed v5 container whose index genuinely mixes entropy-coded
    and fallback chunks — the fuzz below must exercise both stream
    kinds and the codec-tag bytes."""
    comp = _comp(topk=8, container_version=5, route="auto",
                 router=RouterConfig(fallbacks=("raw", "lzma")))
    toks = np.concatenate([golden_self_tokens(32, seed=3),
                           golden_tokens(32, seed=4),
                           golden_self_tokens(16, seed=5),
                           golden_tokens(21, seed=6)])
    blob, _ = comp.compress(toks)
    tags = {e.codec_name for e in read_index(blob).entries}
    assert "rans" in tags and tags != {"rans"}
    return comp, toks, blob


def test_every_v5_truncation_raises_container_error(v5_case):
    comp, _, blob = v5_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_v5_detects_every_single_bit_flip(v5_case):
    """Exhaustive: flip each bit of the mixed container; decompress must
    raise ContainerError every time. Flips in the codec-tag bytes are
    caught by the footer hash (the tags live inside the hashed index),
    flips in fallback streams by the per-chunk xxh64 — same coverage as
    the entropy chunks."""
    comp, _, blob = v5_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


def _v5_tamper(blob, chunk, tag=None, stream=None):
    """Rewrite chunk ``chunk``'s codec tag and/or stream bytes in a v5
    container and RECOMPUTE every checksum (per-chunk xxh64 + footer
    hash), so the corruption-detection layer passes and only the
    semantic validation behind it stands between the tamper and a
    silent wrong decode. Same-length stream patches only (the body's
    varint framing stays valid)."""
    assert blob[-4:] == _V5_END_MAGIC
    info = read_header(blob)
    n, footer_len = struct.unpack("<II", blob[-12:-4])
    footer_start = len(blob) - _V4_TRAILER - footer_len
    entries = [list(struct.unpack_from(_V5_ENTRY, blob,
                                       footer_start + i * _V5_ENTRY_SIZE))
               for i in range(n)]
    body = bytearray(blob[:footer_start])
    if stream is not None:
        off, ln = entries[chunk][0], entries[chunk][1]
        assert len(stream) == ln
        body[off:off + ln] = stream
        entries[chunk][4] = xxh64(bytes(stream))
    if tag is not None:
        entries[chunk][3] = tag
    ents = b"".join(struct.pack(_V5_ENTRY, *e) for e in entries)
    eb_off = footer_start + n * _V5_ENTRY_SIZE
    tail = ents + blob[eb_off:eb_off + 4]           # + u32 encode_batch
    return (bytes(body) + tail
            + struct.pack("<Q", xxh64(blob[:info.header_size] + tail))
            + struct.pack("<II", n, len(tail) + 8) + _V5_END_MAGIC)


def test_v5_semantic_validation_behind_checksums(v5_case):
    """Checksum-fixing tampers still fail loudly: the index validation
    and fallback-stream structure checks are real, not artifacts of the
    hash coverage."""
    comp, _, blob = v5_case
    info = read_index(blob)
    # sanity: an untampered rewrite round-trips bit-exactly
    assert _v5_tamper(blob, 0) == blob
    # unknown codec id in a tag
    with pytest.raises(ContainerError, match="unknown codec id"):
        comp.decompress(_v5_tamper(blob, 0, tag=9))
    # entropy-codec tag that contradicts the header codec (rans=1, ac=0)
    with pytest.raises(ContainerError, match="entropy codec"):
        comp.decompress(_v5_tamper(blob, 0, tag=0))
    fb = next(i for i, e in enumerate(info.entries) if not e.is_llm)
    s = bytearray(blob[info.entries[fb].offset:
                       info.entries[fb].offset + info.entries[fb].length])
    # illegal token width in the fallback stream's framing byte
    bad_width = bytes([3]) + bytes(s[1:])
    with pytest.raises(ContainerError, match="width"):
        comp.decompress(_v5_tamper(blob, fb, stream=bad_width))
    # width that disagrees with the payload length
    wrong_width = bytes([2 if s[0] == 1 else 1]) + bytes(s[1:])
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress(_v5_tamper(blob, fb, stream=wrong_width))
    # retagging a fallback chunk as a different fallback codec: the
    # stream no longer parses under that codec — error, never garbage
    other = 3 if info.entries[fb].codec == 4 else 4
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress(_v5_tamper(blob, fb, tag=other))


def test_v5_range_decode_matches_full_decode(v5_case):
    """Random access over a mixed-codec archive: every interval equals
    the matching slice of a full decode (the v4 guarantee survives
    per-chunk codecs)."""
    comp, toks, blob = v5_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_v5_range_decode_detects_fallback_corruption(v5_case):
    """Chunk-level corruption detection localizes across codecs: damage
    to a fallback chunk's stream fails only reads that touch it."""
    comp, _, blob = v5_case
    info = read_index(blob)
    fb = next(i for i, e in enumerate(info.entries) if not e.is_llm)
    bad = bytearray(blob)
    bad[info.entries[fb].offset] ^= 0x01
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress_range(bytes(bad), fb, fb + 1)
    lo = 0 if fb else 1
    assert np.array_equal(comp.decompress_range(bytes(bad), lo, lo + 1),
                          comp.decompress_range(blob, lo, lo + 1))
