"""Corrupt/truncated/bit-flipped container fuzzing + v4 random access.

Guarantees under test (ISSUE 2 satellites; extended to the v5
mixed-codec container for ISSUE 8):

* every strict prefix of a container raises ``ContainerError`` — never a
  bare IndexError/struct.error from running off the end of the blob;
* a v4 container detects **every** single-bit flip: the footer checksum
  covers header + index, each chunk stream carries its own xxh64, and
  the trailer is structurally validated — so any flip anywhere raises
  ContainerError before the entropy coder sees garbage;
* v2/v3 header corruption is caught by field validation (codec id,
  precision bounds, config match) or decodes to the original bytes when
  it hits dead bits — silent *wrong* output from header damage is the
  failure mode being excluded;
* v4 range decode of any chunk interval equals the corresponding slice
  of a full decompress, touching only that interval's bytes;
* a container whose header claims rANS at a precision above the coder
  limit is rejected at parse (the *container*, not the compressor
  object, selects the codec — satellite fix);
* a routed **v5** container detects every single-bit flip too — the
  footer hash additionally covers the per-chunk codec tags, and the
  per-chunk xxh64 covers fallback streams exactly like entropy streams;
* v5 semantic validation holds even when an attacker *recomputes* the
  checksums after tampering: unknown/mismatched codec tags and
  structurally broken fallback streams raise ContainerError, never a
  silent wrong decode;
* a carried **v6** container (ISSUE 9) detects every single-bit flip and
  every truncation — the footer hash additionally covers the per-chunk
  recipe fields and the shared-prefix dictionary section;
* v6 recipe/dictionary validation also holds behind recomputed
  checksums: unknown recipe kinds, carry-on-chunk-0, zero carry
  windows, out-of-range shared-prefix indices, recipes on
  fallback-coded chunks, out-of-vocab dictionary tokens, and stray or
  short dictionary bytes all raise ContainerError.
"""
import pathlib
import struct

import numpy as np
import pytest

from helpers import GoldenPredictor, golden_self_tokens, golden_tokens
from repro.core import (ContainerError, LLMCompressor, RouterConfig,
                        read_header, read_index)
from repro.core.checksum import xxh64
from repro.core.compressor import (MAGIC, _V3_HEADER, _V4_TRAILER, _V5_ENTRY,
                                   _V5_ENTRY_SIZE, _V5_END_MAGIC, _V6_ENTRY,
                                   _V6_ENTRY_SIZE, _V6_END_MAGIC, CODEC_RANS,
                                   RECIPE_CARRY, RECIPE_NONE)

GOLDEN = pathlib.Path(__file__).parent / "golden"


def _comp(**kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=16, decode_batch=4,
                         **kw)


@pytest.fixture(scope="module")
def v4_case():
    comp = _comp(topk=8, container_version=4)
    toks = golden_tokens(100)
    blob, _ = comp.compress(toks)
    return comp, toks, blob


# ------------------------------------------------------------- truncation
@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc",
                                  "v3_ac_topk.llmc"])
def test_every_truncation_raises_container_error(name):
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_every_v4_truncation_raises_container_error(v4_case):
    comp, _, blob = v4_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


# --------------------------------------------------------------- bit flips
def test_v4_detects_every_single_bit_flip(v4_case):
    """Exhaustive: flip each bit of the container; decompress must raise
    ContainerError every time (header+index covered by the footer hash,
    streams by per-chunk hashes, trailer by structural checks)."""
    comp, _, blob = v4_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


@pytest.mark.parametrize("name", ["v2_topk.llmc", "v3_rans_topk.llmc"])
def test_header_bit_flips_never_crash(name):
    """v2/v3 have no checksums, so a handful of header flips (e.g. the
    low bits of n_tokens) decode silently wrong — the limitation that
    motivates v4, where the footer hash covers the header and the
    exhaustive-flip test above proves detection. What v2/v3 must still
    guarantee: every header flip either raises ContainerError or decodes
    *something* — never an uncontrolled IndexError/struct.error."""
    blob = (GOLDEN / name).read_bytes()
    comp = _comp(topk=8)
    hsize = 4 + struct.calcsize(_V3_HEADER)
    for i in range(min(hsize, len(blob))):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            try:
                comp.decompress(bytes(bad))
            except ContainerError:
                continue


def test_varint_bomb_rejected():
    """A length varint that never terminates (or overflows 64 bits) must
    raise ContainerError, not hang or IndexError."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 16, 1)
    with pytest.raises(ContainerError):
        comp.decompress(hdr + b"\xff" * 64)


def test_rans_precision_validated_from_container():
    """Satellite: a container header that selects rANS at precision 24
    (> rans.MAX_PRECISION) is rejected at parse even though the decoder
    object was built with a legal precision."""
    comp = _comp(topk=8)
    hdr = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24,
                              CODEC_RANS)
    with pytest.raises(ContainerError, match="rANS"):
        comp.decompress(hdr + b"\x00" * 32)
    # the same precision under the AC codec is structurally legal and
    # must fail only on the config match, not the rANS limit
    hdr_ac = MAGIC + struct.pack(_V3_HEADER, 3, 1, 16, 100, 64, 8, 24, 0)
    with pytest.raises(ContainerError, match="mismatch"):
        comp.decompress(hdr_ac + b"\x00" * 32)


def test_unknown_version_and_codec_rejected():
    comp = _comp(topk=8)
    blob, _ = _comp(topk=8).compress(golden_tokens(20))
    bad = bytearray(blob)
    bad[4] = 9
    with pytest.raises(ContainerError, match="version"):
        comp.decompress(bytes(bad))
    bad = bytearray(blob)
    bad[19] = 7
    with pytest.raises(ContainerError, match="codec"):
        comp.decompress(bytes(bad))


# ------------------------------------------------------------ random access
def test_v4_range_decode_matches_full_decode(v4_case):
    comp, toks, blob = v4_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_range_decode_detects_chunk_corruption(v4_case):
    comp, _, blob = v4_case
    info = read_index(blob)
    e = info.entries[2]
    bad = bytearray(blob)
    bad[e.offset] ^= 0x01                  # corrupt only chunk 2's stream
    with pytest.raises(ContainerError, match="chunk 2"):
        comp.decompress_range(bytes(bad), 2, 3)
    # other chunks remain independently readable
    assert np.array_equal(comp.decompress_range(bytes(bad), 0, 2),
                          comp.decompress_range(blob, 0, 2))


def test_range_decode_requires_v4_and_bounds():
    comp = _comp(topk=8)
    v3, _ = comp.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="v4"):
        comp.decompress_range(v3, 0, 1)
    comp4 = _comp(topk=8, container_version=4)
    v4, _ = comp4.compress(golden_tokens(50))
    with pytest.raises(ContainerError, match="out of bounds"):
        comp4.decompress_range(v4, 0, 99)
    with pytest.raises(ContainerError, match="empty"):
        comp4.decompress_range(v4, 1, 1)
    with pytest.raises(ContainerError, match="reversed"):
        comp4.decompress_range(v4, 3, 1)


def test_empty_and_garbage_blobs():
    comp = _comp(topk=8)
    for blob in (b"", b"LL", b"XXXX" + b"\x00" * 40, MAGIC):
        with pytest.raises(ContainerError):
            comp.decompress(blob)


# ----------------------------------------------------- v5 mixed containers
@pytest.fixture(scope="module")
def v5_case():
    """A routed v5 container whose index genuinely mixes entropy-coded
    and fallback chunks — the fuzz below must exercise both stream
    kinds and the codec-tag bytes."""
    comp = _comp(topk=8, container_version=5, route="auto",
                 router=RouterConfig(fallbacks=("raw", "lzma")))
    toks = np.concatenate([golden_self_tokens(32, seed=3),
                           golden_tokens(32, seed=4),
                           golden_self_tokens(16, seed=5),
                           golden_tokens(21, seed=6)])
    blob, _ = comp.compress(toks)
    tags = {e.codec_name for e in read_index(blob).entries}
    assert "rans" in tags and tags != {"rans"}
    return comp, toks, blob


def test_every_v5_truncation_raises_container_error(v5_case):
    comp, _, blob = v5_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_v5_detects_every_single_bit_flip(v5_case):
    """Exhaustive: flip each bit of the mixed container; decompress must
    raise ContainerError every time. Flips in the codec-tag bytes are
    caught by the footer hash (the tags live inside the hashed index),
    flips in fallback streams by the per-chunk xxh64 — same coverage as
    the entropy chunks."""
    comp, _, blob = v5_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


def _v5_tamper(blob, chunk, tag=None, stream=None):
    """Rewrite chunk ``chunk``'s codec tag and/or stream bytes in a v5
    container and RECOMPUTE every checksum (per-chunk xxh64 + footer
    hash), so the corruption-detection layer passes and only the
    semantic validation behind it stands between the tamper and a
    silent wrong decode. Same-length stream patches only (the body's
    varint framing stays valid)."""
    assert blob[-4:] == _V5_END_MAGIC
    info = read_header(blob)
    n, footer_len = struct.unpack("<II", blob[-12:-4])
    footer_start = len(blob) - _V4_TRAILER - footer_len
    entries = [list(struct.unpack_from(_V5_ENTRY, blob,
                                       footer_start + i * _V5_ENTRY_SIZE))
               for i in range(n)]
    body = bytearray(blob[:footer_start])
    if stream is not None:
        off, ln = entries[chunk][0], entries[chunk][1]
        assert len(stream) == ln
        body[off:off + ln] = stream
        entries[chunk][4] = xxh64(bytes(stream))
    if tag is not None:
        entries[chunk][3] = tag
    ents = b"".join(struct.pack(_V5_ENTRY, *e) for e in entries)
    eb_off = footer_start + n * _V5_ENTRY_SIZE
    tail = ents + blob[eb_off:eb_off + 4]           # + u32 encode_batch
    return (bytes(body) + tail
            + struct.pack("<Q", xxh64(blob[:info.header_size] + tail))
            + struct.pack("<II", n, len(tail) + 8) + _V5_END_MAGIC)


def test_v5_semantic_validation_behind_checksums(v5_case):
    """Checksum-fixing tampers still fail loudly: the index validation
    and fallback-stream structure checks are real, not artifacts of the
    hash coverage."""
    comp, _, blob = v5_case
    info = read_index(blob)
    # sanity: an untampered rewrite round-trips bit-exactly
    assert _v5_tamper(blob, 0) == blob
    # unknown codec id in a tag
    with pytest.raises(ContainerError, match="unknown codec id"):
        comp.decompress(_v5_tamper(blob, 0, tag=9))
    # entropy-codec tag that contradicts the header codec (rans=1, ac=0)
    with pytest.raises(ContainerError, match="entropy codec"):
        comp.decompress(_v5_tamper(blob, 0, tag=0))
    fb = next(i for i, e in enumerate(info.entries) if not e.is_llm)
    s = bytearray(blob[info.entries[fb].offset:
                       info.entries[fb].offset + info.entries[fb].length])
    # illegal token width in the fallback stream's framing byte
    bad_width = bytes([3]) + bytes(s[1:])
    with pytest.raises(ContainerError, match="width"):
        comp.decompress(_v5_tamper(blob, fb, stream=bad_width))
    # width that disagrees with the payload length
    wrong_width = bytes([2 if s[0] == 1 else 1]) + bytes(s[1:])
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress(_v5_tamper(blob, fb, stream=wrong_width))
    # retagging a fallback chunk as a different fallback codec: the
    # stream no longer parses under that codec — error, never garbage
    other = 3 if info.entries[fb].codec == 4 else 4
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress(_v5_tamper(blob, fb, tag=other))


def test_v5_range_decode_matches_full_decode(v5_case):
    """Random access over a mixed-codec archive: every interval equals
    the matching slice of a full decode (the v4 guarantee survives
    per-chunk codecs)."""
    comp, toks, blob = v5_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_v5_range_decode_detects_fallback_corruption(v5_case):
    """Chunk-level corruption detection localizes across codecs: damage
    to a fallback chunk's stream fails only reads that touch it."""
    comp, _, blob = v5_case
    info = read_index(blob)
    fb = next(i for i, e in enumerate(info.entries) if not e.is_llm)
    bad = bytearray(blob)
    bad[info.entries[fb].offset] ^= 0x01
    with pytest.raises(ContainerError, match=f"chunk {fb}"):
        comp.decompress_range(bytes(bad), fb, fb + 1)
    lo = 0 if fb else 1
    assert np.array_equal(comp.decompress_range(bytes(bad), lo, lo + 1),
                          comp.decompress_range(blob, lo, lo + 1))


# --------------------------------------------- v6 carried-context containers
@pytest.fixture(scope="module")
def v6_case():
    """A routed v6 container that exercises every recipe kind at once:
    shared-prefix heads, carry chunks, and fallback chunks whose recipes
    were zeroed by the router — plus a real dictionary section in the
    footer. The fuzz below must cover the new recipe bytes and the
    dictionary span."""
    comp = _comp(topk=8, container_version=6, route="auto",
                 router=RouterConfig(fallbacks=("raw", "lzma")),
                 context_window=6, context_stripes=2,
                 shared_prefix=golden_self_tokens(10, seed=9))
    toks = np.concatenate([golden_self_tokens(32, seed=3),
                           golden_tokens(32, seed=4),
                           golden_self_tokens(16, seed=5),
                           golden_tokens(21, seed=6)])
    blob, _ = comp.compress(toks)
    info = read_index(blob)
    tags = {e.codec_name for e in info.entries}
    kinds = {e.recipe_kind for e in info.entries}
    assert "rans" in tags and tags != {"rans"}
    assert RECIPE_CARRY in kinds and RECIPE_NONE in kinds
    assert len(info.shared_prefixes) == 1
    return comp, toks, blob


def test_every_v6_truncation_raises_container_error(v6_case):
    comp, _, blob = v6_case
    for cut in range(len(blob)):
        with pytest.raises(ContainerError):
            comp.decompress(blob[:cut])


def test_v6_detects_every_single_bit_flip(v6_case):
    """Exhaustive: flip each bit of the carried container; decompress
    must raise ContainerError every time. The recipe bytes and the
    shared-prefix dictionary live inside the footer-hash span, streams
    keep their per-chunk xxh64 — no new byte escapes coverage."""
    comp, _, blob = v6_case
    for i in range(len(blob)):
        for bit in range(8):
            bad = bytearray(blob)
            bad[i] ^= 1 << bit
            with pytest.raises(ContainerError):
                comp.decompress(bytes(bad))


def _v6_tamper(blob, chunk=None, tag=None, kind=None, param=None,
               stream=None, dict_blob=None, ctx_budget=None):
    """Rewrite a chunk's codec tag / recipe fields / stream bytes, the
    shared-prefix dictionary section, and/or the recorded context budget
    of a v6 container, RECOMPUTING every checksum, so only the semantic
    validation stands between the tamper and a silent wrong decode."""
    assert blob[-4:] == _V6_END_MAGIC
    info = read_header(blob)
    n, footer_len = struct.unpack("<II", blob[-12:-4])
    footer_start = len(blob) - _V4_TRAILER - footer_len
    entries_end = footer_start + n * _V6_ENTRY_SIZE
    dict_len = footer_len - (n * _V6_ENTRY_SIZE + 16)
    d = blob[entries_end:entries_end + dict_len] \
        if dict_blob is None else dict_blob
    eb = blob[entries_end + dict_len:entries_end + dict_len + 4]
    cb = blob[entries_end + dict_len + 4:entries_end + dict_len + 8] \
        if ctx_budget is None else struct.pack("<I", ctx_budget)
    entries = [list(struct.unpack_from(_V6_ENTRY, blob,
                                       footer_start + i * _V6_ENTRY_SIZE))
               for i in range(n)]
    body = bytearray(blob[:footer_start])
    if chunk is not None:
        if stream is not None:
            off, ln = entries[chunk][0], entries[chunk][1]
            assert len(stream) == ln
            body[off:off + ln] = stream
            entries[chunk][6] = xxh64(bytes(stream))
        if tag is not None:
            entries[chunk][3] = tag
        if kind is not None:
            entries[chunk][4] = kind
        if param is not None:
            entries[chunk][5] = param
    ents = b"".join(struct.pack(_V6_ENTRY, *e) for e in entries)
    tail = ents + d + eb + cb   # u32 encode_batch + u32 ctx_budget
    return (bytes(body) + tail
            + struct.pack("<Q", xxh64(blob[:info.header_size] + tail))
            + struct.pack("<II", n, len(tail) + 8) + _V6_END_MAGIC)


def test_v6_recipe_validation_behind_checksums(v6_case):
    """Checksum-fixing tampers of the recipe fields still fail loudly:
    every format law from DESIGN.md §12 is enforced by read_index, not
    an artifact of hash coverage."""
    comp, _, blob = v6_case
    info = read_index(blob)
    assert _v6_tamper(blob) == blob     # untampered rewrite is bit-exact
    with pytest.raises(ContainerError, match="unknown recipe kind"):
        comp.decompress(_v6_tamper(blob, 0, kind=3))
    with pytest.raises(ContainerError, match="chunk 0 cannot carry"):
        comp.decompress(_v6_tamper(blob, 0, kind=1, param=4))
    carry = next(i for i, e in enumerate(info.entries)
                 if e.recipe_kind == RECIPE_CARRY)
    with pytest.raises(ContainerError, match="window 0"):
        comp.decompress(_v6_tamper(blob, carry, param=0))
    with pytest.raises(ContainerError, match="dictionary has 1"):
        comp.decompress(_v6_tamper(blob, carry, kind=2, param=7))
    fb = next(i for i, e in enumerate(info.entries) if not e.is_llm)
    fb_kind, fb_param = (1, 4) if fb else (2, 0)
    with pytest.raises(ContainerError, match="context-free"):
        comp.decompress(_v6_tamper(blob, fb, kind=fb_kind, param=fb_param))


def test_v6_ctx_budget_validation_behind_checksums(v6_case):
    """The recorded context budget is coding geometry (DESIGN.md §12):
    a checksum-fixing tamper that shrinks it below a chunk's materialized
    context, or inflates it past the prefix-length ceiling, raises at
    index time — a wrong budget could never have been the encoder's
    decode-program length."""
    comp, _, blob = v6_case
    recorded = read_index(blob).ctx_budget
    assert recorded > 0          # the fixture carries context by design
    # a too-small budget violates the floor law for some carried chunk
    with pytest.raises(ContainerError, match="materializes"):
        comp.decompress(_v6_tamper(blob, ctx_budget=0))
    # above the u16 prefix-length ceiling: structurally impossible
    with pytest.raises(ContainerError, match="exceeds"):
        comp.decompress(_v6_tamper(blob, ctx_budget=1 << 16))
    # a LARGER-than-needed budget passes the index laws (routing may
    # erase recipes after the budget is fixed, so over-provisioning is
    # legal wire-wise). On a real model it changes the decode program —
    # the per-chunk checksums catch that; the golden predictor is
    # geometry-free, so here the archive still round-trips.
    bigger = _v6_tamper(blob, ctx_budget=recorded + 4)
    assert read_index(bigger).ctx_budget == recorded + 4
    comp.decompress(bigger)


def test_v6_dictionary_validation_behind_checksums(v6_case):
    """Same idea for the shared-prefix dictionary section: a structurally
    broken or out-of-vocab dictionary raises even with all checksums
    recomputed over the tampered bytes."""
    comp, _, blob = v6_case
    vocab = read_header(blob).vocab
    # token id outside the vocab
    bad_tok = (struct.pack("<H", 1) + struct.pack("<B", 1) + b"p"
               + struct.pack("<H", 1) + struct.pack("<I", vocab))
    with pytest.raises(ContainerError, match="vocab"):
        comp.decompress(_v6_tamper(blob, dict_blob=bad_tok))
    # stray bytes after the last prefix (hash-covered span ≠ padding)
    good = _v6_tamper(blob)
    n, footer_len = struct.unpack("<II", good[-12:-4])
    footer_start = len(good) - _V4_TRAILER - footer_len
    entries_end = footer_start + n * _V6_ENTRY_SIZE
    dict_len = footer_len - (n * _V6_ENTRY_SIZE + 16)
    d = good[entries_end:entries_end + dict_len]
    with pytest.raises(ContainerError, match="stray bytes"):
        comp.decompress(_v6_tamper(blob, dict_blob=d + b"\x00"))
    # an empty prefix (token count 0)
    empty = (struct.pack("<H", 1) + struct.pack("<B", 1) + b"p"
             + struct.pack("<H", 0))
    with pytest.raises(ContainerError, match="empty"):
        comp.decompress(_v6_tamper(blob, dict_blob=empty))
    # a token count that runs past the section
    short = (struct.pack("<H", 1) + struct.pack("<B", 1) + b"p"
             + struct.pack("<H", 9) + struct.pack("<I", 1))
    with pytest.raises(ContainerError, match="ends early"):
        comp.decompress(_v6_tamper(blob, dict_blob=short))
    # dropping the dictionary while shared recipes still reference it
    with pytest.raises(ContainerError, match="dictionary has 0"):
        comp.decompress(_v6_tamper(blob, dict_blob=struct.pack("<H", 0)))


def test_v6_range_decode_matches_full_decode(v6_case):
    """Random access over a carried archive: every interval equals the
    matching slice of a full decode. Carried chunks are reconstructed by
    decoding their chain from its head — invisible to the caller."""
    comp, toks, blob = v6_case
    full = comp.decompress(blob)
    assert np.array_equal(full, toks)
    info = read_index(blob)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in range(lo + 1, info.n_chunks + 1):
            part = comp.decompress_range(blob, lo, hi)
            assert np.array_equal(part,
                                  full[lo * C:min(hi * C, toks.size)]), \
                (lo, hi)


def test_v6_range_decode_detects_upstream_corruption(v6_case):
    """A carried chunk's range decode must fail loudly when its chain
    HEAD is corrupt (the context it needs cannot be reconstructed), while
    chunks in other chains stay independently readable."""
    comp, _, blob = v6_case
    info = read_index(blob)
    carry = next(i for i, e in enumerate(info.entries)
                 if e.recipe_kind == RECIPE_CARRY)
    head = max(i for i in range(carry + 1)
               if info.entries[i].recipe_kind != RECIPE_CARRY)
    assert head < carry
    bad = bytearray(blob)
    bad[info.entries[head].offset] ^= 0x01
    with pytest.raises(ContainerError, match=f"chunk {head}"):
        comp.decompress_range(bytes(bad), carry, carry + 1)
    # a chunk that heads a DIFFERENT chain never reads the damaged bytes
    other = next(i for i, e in enumerate(info.entries)
                 if e.recipe_kind != RECIPE_CARRY and i != head)
    assert np.array_equal(comp.decompress_range(bytes(bad), other, other + 1),
                          comp.decompress_range(blob, other, other + 1))
