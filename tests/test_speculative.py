"""Bit-exactness of speculative batched decompression (DESIGN.md §9).

The entropy decoder — not the draft — arbitrates every token, so
speculative decode must produce EXACTLY the lock-step decoder's output on
every container, for every proposer, including one that is always wrong.
These tests pin that contract across the registered model families, both
coded alphabets (top-k + escape, full vocab), adversarial and oracle
proposers, escape-heavy streams, and the empty-input / invalid-range
container edges fixed in the same PR.
"""
import numpy as np
import pytest

import jax
from helpers import GoldenPredictor, tiny
from repro.core import ContainerError, LLMCompressor
from repro.core.draft import ConstantDraft, OracleDraft, SuffixDraft

FAMILIES = ["dense", "moe", "ssm", "hybrid"]


def _model_pred(family):
    from repro.models import init_params
    from repro.serve.engine import ModelPredictor
    cfg = tiny(family, vocab_size=258)
    return ModelPredictor(init_params(cfg, jax.random.PRNGKey(0)), cfg,
                          bos_id=257)


def _predictable_tokens(pred, n, q=0.9, seed=11):
    """Follow the predictor's table argmax with prob q — compressible AND
    draftable (repeating n-grams), the regime speculation targets."""
    rng = np.random.default_rng(seed)
    argmax = pred._table.argmax(axis=-1)
    toks = np.zeros(n, np.int32)
    prev = pred.bos_id
    for i in range(n):
        t = int(argmax[prev]) if rng.random() < q \
            else int(rng.integers(0, pred.vocab_size - 1))
        toks[i] = t
        prev = t
    return toks


class CountingPredictor(GoldenPredictor):
    """GoldenPredictor + dispatch counters (decode_step vs verify)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.n_decode = 0
        self.n_verify = 0

    def decode_step(self, state, prev_tokens):
        self.n_decode += 1
        return super().decode_step(state, prev_tokens)

    def verify_steps(self, state, seq):
        self.n_verify += 1
        return super().verify_steps(state, seq)


@pytest.mark.parametrize("topk", [8, 0])
@pytest.mark.parametrize("draft_k", [1, 3, 5])
def test_spec_equals_lockstep_golden(topk, draft_k):
    pred = GoldenPredictor()
    toks = _predictable_tokens(pred, 400)
    comp = LLMCompressor(pred, chunk_size=32, topk=topk, decode_batch=4)
    blob, _ = comp.compress(toks)
    lock = comp.decompress(blob)
    assert np.array_equal(lock, toks)
    spec = LLMCompressor(pred, chunk_size=32, topk=topk, decode_batch=4,
                         draft_k=draft_k)
    assert np.array_equal(spec.decompress(blob), toks)


@pytest.mark.parametrize("family", FAMILIES)
def test_spec_equals_lockstep_model_families(family):
    pred = _model_pred(family)
    toks = np.random.default_rng(3).integers(0, 250, 120).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=24, topk=16, decode_batch=4)
    blob, _ = comp.compress(toks)
    assert np.array_equal(comp.decompress(blob), toks)
    spec = LLMCompressor(pred, chunk_size=24, topk=16, decode_batch=4,
                         draft_k=3)
    assert np.array_equal(spec.decompress(blob), toks)


@pytest.mark.parametrize("topk", [8, 0])
def test_adversarial_always_wrong_draft(topk):
    """A proposer that never matches costs rounds, never correctness:
    every round degenerates to one accepted (entropy-decoded) token."""
    pred = CountingPredictor()
    toks = _predictable_tokens(pred, 300)
    comp = LLMCompressor(pred, chunk_size=32, topk=topk, decode_batch=4)
    blob, _ = comp.compress(toks)
    bad = LLMCompressor(pred, chunk_size=32, topk=topk, decode_batch=4,
                        draft_k=4, draft=ConstantDraft(pred.vocab_size - 1))
    assert np.array_equal(bad.decompress(blob), toks)


def test_oracle_draft_accepts_everything():
    """With a perfect proposer every drafted position is accepted, so the
    verify-forward count collapses toward n_tokens / (K+1) per lane —
    the tentpole's speed mechanism, observable deterministically."""
    pred = CountingPredictor()
    toks = _predictable_tokens(pred, 512)
    C, B, K = 32, 4, 4
    comp = LLMCompressor(pred, chunk_size=C, topk=8, decode_batch=B)
    blob, _ = comp.compress(toks)
    pred.n_decode = pred.n_verify = 0
    comp.decompress(blob)
    lock_calls = pred.n_decode
    spec = LLMCompressor(pred, chunk_size=C, topk=8, decode_batch=B,
                         draft_k=K, draft=OracleDraft(toks, C))
    pred.n_decode = pred.n_verify = 0
    assert np.array_equal(spec.decompress(blob), toks)
    spec_calls = pred.n_decode + pred.n_verify
    assert lock_calls == C * (toks.size // (C * B))  # C steps per group
    # all-accept: ceil(C / (K+1)) verify rounds per group, no lock-step
    assert spec_calls <= -(-C // (K + 1)) * (toks.size // (C * B)) + 1
    assert spec_calls * 2 < lock_calls


def test_suffix_draft_beats_lockstep_dispatches_on_predictable_text():
    pred = CountingPredictor()
    toks = _predictable_tokens(pred, 1024, q=0.95)
    comp = LLMCompressor(pred, chunk_size=64, topk=8, decode_batch=4)
    blob, _ = comp.compress(toks)
    pred.n_decode = pred.n_verify = 0
    comp.decompress(blob)
    lock_calls = pred.n_decode
    spec = LLMCompressor(pred, chunk_size=64, topk=8, decode_batch=4,
                         draft_k=4)
    pred.n_decode = pred.n_verify = 0
    assert np.array_equal(spec.decompress(blob), toks)
    assert pred.n_decode + pred.n_verify < lock_calls


def test_escape_heavy_topk_stream():
    """topk=2 over near-uniform data: most tokens escape, every escape
    goes through get_uniform inside the speculative accept loop."""
    pred = GoldenPredictor()
    rng = np.random.default_rng(5)
    toks = rng.integers(0, pred.vocab_size - 1, 300).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=32, topk=2, decode_batch=4)
    blob, stats = comp.compress(toks)
    assert np.array_equal(comp.decompress(blob), toks)
    spec = LLMCompressor(pred, chunk_size=32, topk=2, decode_batch=4,
                         draft_k=3)
    assert np.array_equal(spec.decompress(blob), toks)


def test_spec_ragged_tail_and_tiny_inputs():
    """Lane masks at chunk boundaries: sizes that end mid-chunk,
    single-token, fewer chunks than lanes."""
    pred = GoldenPredictor()
    for n in (1, 7, 31, 33, 65, 97):
        toks = _predictable_tokens(pred, n, seed=n)
        comp = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4)
        blob, _ = comp.compress(toks)
        spec = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4,
                             draft_k=4)
        assert np.array_equal(spec.decompress(blob), toks), n


def test_ac_codec_ignores_draft():
    """The AC codec has no speculative path; draft_k must be inert, not
    wrong."""
    pred = GoldenPredictor()
    toks = _predictable_tokens(pred, 100)
    comp = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4,
                         codec="ac")
    blob, _ = comp.compress(toks)
    spec = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4,
                         codec="ac", draft_k=4)
    assert np.array_equal(spec.decompress(blob), toks)


# ---------------------------------------------------------------- edges

def test_empty_input_roundtrip():
    """Zero tokens -> valid zero-chunk container -> empty array, with no
    model involvement on either side."""
    class Exploding(GoldenPredictor):
        def score_chunks(self, tokens):
            raise AssertionError("model called for empty input")

        def decode_step(self, state, prev):
            raise AssertionError("model called for empty input")

    pred = Exploding()
    for kw in (dict(topk=8), dict(topk=0), dict(codec="ac"),
               dict(topk=8, draft_k=4), dict(container_version=4)):
        comp = LLMCompressor(pred, chunk_size=32, decode_batch=4, **kw)
        blob, stats = comp.compress(np.zeros(0, np.int32))
        assert stats.n_tokens == 0
        out = comp.decompress(blob)
        assert out.size == 0 and out.dtype == np.int32


def test_empty_input_via_service():
    from repro.service import CompressionService
    svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=16,
                             topk=8)
    blob, stats = svc.submit_compress(np.zeros(0, np.int32)).result()
    assert stats.n_tokens == 0
    out = svc.submit_decompress(blob).result()
    assert out.size == 0 and out.dtype == np.int32


@pytest.mark.parametrize("lo,hi,frag", [
    (2, 2, "empty"), (3, 1, "reversed"), (-1, 2, "out of bounds"),
    (0, 99, "out of bounds"),
])
def test_decompress_range_invalid_ranges(lo, hi, frag):
    pred = GoldenPredictor()
    toks = _predictable_tokens(pred, 150)
    comp = LLMCompressor(pred, chunk_size=32, topk=8, decode_batch=4,
                         container_version=4)
    blob, _ = comp.compress(toks)
    with pytest.raises(ContainerError, match=frag):
        comp.decompress_range(blob, lo, hi)


def test_decompress_range_empty_container():
    comp = LLMCompressor(GoldenPredictor(), chunk_size=32, topk=8,
                         decode_batch=4, container_version=4)
    blob, _ = comp.compress(np.zeros(0, np.int32))
    with pytest.raises(ContainerError, match="out of bounds"):
        comp.decompress_range(blob, 0, 1)


# ------------------------------------------------------------------ CLI

def test_cli_empty_file_roundtrip(tmp_path, monkeypatch):
    import repro.cli as cli
    monkeypatch.setattr(cli, "_predictor",
                        lambda name: GoldenPredictor(vocab_size=258))
    src = tmp_path / "empty.bin"
    src.write_bytes(b"")
    arc = tmp_path / "empty.llmc"
    out = tmp_path / "out.bin"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16"]) == 0
    assert cli.main(["info", str(arc)]) == 0
    assert cli.main(["decompress", str(arc), str(out)]) == 0
    assert out.read_bytes() == b""


def test_cli_range_errors_are_clean(tmp_path, monkeypatch):
    import repro.cli as cli
    monkeypatch.setattr(cli, "_predictor",
                        lambda name: GoldenPredictor(vocab_size=258))
    src = tmp_path / "data.bin"
    src.write_bytes(bytes(range(100)))
    arc = tmp_path / "data.llmc"
    out = tmp_path / "out.bin"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8"]) == 0
    with pytest.raises(SystemExit, match="llmc: invalid chunk range"):
        cli.main(["range", str(arc), str(out), "--chunks", "2:2"])
    with pytest.raises(SystemExit, match="llmc: chunk range .* out of"):
        cli.main(["range", str(arc), str(out), "--chunks", "0:99"])
    with pytest.raises(SystemExit, match="LO:HI"):
        cli.main(["range", str(arc), str(out), "--chunks", "nope"])
    # a valid range still decodes
    assert cli.main(["range", str(arc), str(out), "--chunks", "1:3"]) == 0
    assert out.read_bytes() == bytes(range(16, 48))
