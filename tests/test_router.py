"""Adaptive codec routing (DESIGN.md §11): unit + property suites.

What must hold, independent of the probe's quality:

* **round-trip bit-exactness** for any interleaving of model-friendly
  (self-generated), adversarial, and uniform-random chunks — routing
  may only ever change *where* bytes come from, never what decodes;
* the fallback byte codecs are exact inverses on arbitrary bytes,
  including when the optional zstd backend is absent (``HAVE_ZSTD``
  gating — the lzma/raw paths carry the suite on minimal installs);
* the routed container never loses to either pure strategy on the same
  stream: per-chunk realized-size comparison makes
  ``routed ≤ min(pure-LLM, forced-fallback)`` a structural guarantee
  at equal container geometry;
* the probe actually skips the model on hopeless chunks (and records
  the estimate), and keeps it on friendly ones.

Property tests run through ``tests/_hypo.py`` — real Hypothesis with
the ``[test]`` extras, a seeded deterministic fallback without.
"""
import numpy as np
import pytest

from _hypo import given, settings, st
from helpers import GoldenPredictor, golden_self_tokens, golden_tokens
from repro import obs
from repro.core import (LLMCompressor, RouterConfig, available_byte_codecs,
                        compress_bytes, decompress_bytes, pack_tokens,
                        read_index, unpack_tokens)
from repro.core import baselines
from repro.core.router import CodecRouter

VOCAB = 64          # GoldenPredictor default


def _adversarial_tokens(pred, n):
    """Argmin-walk through the predictor's table: every step takes the
    token the model considers least likely, so the probe estimate blows
    past any fallback — and the walk quickly cycles, which also makes it
    highly compressible for the dictionary codecs."""
    out = np.empty(n, np.int32)
    prev = pred.bos_id
    for i in range(n):
        prev = out[i] = int(np.argmin(pred._table[prev]))
    return out


def _comp(**kw):
    base = dict(chunk_size=16, decode_batch=4, topk=8, codec="rans")
    base.update(kw)
    return LLMCompressor(GoldenPredictor(), **base)


# ---------------------------------------------------- token <-> byte packing
def test_pack_tokens_width_selection():
    assert pack_tokens(np.array([0, 255]))[0] == 1
    assert pack_tokens(np.array([0, 256]))[0] == 2
    assert pack_tokens(np.array([0, 65536]))[0] == 4
    assert pack_tokens(np.zeros(0, np.int32)) == (1, b"")


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 200_000), min_size=0, max_size=40))
def test_pack_unpack_inverse(toks):
    toks = np.asarray(toks, np.int64)
    width, packed = pack_tokens(toks)
    got = unpack_tokens(packed, width, toks.size, 200_001)
    assert np.array_equal(got, toks)


def test_unpack_tokens_validates():
    with pytest.raises(ValueError, match="width"):
        unpack_tokens(b"\x00" * 3, 3, 1, 10)
    with pytest.raises(ValueError, match="payload bytes"):
        unpack_tokens(b"\x00" * 3, 2, 1, 10)
    with pytest.raises(ValueError, match="vocab"):
        unpack_tokens(b"\x09", 1, 1, 9)


# ------------------------------------------------------ fallback byte codecs
@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=0, max_size=300))
def test_byte_codecs_are_inverses(data):
    """Every available fallback codec is an exact inverse on arbitrary
    bytes (zstd joins only when the optional package is importable)."""
    for name in available_byte_codecs():
        assert decompress_bytes(name, compress_bytes(name, data)) == data


def test_unknown_byte_codec_rejected():
    with pytest.raises(KeyError):
        compress_bytes("brotli", b"x")
    with pytest.raises(KeyError):
        decompress_bytes("brotli", b"x")


def test_no_zstd_gating(monkeypatch):
    """With the optional zstd backend absent the codec never appears,
    its entry points fail loudly, and routing still works end-to-end on
    the remaining codecs — the minimal install loses a codec choice,
    never correctness."""
    monkeypatch.setattr(baselines, "HAVE_ZSTD", False)
    assert "zstd" not in available_byte_codecs()
    with pytest.raises(RuntimeError, match="zstandard"):
        compress_bytes("zstd", b"x")
    # a router configured *only* with zstd has nothing to fall back to
    with pytest.raises(ValueError, match="available"):
        CodecRouter(RouterConfig(fallbacks=("zstd",))).fallback_candidates()
    # default config degrades to lzma+raw and round-trips
    comp = _comp(container_version=5, route="auto")
    toks = np.concatenate([golden_self_tokens(16, seed=1),
                           golden_tokens(16, seed=2)])
    blob, _ = comp.compress(toks)
    assert np.array_equal(_comp(container_version=5).decompress(blob), toks)
    assert all(e.codec_name != "zstd" for e in read_index(blob).entries)


@pytest.mark.skipif(not baselines.HAVE_ZSTD,
                    reason="optional zstandard not installed")
def test_zstd_roundtrip_when_available():
    """CI's full install: zstd is a live candidate and a forced-zstd v5
    container round-trips (the golden set cannot pin zstd bytes —
    payloads vary across zstd builds — so this guards the path)."""
    comp = _comp(container_version=5, route="zstd", chunk_size=64)
    toks = np.tile(np.arange(8, dtype=np.int32), 20)
    blob, _ = comp.compress(toks)
    info = read_index(blob)
    assert "zstd" in {e.codec_name for e in info.entries}
    assert np.array_equal(_comp(chunk_size=64).decompress(blob), toks)


# --------------------------------------------------------- routed round-trip
def _segment(kind, n, seed):
    if kind == "self":
        return golden_self_tokens(n, seed=seed)
    if kind == "rand":
        return golden_tokens(n, seed=seed, vocab=VOCAB - 1)
    return _adversarial_tokens(GoldenPredictor(), n)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["self", "adv", "rand"]),
                min_size=0, max_size=5),
       st.integers(1, 16), st.integers(0, 2 ** 20))
def test_routed_roundtrip_any_interleaving(kinds, tail, seed):
    """The core property: ANY interleaving of predictable, adversarial,
    and random chunks (plus a ragged tail) round-trips bit-exactly
    through auto routing, and a fresh decoder — no shared state with
    the encoder — reads the same tokens from the recorded tags."""
    segs = [_segment(k, 16, seed + i) for i, k in enumerate(kinds)]
    if kinds:
        segs[-1] = segs[-1][:tail]
    toks = (np.concatenate(segs) if segs
            else np.zeros(0, np.int32)).astype(np.int32)
    comp = _comp(container_version=5, route="auto",
                 router=RouterConfig(fallbacks=("raw", "lzma")))
    blob, stats = comp.compress(toks)
    info = read_index(blob)
    assert len(stats.routes) == info.n_chunks
    assert all(e.codec_name in ("rans", "raw", "lzma")
               for e in info.entries)
    assert np.array_equal(comp.decompress(blob), toks)
    fresh = _comp(container_version=5)
    assert np.array_equal(fresh.decompress(blob), toks)


def test_routed_never_loses_to_either_pure_strategy():
    """Same stream, same v5 geometry, three strategies: the routed
    container is never larger than pure-LLM or forced-raw — the
    realized-size comparison guarantees the per-chunk minimum."""
    toks = np.concatenate([golden_self_tokens(48, seed=9),
                           golden_tokens(48, seed=10, vocab=VOCAB - 1),
                           _adversarial_tokens(GoldenPredictor(), 32)])
    kw = dict(container_version=5, router=RouterConfig(fallbacks=("raw",)))
    routed, _ = _comp(route="auto", **kw).compress(toks)
    llm, _ = _comp(container_version=5).compress(toks)
    forced, _ = _comp(route="raw", container_version=5).compress(toks)
    assert len(routed) <= min(len(llm), len(forced))


def test_probe_skips_hopeless_chunks_and_keeps_friendly_ones():
    """Direction check with counters: adversarial chunks skip the model
    (probe estimate recorded), self-generated chunks stay on the
    entropy path."""
    reg = obs.MetricsRegistry(enabled=True)
    pred = GoldenPredictor()
    comp = LLMCompressor(pred, chunk_size=16, decode_batch=4, topk=8,
                         container_version=5, route="auto",
                         router=RouterConfig(fallbacks=("raw", "lzma")),
                         registry=reg)
    toks = np.concatenate([golden_self_tokens(32, seed=21),
                           _adversarial_tokens(pred, 32)])
    blob, stats = comp.compress(toks)
    names = [e.codec_name for e in read_index(blob).entries]
    assert names[:2] == ["rans", "rans"] and \
        all(n != "rans" for n in names[2:])
    snap = reg.snapshot()
    assert snap[obs.ROUTER_CHUNKS_LLM]["value"] == 2
    assert snap[obs.ROUTER_CHUNKS_FALLBACK]["value"] == 2
    assert snap[obs.ROUTER_PROBE_SKIPS]["value"] == 2
    # every decision carries the probe estimate; skipped chunks never
    # produced an LLM stream to compare against
    assert all(d.llm_bits_est >= 0 for d in stats.routes)
    assert all(not d.flipped for d in stats.routes[2:])
    assert np.array_equal(comp.decompress(blob), toks)


def test_forced_route_validation():
    with pytest.raises(ValueError, match="unknown route"):
        _comp(route="brotli", container_version=5)
    with pytest.raises(ValueError, match="v5"):
        _comp(route="auto", container_version=4)
    with pytest.raises(ValueError, match="v5"):
        _comp(route="raw", container_version=3)


# ------------------------------------------------------- adaptive margin
def test_adaptive_margin_direction_and_clamps():
    """The calibration loop moves the effective margin the right way:
    estimates running HOT (realized > estimated — the probe flatters the
    model on adversarial traffic) shrink the margin toward the floor so
    such chunks skip sooner; estimates running COOL grow it toward the
    ceiling so predictable chunks keep their slot. Both ends clamp, each
    class calibrates independently, and fixed mode never moves."""
    r = CodecRouter(RouterConfig(fallbacks=("raw",)))
    for cls in ("predictable", "borderline", "adversarial"):
        assert r.margin_for(cls) == pytest.approx(1.25)   # no history yet
    # adversarial traffic (est 2.5x the fallback bits), realized 2x hot:
    # margin 1.25/2.0 = 0.625 clamps UP to the 1.05 floor
    r.observe(2000.0, 4000.0, 100)
    assert r.margin_for("adversarial") == pytest.approx(1.05)
    # predictable traffic (est 0.5x fallback), realized 2x cool:
    # 1.25/0.5 = 2.5 clamps DOWN to the 2.0 ceiling
    r.observe(400.0, 200.0, 100)
    assert r.margin_for("predictable") == pytest.approx(2.0)
    # the un-observed class is untouched — regimes never cross-talk
    assert r.margin_for("borderline") == pytest.approx(1.25)
    # the margin feeds the skip decision directionally: a borderline
    # chunk (est 900 vs 800 fallback bits) is kept at the default margin
    # (900 < 1.25*800); after its class runs 2x hot the floor margin
    # skips it (900 > 1.05*800)
    assert not r.skip_llm(900.0, b"\x00" * 100)
    r.observe(900.0, 1800.0, 100)            # borderline class, 2x hot
    assert r.margin_for("borderline") == pytest.approx(1.05)
    assert r.skip_llm(900.0, b"\x00" * 100)
    fixed = CodecRouter(RouterConfig(fallbacks=("raw",),
                                     adaptive_margin=False))
    fixed.observe(900.0, 1800.0, 100)
    fixed.observe(2000.0, 4000.0, 100)
    assert fixed.margin_for("adversarial") == pytest.approx(1.25)
    assert not fixed.skip_llm(900.0, b"\x00" * 100)


def test_adaptive_margin_ema_converges():
    """Repeated observations EMA toward the latest regime instead of
    locking in the first sample, and degenerate observations (zero/neg
    sizes) are ignored."""
    cfg = RouterConfig(fallbacks=("raw",), margin_floor=0.1,
                       margin_ceil=10.0)
    r = CodecRouter(cfg)
    r.observe(2000.0, 2000.0, 100)            # rho = 1.0
    assert r.margin_for("adversarial") == pytest.approx(1.25)
    for _ in range(40):
        r.observe(2000.0, 4000.0, 100)        # regime shifts 2x hot
    assert r.margin_for("adversarial") == pytest.approx(0.625, rel=1e-3)
    before = r.margin_for("adversarial")
    r.observe(0.0, 4000.0, 100)
    r.observe(2000.0, 0.0, 100)
    assert r.margin_for("adversarial") == before


def test_compressor_feeds_router_calibration():
    """End to end: an auto-routed compress feeds probe-vs-realized
    observations back into the router for every chunk that produced an
    LLM stream — the calibration state is non-empty afterwards."""
    comp = _comp(container_version=5, route="auto",
                 router=RouterConfig(fallbacks=("raw",)))
    toks = np.concatenate([golden_self_tokens(32, seed=31),
                           golden_tokens(32, seed=32, vocab=VOCAB - 1)])
    comp.compress(toks)
    assert comp.router._calibration          # at least one class observed


# ------------------------------------------------------------------ CLI
def _friendly_bytes(pred, n):
    """Bytes the byte-level predictor finds maximally predictable: an
    argmax walk through its table restricted to the raw-byte ids."""
    out = bytearray()
    prev = pred.bos_id
    for _ in range(n):
        prev = int(np.argmax(pred._table[prev][:256]))
        out.append(prev)
    return bytes(out)


def _cli_mixed_setup(tmp_path, monkeypatch, seed=0):
    import repro.cli as cli
    pred = GoldenPredictor(vocab_size=258, seed=seed)
    monkeypatch.setattr(cli, "_predictor", lambda name: pred)
    rng = np.random.default_rng(7)
    data = (_friendly_bytes(pred, 32)
            + rng.integers(0, 256, 32, dtype=np.uint8).tobytes())
    src = tmp_path / "data.bin"
    src.write_bytes(data)
    return cli, data, src


def test_cli_route_auto_writes_v5_and_info_prints_codecs(
        tmp_path, monkeypatch, capsys):
    """`llmc compress --route auto` produces a mixed-codec v5 archive;
    `llmc info` prints each chunk's codec tag and the codec mix."""
    cli, data, src = _cli_mixed_setup(tmp_path, monkeypatch)
    arc, out = tmp_path / "a.llmc", tmp_path / "out.bin"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--route", "auto"]) == 0
    blob = arc.read_bytes()
    assert blob[4] == 5 and blob[-4:] == b"LC5F"
    tags = [e.codec_name for e in read_index(blob).entries]
    assert "rans" in tags and set(tags) != {"rans"}      # genuinely mixed
    assert cli.main(["info", str(arc)]) == 0
    shown = capsys.readouterr().out
    assert "codecs:" in shown
    for t in set(tags):
        assert t in shown
    assert cli.main(["decompress", str(arc), str(out)]) == 0
    assert out.read_bytes() == data


def test_cli_range_roundtrips_mixed_v5_archive(tmp_path, monkeypatch):
    """Satellite regression: `llmc range` (help now says v4+) random-
    access decodes an interval that spans an entropy chunk and a
    fallback chunk of the same v5 archive."""
    cli, data, src = _cli_mixed_setup(tmp_path, monkeypatch)
    arc, out = tmp_path / "a.llmc", tmp_path / "out.bin"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--route", "auto"]) == 0
    tags = [e.codec_name for e in read_index(arc.read_bytes()).entries]
    assert tags[1] == "rans" and tags[2] != "rans"   # interval is mixed
    assert cli.main(["range", str(arc), str(out), "--chunks", "1:3"]) == 0
    assert out.read_bytes() == data[16:48]


def test_cli_recorded_route_overrides_decode_side_guessing(
        tmp_path, monkeypatch):
    """A forced `--route raw` archive decodes through the recorded tags
    alone: swapping in a predictor with a *different* table for decode
    still reconstructs the bytes exactly, because no decode-side
    heuristic (or model) is consulted for fallback chunks."""
    import repro.cli as cli
    pred = GoldenPredictor(vocab_size=258, seed=0)
    monkeypatch.setattr(cli, "_predictor", lambda name: pred)
    data = np.random.default_rng(3).integers(
        0, 256, 100, dtype=np.uint8).tobytes()
    src, arc, out = (tmp_path / n for n in ("d.bin", "a.llmc", "o.bin"))
    src.write_bytes(data)
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--route", "raw"]) == 0
    tags = {e.codec_name for e in read_index(arc.read_bytes()).entries}
    assert "rans" not in tags
    monkeypatch.setattr(cli, "_predictor",
                        lambda name: GoldenPredictor(vocab_size=258,
                                                     seed=999))
    assert cli.main(["decompress", str(arc), str(out)]) == 0
    assert out.read_bytes() == data


def test_cli_route_rejects_v3_and_ac_paths(tmp_path, monkeypatch):
    cli, data, src = _cli_mixed_setup(tmp_path, monkeypatch)
    arc = tmp_path / "a.llmc"
    with pytest.raises(SystemExit, match="--route"):
        cli.main(["compress", str(src), str(arc), "--v3",
                  "--route", "auto"])
    with pytest.raises(SystemExit, match="--route"):
        cli.main(["compress", str(src), str(arc), "--codec", "ac",
                  "--route", "raw"])
