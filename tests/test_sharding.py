"""Sharding policy unit tests (no multi-device needed)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_mesh
from repro.models.schema import abstract_params, param_axes, schema, Leaf
from repro.sharding.specs import batch_pspecs, cache_pspecs, param_pspecs


def _fake_mesh():
    # single real device, but the POLICY is computed from names/shape only
    return make_mesh((1, 1), ("data", "model"))


def test_param_specs_match_tree_structure():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pa = abstract_params(cfg)
        ps = param_pspecs(cfg, _fake_mesh())
        assert jax.tree_util.tree_structure(pa) == \
            jax.tree_util.tree_structure(ps)


def test_specs_rank_matches_shapes():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pa = jax.tree_util.tree_leaves(abstract_params(cfg))
        ps = jax.tree_util.tree_leaves(
            param_pspecs(cfg, _fake_mesh()),
            is_leaf=lambda x: isinstance(x, P))
        for a, s in zip(pa, ps):
            assert len(s) <= len(a.shape), (a.shape, s)


def test_divisibility_policy():
    """Every sharded dim must divide by the production TP/DP degrees."""
    import numpy as np
    from repro.launch.mesh import make_production_mesh

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        sch = schema(cfg)
        specs = param_pspecs(cfg, mesh)
        flat_s, _ = jax.tree_util.tree_flatten(
            sch, is_leaf=lambda x: isinstance(x, Leaf))
        flat_p = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(flat_s, flat_p):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                deg = mesh.shape[ax] if isinstance(ax, str) else \
                    int(np.prod([mesh.shape[a] for a in ax]))
                assert dim % deg == 0, (arch, leaf.shape, spec)


def test_cache_specs_cover_cache_tree():
    from repro.models import init_cache

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cache = jax.eval_shape(lambda c=cfg: init_cache(c, 16, 128))
        specs = cache_pspecs(cfg, FakeMesh(), batch=16)
        assert set(cache.keys()) == set(specs.keys()), arch


def test_batch_unshardable_falls_back_to_replicated():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("qwen3_14b")
    specs = batch_pspecs(cfg, FakeMesh(), global_batch=1)
    assert specs["tokens"][0] is None
    c = cache_pspecs(cfg, FakeMesh(), batch=1)
    assert c["k"][1] is None        # batch dim replicated
    assert c["k"][2] is not None    # seq dim sharded instead
