"""Hypothesis compatibility shim for the property-test suite.

With the ``[test]`` extras installed this is a pure re-export of
``hypothesis`` — full shrinking, example database, the works. Without it
(the minimal container), a deterministic fallback runs each property
``max_examples`` times with seeded pseudo-random draws, so the tier-1
suite still *collects and runs* everywhere instead of dying on import.

Only the strategy surface this repo uses is implemented in the fallback:
``integers``, ``binary``, ``sampled_from``, ``lists``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def binary(min_size=0, max_size=100):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return rng.integers(0, 256, n, dtype=np.uint8).tobytes()
            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples=50, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # NOTE: deliberately not functools.wraps — preserving the
            # wrapped signature makes pytest treat the strategy params
            # as fixtures. The wrapper takes no arguments at all.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 25)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*[s.draw(rng) for s in strats])
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
