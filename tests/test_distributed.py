"""Multi-device correctness (8 host devices in a subprocess — the parent
test process must keep seeing 1 device)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, timeout=900):
    env = {"PYTHONPATH": f"{REPO}/src:{REPO}", "HOME": "/root",
           "PATH": "/usr/bin:/bin",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_moe_ep_matches_single_device_oracle():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import init_params, forward
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                          n_experts=8, top_k=2, head_pad_multiple=2,
                          vocab_pad_multiple=8, dtype="float32", remat=False)
        p = init_params(cfg, jax.random.PRNGKey(0))
        t = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 256)
        ref = forward(p, cfg, {"tokens": t}, dropless=True)
        out = jax.jit(lambda p, t: forward(p, cfg, {"tokens": t},
                      dropless=True, mesh=mesh))(p, t)
        err = float(jnp.abs(ref - out).max())
        assert err < 2e-4, err
        print("EP_OK", err)
    """)
    assert "EP_OK" in out


@pytest.mark.slow
def test_train_and_serve_on_multipod_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import init_params, init_cache
        from repro.launch.mesh import make_mesh
        from repro.train.train_loop import make_train_step, init_train_state
        from repro.serve.steps import make_serve_step, make_score_step
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab_size=256, head_pad_multiple=2,
                    vocab_pad_multiple=8, dtype="float32", remat=True)
        losses = {}
        for fam, kw in [("dense", {}), ("moe", dict(n_experts=8, top_k=2))]:
            cfg = ModelConfig(name=fam, family=fam, **{**base, **kw})
            params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
            step = make_train_step(cfg, mesh, num_microbatches=2,
                                   global_batch=8)
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2),
                                                  (8, 17), 0, 256)}
            l0 = None
            for i in range(6):
                params, opt_state, m = step(params, opt_state, batch)
                l0 = l0 or float(m["loss"])
            assert float(m["loss"]) < l0
            losses[fam] = float(m["loss"])
        cfg = ModelConfig(name="d", family="dense", **base)
        p = init_params(cfg, jax.random.PRNGKey(0))
        serve = make_serve_step(cfg, mesh, batch=8, topk=8)
        cache = init_cache(cfg, 8, 32)
        ids, q, cache = serve(p, cache, jnp.zeros((8,), jnp.int32))
        assert int(np.asarray(q).sum(-1)[0]) == 1 << 16
        score = make_score_step(cfg, mesh, topk=8, s_block=16, global_batch=8)
        ids, q = score(p, {"tokens": jax.random.randint(
            jax.random.PRNGKey(3), (8, 32), 0, 256)})
        assert ids.shape == (8, 32, 8)
        print("MESH_OK", losses)
    """)
    assert "MESH_OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,4) and (8,1): identical values."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding
        from repro.configs.base import ModelConfig
        from repro.models import init_params
        from repro.launch.mesh import make_mesh
        from repro.sharding.specs import param_pspecs
        from repro.train.checkpoint import save_checkpoint, restore_latest
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                          head_pad_multiple=2, vocab_pad_multiple=8,
                          dtype="float32", remat=False)
        mesh_a = make_mesh((4, 2), ("data", "model"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        sh_a = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh_a, s), param_pspecs(cfg, mesh_a))
        params_a = jax.tree_util.tree_map(jax.device_put, params, sh_a)
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, {"params": params_a})
        for shape in ((2, 4), (8, 1)):
            mesh_b = make_mesh(shape, ("data", "model"))
            sh_b = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh_b, s), param_pspecs(cfg, mesh_b))
            restored, _ = restore_latest(d, {"params": params},
                                         shardings={"params": sh_b})
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(restored["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
