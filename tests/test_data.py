"""Data pipeline determinism + tokenizer + entropy analysis tools."""
import numpy as np
from _hypo import given, settings, st

from repro.core.entropy import analyze
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import DOMAINS, human_like
from repro.data.tokenizer import decode, encode


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=500))
def test_tokenizer_roundtrip(data):
    assert decode(encode(data)) == data


def test_pipeline_deterministic_across_instances():
    toks = np.arange(5000) % 250
    a = TokenPipeline(toks, global_batch=4, seq_len=32, seed=7)
    b = TokenPipeline(toks, global_batch=4, seq_len=32, seed=7)
    for step in (0, 3, 11):
        assert np.array_equal(a.global_batch_array(step),
                              b.global_batch_array(step))


def test_pipeline_host_sharding_partitions_batch():
    toks = np.arange(5000) % 250
    pipes = [TokenPipeline(toks, global_batch=8, seq_len=16, n_hosts=4,
                           host_id=h, seed=1) for h in range(4)]
    rows = sum(len(p.host_batch(2)) for p in pipes)
    assert rows == 8


def test_pipeline_reassign_covers_all_rows():
    toks = np.arange(5000) % 250
    pipes = [TokenPipeline(toks, global_batch=8, seq_len=16, n_hosts=4,
                           host_id=h, seed=1) for h in range(4)]
    for p in pipes:
        p.reassign([1, 3])
    rows = len(pipes[0].host_batch(5)) + len(pipes[2].host_batch(5))
    assert rows == 8  # survivors cover the whole batch


def test_synthetic_text_humanlike_entropy():
    txt = human_like("wiki", 20000, seed=0).decode()
    r = analyze(txt)
    assert 3.0 < r["char_entropy_per_byte"] < 5.5
    assert r["fourgram_top10_coverage"] < 0.2  # paper Fig 2: low redundancy


def test_all_domains_generate():
    for d in DOMAINS:
        assert len(human_like(d, 500, seed=1)) == 500
