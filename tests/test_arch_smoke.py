"""Per-assigned-architecture smoke tests: REDUCED same-family config, one
forward + one train step on CPU; output shapes + no NaNs (deliverable f)."""
import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import local_mesh
from repro.models import forward, init_params
from repro.train.train_loop import make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    logits = forward(params, cfg, batch)
    exp_S = S if cfg.family != "vlm" else S
    assert logits.shape == (B, exp_S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"

    from repro.train.optimizer import AdamWConfig, init_opt_state
    step = make_train_step(cfg, local_mesh(), opt=AdamWConfig(),
                           global_batch=B)
    opt_state = init_opt_state(params, AdamWConfig())
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks)."""
    c = get_config("qwen3_moe_235b_a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (94, 4096, 64, 4)
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab_size) == (128, 8, 1536, 151936)
    c = get_config("llava_next_34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (60, 7168, 56, 20480)
    c = get_config("zamba2_7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = get_config("h2o_danube_3_4b")
    assert c.sliding_window == 4096 and c.d_model == 3840
    c = get_config("whisper_large_v3")
    assert c.n_enc_layers == 32 and c.vocab_size == 51866
    c = get_config("mamba2_130m")
    assert c.ssm_state == 128 and c.n_heads == 0
    assert all(SHAPES)  # 4 shapes defined


def test_param_counts_sane():
    from repro.models.schema import count_params
    expected = {"qwen3_moe_235b_a22b": 235e9, "qwen3_14b": 15e9,
                "llava_next_34b": 35e9, "deepseek_7b": 6.9e9,
                "mamba2_130m": 0.13e9, "qwen3_1_7b": 2.0e9,
                "zamba2_7b": 6.8e9, "h2o_danube_3_4b": 4.0e9}
    for arch, want in expected.items():
        got = count_params(get_config(arch))
        assert abs(got - want) / want < 0.15, (arch, got, want)
