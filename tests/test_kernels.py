"""Pallas kernels vs pure-jnp oracles (interpret=True), sweeping shapes
and dtypes per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ac_cdf import cdf_points
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_intra

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,causal,window,blk", [
    (2, 4, 2, 64, 16, True, None, 16),
    (1, 4, 4, 128, 32, True, None, 32),
    (2, 2, 1, 64, 16, False, None, 16),
    (1, 4, 2, 128, 16, True, 24, 32),
    (1, 8, 2, 256, 64, True, None, 64),
])
def test_flash_attention(B, H, K, S, hd, causal, window, blk, dtype):
    q, k, v = (_rand((B, H, S, hd), dtype), _rand((B, K, S, hd), dtype),
               _rand((B, K, S, hd), dtype))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=blk, block_k=blk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,blk", [
    (2, 4, 2, 64, 16, 16), (3, 4, 4, 128, 32, 32), (2, 2, 1, 96, 16, 32),
    (1, 8, 8, 512, 64, 128),
])
def test_decode_attention(B, H, K, S, hd, blk, dtype):
    q = _rand((B, H, hd), dtype)
    kc, vc = _rand((B, K, S, hd), dtype), _rand((B, K, S, hd), dtype)
    lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,Q,H,P,N", [
    (2, 16, 3, 8, 4), (1, 32, 2, 16, 8), (2, 64, 4, 8, 16),
    (1, 128, 2, 32, 32),
])
def test_ssd_intra(B, Q, H, P, N):
    x = _rand((B, Q, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.8, (B, Q, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (H,)), jnp.float32)
    Bm, Cm = _rand((B, Q, N), jnp.float32), _rand((B, Q, N), jnp.float32)
    y, s = ssd_intra(x, dt, A, Bm, Cm, interpret=True)
    yr, sr = ref.ssd_intra_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-4)


@pytest.mark.parametrize("B,V,bv,prec", [
    (4, 256, 64, 16), (2, 1024, 256, 16), (1, 512, 512, 14),
    (3, 4096, 1024, 18),
])
def test_cdf_points(B, V, bv, prec):
    lg = jnp.asarray(RNG.normal(size=(B, V)) * 3, jnp.float32)
    pts = np.asarray(cdf_points(lg, prec, block_v=bv, interpret=True))
    want = np.asarray(ref.cdf_quantize_ref(
        jnp.exp(lg - lg.max(-1, keepdims=True)), prec))
    # strict coder invariants hold exactly; vs-ref tolerance 1 quantum
    assert (np.diff(pts, axis=-1) >= 1).all()
    assert (pts[:, -1] == (1 << prec)).all()
    assert np.abs(pts - want).max() <= 1


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    q = jnp.ones((1, 2, 8, 4))
    out = ops.flash_attention(q, q, q)
    assert out.shape == (1, 2, 8, 4)
