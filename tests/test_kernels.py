"""Pallas kernels vs pure-jnp oracles (interpret=True), sweeping shapes
and dtypes per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st
from repro.kernels import ref
from repro.kernels.ac_cdf import cdf_points, topk_cdf_points
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_intra

RNG = np.random.default_rng(0)

# Bit-identity comparisons must run the oracle under jit: the Pallas
# interpreter executes inside a jitted program, and XLA fusion moves
# float rounding by an ulp vs eager op-by-op execution — enough to flip
# a floor(x + 0.5) at a half-integer boundary.
_blocked_cdf_ref = jax.jit(ref.cdf_quantize_blocked_ref,
                           static_argnums=(1, 2))
_topk_ref = jax.jit(ref.topk_cdf_ref, static_argnums=(1, 2))
_topk_blocked_ref = jax.jit(ref.topk_cdf_blocked_ref,
                            static_argnums=(1, 2, 3))


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,causal,window,blk", [
    (2, 4, 2, 64, 16, True, None, 16),
    (1, 4, 4, 128, 32, True, None, 32),
    (2, 2, 1, 64, 16, False, None, 16),
    (1, 4, 2, 128, 16, True, 24, 32),
    (1, 8, 2, 256, 64, True, None, 64),
])
def test_flash_attention(B, H, K, S, hd, causal, window, blk, dtype):
    q, k, v = (_rand((B, H, S, hd), dtype), _rand((B, K, S, hd), dtype),
               _rand((B, K, S, hd), dtype))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=blk, block_k=blk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,blk", [
    (2, 4, 2, 64, 16, 16), (3, 4, 4, 128, 32, 32), (2, 2, 1, 96, 16, 32),
    (1, 8, 8, 512, 64, 128),
])
def test_decode_attention(B, H, K, S, hd, blk, dtype):
    q = _rand((B, H, hd), dtype)
    kc, vc = _rand((B, K, S, hd), dtype), _rand((B, K, S, hd), dtype)
    lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,Q,H,P,N", [
    (2, 16, 3, 8, 4), (1, 32, 2, 16, 8), (2, 64, 4, 8, 16),
    (1, 128, 2, 32, 32),
])
def test_ssd_intra(B, Q, H, P, N):
    x = _rand((B, Q, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.8, (B, Q, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (H,)), jnp.float32)
    Bm, Cm = _rand((B, Q, N), jnp.float32), _rand((B, Q, N), jnp.float32)
    y, s = ssd_intra(x, dt, A, Bm, Cm, interpret=True)
    yr, sr = ref.ssd_intra_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-4)


@pytest.mark.parametrize("B,V,bv,prec", [
    (4, 256, 64, 16), (2, 1024, 256, 16), (1, 512, 512, 14),
    (3, 4096, 1024, 18),
])
def test_cdf_points(B, V, bv, prec):
    lg = jnp.asarray(RNG.normal(size=(B, V)) * 3, jnp.float32)
    pts = np.asarray(cdf_points(lg, prec, block_v=bv, interpret=True))
    want = np.asarray(ref.cdf_quantize_ref(
        jnp.exp(lg - lg.max(-1, keepdims=True)), prec))
    # strict coder invariants hold exactly; vs-ref tolerance 1 quantum
    assert (np.diff(pts, axis=-1) >= 1).all()
    assert (pts[:, -1] == (1 << prec)).all()
    assert np.abs(pts - want).max() <= 1


@pytest.mark.parametrize("B,V,bv,prec", [
    (4, 256, 64, 16), (2, 1024, 256, 16), (1, 512, 512, 14),
    (3, 4096, 1024, 18),
])
def test_cdf_points_bitwise_vs_blocked_oracle(B, V, bv, prec):
    """The kernel's blocked float accumulation is replayed term-for-term
    by ref.cdf_quantize_blocked_ref — equality must be BIT-exact, not
    within a quantum."""
    lg = jnp.asarray(RNG.normal(size=(B, V)) * 3, jnp.float32)
    pts = np.asarray(cdf_points(lg, prec, block_v=bv, interpret=True))
    want = np.asarray(_blocked_cdf_ref(lg, prec, bv))
    assert np.array_equal(pts, want)


@pytest.mark.parametrize("case", ["peaky", "flat", "ramp", "padded"])
def test_cdf_points_tail_exact_drift_prone(case):
    """Regression for the tail-exactness bug: the old kernel clamped
    drifted points DOWN but never UP, so a float prefix that drifted low
    left cdf[-1] < 2**precision (an invalid coder CDF). Drift-prone
    shapes: near-delta pmfs (peaky), near-uniform across many blocks
    (flat/ramp), and padded-vocab tails of exact zeros."""
    B, V, bv, prec = 3, 4096, 128, 16      # 32 blocks: maximal carry drift
    rng = np.random.default_rng(7)
    if case == "peaky":
        lg = rng.standard_normal((B, V)).astype(np.float32) * 40.0
    elif case == "flat":
        lg = rng.standard_normal((B, V)).astype(np.float32) * 1e-3
    elif case == "ramp":
        lg = np.tile(np.linspace(-5, 5, V, dtype=np.float32), (B, 1))
    else:
        lg = rng.standard_normal((B, V)).astype(np.float32) * 3.0
        lg[:, V // 2:] = ref.NEG_INF       # upstream pad masking
    pts = np.asarray(cdf_points(jnp.asarray(lg), prec, block_v=bv,
                                interpret=True))
    assert (pts[:, -1] == (1 << prec)).all(), "tail must be exact"
    assert (np.diff(pts, axis=-1) >= 1).all(), "strictly increasing"
    assert (pts[:, 0] >= 1).all()
    want = np.asarray(_blocked_cdf_ref(jnp.asarray(lg), prec, bv))
    assert np.array_equal(pts, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([64, 128, 256]),
       st.sampled_from([12, 16, 20]))
def test_cdf_points_kernel_vs_host_property(seed, bv, prec):
    """Property: over randomized logits the kernel output is bit-identical
    to the blocked host oracle, and within one quantum of the flat host
    path (core.cdf cumulative rounding) with every coder invariant held
    absolutely."""
    rng = np.random.default_rng(seed)
    B, V = int(rng.integers(1, 5)), 1024
    scale = float(rng.uniform(0.01, 20.0))
    lg = jnp.asarray(rng.standard_normal((B, V)) * scale, jnp.float32)
    pts = np.asarray(cdf_points(lg, prec, block_v=bv, interpret=True))
    blocked = np.asarray(_blocked_cdf_ref(lg, prec, bv))
    assert np.array_equal(pts, blocked)
    flat = np.asarray(ref.cdf_quantize_ref(
        jnp.exp(lg - lg.max(-1, keepdims=True)), prec))
    assert (pts[:, -1] == (1 << prec)).all()
    assert (np.diff(pts, axis=-1) >= 1).all()
    assert np.abs(pts - flat).max() <= 1


@pytest.mark.parametrize("B,V,k,prec", [
    (4, 512, 16, 16), (2, 1024, 48, 16), (1, 256, 8, 14),
])
def test_topk_cdf_single_block_bitwise_vs_host(B, V, k, prec):
    """With one vocab block the fused kernel's reductions are the host's
    flat reductions — (ids, cdf) must match lax.top_k + core-style
    quantization bit-for-bit (this is what keeps golden containers
    byte-stable when the decode loops move onto the kernel)."""
    lg = jnp.asarray(RNG.normal(size=(B, V)) * 3, jnp.float32)
    ids, cdf = (np.asarray(a) for a in
                topk_cdf_points(lg, k, prec, interpret=True))
    ids_r, cdf_r = (np.asarray(a) for a in _topk_ref(lg, k, prec))
    assert np.array_equal(ids, ids_r)
    assert np.array_equal(cdf, cdf_r)
    from repro.core.cdf import topk_cdf_jit
    ids_c, cdf_c = (np.asarray(a) for a in topk_cdf_jit(lg, k, prec))
    assert np.array_equal(ids, ids_c)
    assert np.array_equal(cdf, cdf_c.astype(np.int32))


@pytest.mark.parametrize("B,V,k,bv,prec", [
    (4, 512, 16, 128, 16), (2, 1024, 32, 256, 16), (3, 512, 8, 64, 14),
])
def test_topk_cdf_blocked_bitwise_and_invariants(B, V, k, bv, prec):
    lg = jnp.asarray(RNG.normal(size=(B, V)) * 3, jnp.float32)
    ids, cdf = (np.asarray(a) for a in
                topk_cdf_points(lg, k, prec, block_v=bv, interpret=True))
    ids_b, cdf_b = (np.asarray(a) for a in
                    _topk_blocked_ref(lg, k, prec, bv))
    assert np.array_equal(ids, ids_b)
    assert np.array_equal(cdf, cdf_b)
    # the id SET always equals lax.top_k's (order can differ only via
    # value ties); the CDF is a valid coder table regardless
    ids_r, _ = _topk_ref(lg, k, prec)
    assert np.array_equal(np.sort(ids), np.sort(np.asarray(ids_r)))
    assert (cdf[:, 0] == 0).all()
    assert (cdf[:, -1] == (1 << prec)).all()
    assert (np.diff(cdf, axis=-1) >= 1).all()


def test_topk_cdf_padded_vocab():
    """Pad logits masked to NEG_INF never enter the top-k, and the CDF
    invariants survive an exactly-zero probability tail."""
    B, V, k, prec = 2, 512, 16, 16
    lg = (RNG.normal(size=(B, V)) * 3).astype(np.float32)
    lg[:, 400:] = ref.NEG_INF
    ids, cdf = (np.asarray(a) for a in
                topk_cdf_points(jnp.asarray(lg), k, prec, block_v=128,
                                interpret=True))
    assert (ids < 400).all()
    ids_r, cdf_r = (np.asarray(a) for a in
                    _topk_ref(jnp.asarray(lg), k, prec))
    assert np.array_equal(np.sort(ids), np.sort(ids_r))
    assert (cdf[:, -1] == (1 << prec)).all()
    assert (np.diff(cdf, axis=-1) >= 1).all()


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    q = jnp.ones((1, 2, 8, 4))
    out = ops.flash_attention(q, q, q)
    assert out.shape == (1, 2, 8, 4)


def test_ops_topk_cdf_dispatch():
    from repro.kernels import ops
    lg = jnp.asarray(RNG.normal(size=(2, 256)) * 3, jnp.float32)
    ids_r, cdf_r = (np.asarray(a) for a in _topk_ref(lg, 8, 16))
    for impl in ("ref", "interpret"):
        ids, cdf = (np.asarray(a) for a in
                    ops.topk_cdf(lg, 8, 16, impl=impl))
        assert np.array_equal(ids, ids_r), impl
        assert np.array_equal(cdf, cdf_r), impl
