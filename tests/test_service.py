"""Continuous-batching compression service: correctness and scheduling.

The load-bearing claims under test:

* a ragged workload (jobs with chunk counts 1..2B, partial final chunks)
  round-trips bit-exactly through the slot scheduler;
* service-compressed containers are byte-identical to LLMCompressor's
  v4 output and cross-decode with the grouped path in both directions,
  including at a *different* slot count than the encoder's batch;
* per-slot cache reset (serve/engine.reset_slots) is bit-exact with a
  fresh cache while neighbour lanes are mid-stream, for every cached
  model family;
* the scheduler spends fewer model steps than the naive grouped decoder
  on ragged traffic (the subsystem's reason to exist);
* corrupt streams and mismatched configs fail loudly, at submit time
  where possible.
"""
import numpy as np
import pytest

import jax

from helpers import GoldenPredictor, golden_tokens, tiny
from repro.core import ContainerError, LLMCompressor
from repro.models import init_params
from repro.serve.engine import ModelPredictor
from repro.service import CompressionService, SlotScheduler
from repro.service.session import COMPRESS, ChunkTask, Job


def _golden_service(slots=4, chunk=16, topk=8, **kw):
    return CompressionService(GoldenPredictor(), slots=slots,
                              chunk_size=chunk, topk=topk, **kw)


def _golden_compressor(chunk=16, topk=8, **kw):
    return LLMCompressor(GoldenPredictor(), chunk_size=chunk, topk=topk,
                         decode_batch=4, **kw)


def _model_pred(family="dense"):
    cfg = tiny(family, vocab_size=258)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ModelPredictor(params, cfg, bos_id=257)


# ------------------------------------------------------------ golden-model
def test_service_compress_matches_grouped_v4_bytes():
    """The scheduler's out-of-order, slot-flushed encoder must produce the
    exact container the lock-step grouped compressor writes."""
    toks = golden_tokens(100)
    blob_svc, stats = _golden_service().submit_compress(toks).result()
    blob_ref, _ = _golden_compressor(container_version=4).compress(toks)
    assert blob_svc == blob_ref
    assert stats.n_tokens == toks.size
    assert stats.payload_bytes + stats.header_bytes == len(blob_svc)


def test_v4_records_encode_batch():
    """The v4 footer records the encoder's lane count — the batch shape a
    decoder must run the model program at for bit-exact logits on
    non-batch-invariant (real) models. Advisory for the batch-invariant
    GoldenPredictor, load-bearing for production models (the CLI defaults
    its decode slot count to this field)."""
    from repro.core import read_index
    blob, _ = _golden_service(slots=5, chunk=16) \
        .submit_compress(golden_tokens(40)).result()
    assert read_index(blob).encode_batch == 5      # service: always slots
    blob4, _ = _golden_compressor(container_version=4) \
        .compress(golden_tokens(40))               # 3 chunks < decode_batch
    assert read_index(blob4).encode_batch == 3     # min(4, n_chunks)


def test_ragged_workload_bit_exact():
    """Acceptance: jobs with chunk counts 1..2B (B=4 slots) — including
    sub-chunk and partial-final-chunk jobs — all round-trip losslessly
    through one shared slot machine."""
    svc = _golden_service(slots=4, chunk=16)
    comp = _golden_compressor()
    rng = np.random.default_rng(0)
    sizes = [1, 7, 16, 33, 100, 55, 128, 17]        # 1..8 chunks at C=16
    datas = [rng.integers(0, 63, n).astype(np.int32) for n in sizes]
    handles = [svc.submit_compress(d, priority=i % 3)
               for i, d in enumerate(datas)]
    blobs = [h.result()[0] for h in handles]
    dec_handles = [svc.submit_decompress(b) for b in blobs]
    for d, b, h in zip(datas, blobs, dec_handles):
        assert np.array_equal(h.result(), d)
        assert np.array_equal(comp.decompress(b), d)
    assert svc.stats.chunks_completed == 2 * sum(-(-n // 16) for n in sizes)


def test_mixed_compress_decompress_same_batch():
    """Compress and decompress jobs interleave in the same model steps."""
    svc = _golden_service()
    rng = np.random.default_rng(1)
    toks = golden_tokens(90)
    blob, _ = _golden_compressor(container_version=4).compress(toks)
    d1 = rng.integers(0, 63, 70).astype(np.int32)
    hc = svc.submit_compress(d1)
    hd = svc.submit_decompress(blob)
    # both queued before any result is pulled: they share the batch
    assert np.array_equal(hd.result(), toks)
    blob1, _ = hc.result()
    assert np.array_equal(svc.submit_decompress(blob1).result(), d1)


def test_full_vocab_path_roundtrip():
    svc = _golden_service(slots=3, chunk=10, topk=0)
    rng = np.random.default_rng(2)
    d = rng.integers(0, 63, 47).astype(np.int32)
    blob, _ = svc.submit_compress(d).result()
    assert np.array_equal(svc.submit_decompress(blob).result(), d)


def test_empty_and_tiny_jobs():
    svc = _golden_service()
    h0 = svc.submit_compress(np.zeros(0, np.int32))
    blob0, stats0 = h0.result()
    assert stats0.n_tokens == 0
    assert np.array_equal(svc.submit_decompress(blob0).result(),
                          np.zeros(0, np.int32))
    h1 = svc.submit_compress(np.array([5], np.int32))
    blob1, _ = h1.result()
    assert np.array_equal(svc.submit_decompress(blob1).result(),
                          np.array([5], np.int32))


def test_occupancy_zero_without_steps():
    """SchedulerStats.occupancy on a scheduler that never stepped (or a
    service whose only jobs resolved at submit) is 0.0 — regression for
    the ZeroDivisionError when lane_steps == 0."""
    from repro.service.scheduler import SchedulerStats
    assert SchedulerStats().occupancy == 0.0
    svc = _golden_service()
    assert svc.stats.occupancy == 0.0           # no traffic at all
    blob, _ = svc.submit_compress(np.zeros(0, np.int32)).result()
    svc.submit_decompress(blob).result()        # resolved at submit
    assert svc.stats.model_steps == 0
    assert svc.stats.occupancy == 0.0


def test_legacy_ac_container_decodes_eagerly():
    toks = golden_tokens(60)
    ac_blob, _ = _golden_compressor(codec="ac").compress(toks)
    svc = _golden_service()
    h = svc.submit_decompress(ac_blob)
    assert h.done()                      # grouped path, resolved at submit
    assert np.array_equal(h.result(), toks)


def test_priority_orders_queue():
    """Lower priority value runs first: with a single slot, a later
    high-priority job completes before an earlier low-priority one."""
    sched = SlotScheduler(GoldenPredictor(), n_slots=1, chunk_size=8,
                          topk=8)
    order = []

    def mk(tag, seed):
        job = Job(0, COMPRESS, 0, 1, 8, lambda streams: order.append(tag))
        return ChunkTask(job, 0, COMPRESS, 8,
                         tokens=golden_tokens(8, seed=seed))
    sched.submit(mk("low", 11), priority=5)
    sched.submit(mk("high", 22), priority=-5)
    sched.run()
    assert order == ["high", "low"]


class CountingPredictor(GoldenPredictor):
    """GoldenPredictor that counts decode_step invocations."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.n_steps = 0

    def decode_step(self, state, prev_tokens):
        self.n_steps += 1
        return super().decode_step(state, prev_tokens)


def test_scheduler_beats_grouped_steps_on_ragged():
    """The reason the subsystem exists: on ragged jobs the grouped
    decoder runs each job's groups to valid.max() with idle lanes; the
    slot machine refills immediately and spends fewer model steps."""
    rng = np.random.default_rng(3)
    C, B = 16, 4
    sizes = [1 + int(rng.integers(0, 2 * B * C)) for _ in range(12)]
    datas = [rng.integers(0, 63, n).astype(np.int32) for n in sizes]
    pred = CountingPredictor()
    comp = LLMCompressor(pred, chunk_size=C, topk=8, decode_batch=B,
                         container_version=4)
    blobs = [comp.compress(d)[0] for d in datas]
    pred.n_steps = 0
    for b, d in zip(blobs, datas):          # naive: one grouped job at a time
        assert np.array_equal(comp.decompress(b), d)
    naive_steps = pred.n_steps
    svc = CompressionService(pred, slots=B, chunk_size=C, topk=8)
    handles = [svc.submit_decompress(b) for b in blobs]
    for h, d in zip(handles, datas):
        assert np.array_equal(h.result(), d)
    assert svc.stats.model_steps < naive_steps, \
        (svc.stats.model_steps, naive_steps)
    assert svc.stats.occupancy > 0.75


# -------------------------------------------------------------- real model
@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_slot_reset_bit_exact_mid_stream(family):
    """reset_slots on a mid-stream batch reproduces fresh-cache logits
    bit-exactly on the reset lanes — the primitive continuous batching
    stands on."""
    pred = _model_pred(family)
    pred.set_decode_len(8)
    B = 4
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 256, (B, 8)).astype(np.int32)
    cache = pred.begin_decode(B)
    prev = np.full((B,), 257, np.int32)
    ref = []
    for t in range(5):
        lg, cache = pred.decode_step(cache, prev)
        ref.append(lg)
        prev = toks[:, t]
    cache = pred.begin_decode(B)
    prev = np.full((B,), 257, np.int32)
    for t in range(3):
        lg, cache = pred.decode_step(cache, prev)
        prev = toks[:, t]
    mask = np.array([False, True, True, False])
    cache = pred.reset_slots(cache, mask)
    prev = np.where(mask, 257, prev).astype(np.int32)
    for t in range(5):
        lg, cache = pred.decode_step(cache, prev)
        assert np.array_equal(lg[1], ref[t][1])
        assert np.array_equal(lg[2], ref[t][2])
        prev = np.where(mask, toks[:, t], 0).astype(np.int32)


def test_service_real_model_ragged_roundtrip():
    """End-to-end with a jitted model: ragged jobs through the service,
    cross-decoded against the grouped compressor, plus decode at a slot
    count different from the encoder's batch shape."""
    pred = _model_pred("dense")
    svc = CompressionService(pred, slots=4, chunk_size=16, topk=8)
    comp = LLMCompressor(pred, chunk_size=16, topk=8, decode_batch=4,
                         container_version=4)
    rng = np.random.default_rng(3)
    datas = [rng.integers(0, 256, n).astype(np.int32)
             for n in (5, 33, 90, 64)]
    handles = [svc.submit_compress(d) for d in datas]
    blobs = [h.result()[0] for h in handles]
    for d, b in zip(datas, blobs):
        assert np.array_equal(comp.decompress(b), d)
        assert np.array_equal(svc.submit_decompress(b).result(), d)
    # different fixed shape than the 4-lane encoder program
    svc6 = CompressionService(pred, slots=6, chunk_size=16, topk=8)
    assert np.array_equal(svc6.submit_decompress(blobs[2]).result(),
                          datas[2])


# ------------------------------------------------------------ error paths
def test_submit_rejects_mismatched_container():
    toks = golden_tokens(40)
    blob, _ = _golden_compressor(chunk=16).compress(toks)
    svc = _golden_service(chunk=32)          # wrong chunk size
    with pytest.raises(ContainerError):
        svc.submit_decompress(blob)


def test_short_stream_rejected_at_submit():
    """A corrupt length varint can yield a stream shorter than the rANS
    state flush; that must fail at submit with ContainerError — not
    mid-step with a bare ValueError and a stranded slot."""
    from repro.core.compressor import CODEC_RANS, write_container
    svc = _golden_service(slots=2, chunk=16)
    blob = write_container([b"xx"], version=3, chunk_size=16, n_tokens=5,
                           vocab=svc.predictor.vocab_size, topk=8,
                           precision=svc.precision, codec_id=CODEC_RANS)
    with pytest.raises(ContainerError, match="cannot code"):
        svc.submit_decompress(blob)


def test_corrupt_v3_stream_fails_loudly():
    """v3 has no checksums, but a bit-flipped rANS stream leaves the coder
    state dirty at end-of-chunk — the scheduler's exhaustion check turns
    that into ContainerError instead of silently wrong tokens."""
    toks = golden_tokens(64)
    blob, _ = _golden_compressor().compress(toks)     # v3, rans
    bad = bytearray(blob)
    bad[len(bad) // 2] ^= 0x10                        # flip inside a stream
    svc = _golden_service()
    got_error = False
    try:
        out = svc.submit_decompress(bytes(bad)).result()
        got_error = not np.array_equal(out, toks)     # wrong-token detect
    except ContainerError:
        got_error = True
    assert got_error
