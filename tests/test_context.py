"""Carried-context ratio engine (ISSUE 9 / DESIGN.md §12).

The load-bearing claims under test:

* **recipe plans** split chunks into striped carry chains whose heads
  start fresh (or from the shared prefix), and the encode-side context
  materialization clamps carry windows to what the predecessor really
  held — the same helper the service uses, so encode can't drift from
  the format;
* carried and shared-prefix containers round-trip **bit-exactly** at
  ANY decode slot count and through ``decompress_range`` over every
  chunk interval — a recipe never makes a chunk depend on state the
  ranged decoder can't reconstruct;
* all-fallback v5/v6 archives decompress and range-decode fully
  **model-free**: no predictor method is called, no prefix-cache entry
  is touched (the regression fixed in this PR);
* the **radix prefix cache** returns the deepest stored ancestor,
  splits edges on divergence, evicts LRU by stored-token budget, and
  counts hits/misses/evictions/tokens-reused;
* engine-level **prefill-from-prefix** is bit-identical to feeding the
  prefix through sequential ``decode_step`` calls, and a
  snapshot/restore of a post-prefill lane reproduces the same decode
  stream — the invariant that makes cache reuse lossless;
* the scheduler skips prefill steps for cache hits on shared-prefix
  jobs, and the archives it writes still round-trip bit-exactly.
"""
import numpy as np
import pytest

import jax

from _hypo import given, settings, st
from helpers import (GoldenPredictor, golden_self_tokens, golden_text_tokens,
                     golden_tokens, tiny)
from repro.core import (ContainerError, LLMCompressor, RECIPE_CARRY,
                        RECIPE_NONE, RECIPE_SHARED, RouterConfig,
                        assign_context_recipes, container_is_model_free,
                        decompress_model_free, decompress_range_model_free,
                        read_index, recipe_context)
from repro.models import init_params
from repro.serve.engine import ModelPredictor
from repro.service import CompressionService, RadixPrefixCache

VOCAB = 64


def _comp(**kw):
    base = dict(chunk_size=16, decode_batch=4, topk=8, codec="rans",
                container_version=6)
    base.update(kw)
    return LLMCompressor(GoldenPredictor(), **base)


def _model_pred():
    cfg = tiny("dense", vocab_size=258)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ModelPredictor(params, cfg, bos_id=257)


# ------------------------------------------------------------ recipe plans
def test_assign_context_recipes_plan():
    assert assign_context_recipes(0) == []
    assert assign_context_recipes(3) == [(RECIPE_NONE, 0)] * 3
    assert assign_context_recipes(3, shared=True) == [(RECIPE_SHARED, 0)] * 3
    # 5 chunks over 2 stripes: chain lengths 3 + 2, heads fresh
    assert assign_context_recipes(5, context_window=8, stripes=2) == [
        (RECIPE_NONE, 0), (RECIPE_CARRY, 8), (RECIPE_CARRY, 8),
        (RECIPE_NONE, 0), (RECIPE_CARRY, 8)]
    # shared heads chain into carries
    assert assign_context_recipes(4, context_window=4, stripes=1,
                                  shared=True) == [
        (RECIPE_SHARED, 0)] + [(RECIPE_CARRY, 4)] * 3
    # more stripes than chunks degrades to all-heads (no carry at all)
    assert assign_context_recipes(2, context_window=4, stripes=8) == \
        [(RECIPE_NONE, 0)] * 2


def test_recipe_context_materialization():
    chunks = np.arange(32, dtype=np.int32).reshape(2, 16)
    valid = np.array([16, 10])
    recipes = [(RECIPE_NONE, 0), (RECIPE_CARRY, 6)]
    assert recipe_context(recipes, chunks, valid, 0, []).size == 0
    np.testing.assert_array_equal(
        recipe_context(recipes, chunks, valid, 1, []), np.arange(10, 16))
    # a window wider than the predecessor clamps to its valid tokens
    recipes[1] = (RECIPE_CARRY, 99)
    np.testing.assert_array_equal(
        recipe_context(recipes, chunks, valid, 1, []), np.arange(16))
    sp = [("s", np.array([5, 6], np.int32))]
    np.testing.assert_array_equal(
        recipe_context([(RECIPE_SHARED, 0)], chunks, valid, 0, sp), [5, 6])


def test_context_config_validation():
    with pytest.raises(ValueError, match="v6"):
        _comp(container_version=5, context_window=4)
    with pytest.raises(ValueError, match="outside"):
        _comp(context_window=-1)
    with pytest.raises(ValueError, match="vocab"):
        _comp(shared_prefix=np.array([999]))
    with pytest.raises(ValueError, match="tokens"):
        _comp(shared_prefix=np.zeros(0, np.int64))


# ----------------------------------------------------- carried round-trips
@settings(max_examples=12, deadline=None)
@given(st.integers(17, 90), st.integers(1, 12), st.integers(1, 4),
       st.integers(0, 2 ** 20))
def test_carried_roundtrip_bit_exact_across_slot_counts(n, W, S, seed):
    """The property the format is built on: a carried archive decodes
    bit-exactly regardless of the decoder's slot count (1, 3, 8 — none
    equal to the encoder's), and every chunk interval range-decodes to
    the matching slice. Carry chains are per-lane self-contained, so the
    recorded recipes + lane count pin the token streams exactly."""
    toks = golden_self_tokens(n, seed=seed)
    blob, _ = _comp(context_window=W, context_stripes=S).compress(toks)
    info = read_index(blob)
    if info.n_chunks > S:
        assert any(e.recipe_kind == RECIPE_CARRY for e in info.entries)
    for B in (1, 3, 8):
        assert np.array_equal(_comp(decode_batch=B).decompress(blob), toks)
    dec = _comp(decode_batch=2)
    full = dec.decompress(blob)
    assert np.array_equal(full, toks)
    C = info.chunk_size
    for lo in range(info.n_chunks):
        for hi in {lo + 1, info.n_chunks}:
            part = dec.decompress_range(blob, lo, hi)
            assert np.array_equal(part, full[lo * C:min(hi * C, n)]), \
                (lo, hi)


def test_shared_prefix_roundtrip_and_index():
    sp = golden_self_tokens(24, seed=5)
    toks = golden_self_tokens(70, seed=6)
    comp = _comp(shared_prefix=sp, shared_prefix_name="sys")
    blob, _ = comp.compress(toks)
    info = read_index(blob)
    assert [n for n, _ in info.shared_prefixes] == ["sys"]
    np.testing.assert_array_equal(info.shared_prefixes[0][1], sp)
    assert all(e.recipe_kind == RECIPE_SHARED for e in info.entries)
    assert np.array_equal(_comp().decompress(blob), toks)
    # a single-chunk range decode needs only the dictionary
    assert np.array_equal(_comp().decompress_range(blob, 1, 2), toks[16:32])


def test_shared_prefix_plus_carry_roundtrip():
    """Both recipe kinds in one archive: shared heads, carry bodies."""
    sp = golden_self_tokens(12, seed=7)
    toks = golden_self_tokens(100, seed=8)
    comp = _comp(shared_prefix=sp, context_window=10, context_stripes=2)
    blob, _ = comp.compress(toks)
    kinds = {e.recipe_kind for e in read_index(blob).entries}
    assert kinds == {RECIPE_SHARED, RECIPE_CARRY}
    assert np.array_equal(_comp(decode_batch=3).decompress(blob), toks)


# ------------------------------------------------------ model-free decode
class _NoModel(GoldenPredictor):
    """Explodes on every model entry point — proves a decode path never
    touched the model."""

    def score_chunks(self, *a, **k):
        raise AssertionError("model touched: score_chunks")

    def begin_decode(self, *a, **k):
        raise AssertionError("model touched: begin_decode")

    def decode_step(self, *a, **k):
        raise AssertionError("model touched: decode_step")

    def snapshot_slot(self, *a, **k):
        raise AssertionError("model touched: snapshot_slot")


@pytest.mark.parametrize("version", [5, 6])
def test_all_fallback_archive_decodes_model_free(version):
    """Regression (ISSUE 9 bugfix): an archive whose every chunk is
    fallback-coded decodes and range-decodes with no model at all —
    the module-level helpers need no predictor, and a compressor whose
    predictor explodes on any model call still decodes it."""
    toks = golden_text_tokens()
    kw = dict(route="lzma", chunk_size=64, container_version=version)
    if version == 6:
        kw.update(context_window=8, context_stripes=2)
    blob, _ = _comp(**kw).compress(toks)
    info = read_index(blob)
    assert container_is_model_free(info)
    # forced-fallback chunks are context-free by format law, even though
    # the encoder was configured with a carried-context plan
    assert all(e.recipe_kind == RECIPE_NONE for e in info.entries)
    assert np.array_equal(decompress_model_free(blob), toks)
    assert np.array_equal(decompress_range_model_free(blob, 1, 3),
                          toks[64:192])
    dead = LLMCompressor(_NoModel(), chunk_size=64, decode_batch=4, topk=8)
    assert np.array_equal(dead.decompress(blob), toks)
    assert np.array_equal(dead.decompress_range(blob, 0, 2), toks[:128])


def test_service_decodes_all_fallback_without_model_or_cache():
    """The service path of the same regression: submit_decompress on an
    all-fallback archive resolves without a model step, a prefill, or a
    prefix-cache touch."""
    toks = golden_text_tokens()
    blob, _ = _comp(route="lzma", chunk_size=64, container_version=6,
                    context_window=8).compress(toks)
    svc = CompressionService(_NoModel(), slots=4, chunk_size=64, topk=8)
    got = svc.submit_decompress(blob).result()
    assert np.array_equal(got, toks)
    snap = svc.snapshot()["prefix_cache"]
    assert snap["hits"] == 0 and snap["misses"] == 0
    assert svc.stats.model_steps == 0 and svc.stats.prefill_steps == 0


def test_model_free_helpers_reject_llm_chunks():
    toks = golden_self_tokens(40, seed=3)
    blob, _ = _comp().compress(toks)
    assert not container_is_model_free(read_index(blob))
    with pytest.raises(ContainerError, match="model"):
        decompress_model_free(blob)


# ------------------------------------------------------- radix prefix cache
def test_radix_cache_lookup_insert_split():
    c = RadixPrefixCache(capacity_tokens=1000)
    a = np.arange(10, dtype=np.int32)
    c.insert(a, "A")
    assert len(c) == 1 and c.size_tokens == 10
    # exact hit, and a query that EXTENDS the stored prefix still hits it
    assert c.lookup(a) == (10, "A")
    assert c.lookup(np.concatenate([a, [99]])) == (10, "A")
    # a strict prefix of the stored key has no stored ancestor
    assert c.lookup(a[:5]) == (0, None)
    # diverging insert splits the edge; both keys stay retrievable
    b = np.concatenate([a[:5], [50, 51]]).astype(np.int32)
    c.insert(b, "B")
    assert c.lookup(a) == (10, "A")
    assert c.lookup(b) == (7, "B")
    # the split midpoint is a skeleton node, not a stored value
    assert c.lookup(a[:5]) == (0, None)
    # deepest stored ancestor wins when several lie on the path
    c.insert(a[:5], "MID")
    assert c.lookup(a) == (10, "A")
    assert c.lookup(np.concatenate([a[:5], [77]])) == (5, "MID")
    assert len(c) == 3 and c.size_tokens == 22


def test_radix_cache_lru_eviction_and_counters():
    c = RadixPrefixCache(capacity_tokens=25)
    a = np.arange(0, 10, dtype=np.int32)
    b = np.arange(20, 30, dtype=np.int32)
    c.insert(a, "A")
    c.insert(b, "B")
    assert c.lookup(a) == (10, "A")      # touch A: B becomes LRU
    d = np.arange(40, 50, dtype=np.int32)
    c.insert(d, "D")                     # 30 tokens > 25: evict B
    assert c.lookup(b) == (0, None)
    assert c.lookup(a) == (10, "A") and c.lookup(d) == (10, "D")
    assert c.size_tokens == 20
    assert c._c_evict.value == 1
    assert c._c_hits.value == 3 and c._c_misses.value == 1
    c.clear()
    assert len(c) == 0 and c.size_tokens == 0
    assert c.lookup(a) == (0, None)
    # an entry larger than the whole budget is still stored (capacity
    # bounds the steady state, never rejects the working set's newest)
    c.insert(np.arange(100, dtype=np.int32), "BIG")
    assert c.lookup(np.arange(100, dtype=np.int32))[0] == 100


def test_radix_cache_validates():
    with pytest.raises(ValueError, match="positive"):
        RadixPrefixCache(capacity_tokens=0)
    c = RadixPrefixCache()
    with pytest.raises(ValueError, match="empty"):
        c.insert(np.zeros(0, np.int32), "X")


# ------------------------------------------- engine prefill-from-prefix
def test_prefill_matches_sequential_decode_bit_exact():
    """begin_decode(prefix=...) must leave the KV cache in EXACTLY the
    state sequential decode_step calls produce — same jitted program,
    same reduction order — so carried encode and decode see identical
    distributions. Checked on logits, not argmax: bit-equality is the
    coder's actual requirement."""
    pred = _model_pred()
    pred.set_decode_len(48)
    prefix = np.array([[3, 1, 4, 1, 5, 9, 2, 6],
                       [2, 7, 1, 8, 2, 8, 1, 8]], np.int32)
    cont = np.array([[5, 3, 5], [9, 7, 9]], np.int32)
    # reference: feed [BOS, prefix] one token at a time
    state = pred.begin_decode(2)
    prev = np.full(2, pred.bos_id, np.int32)
    for t in range(prefix.shape[1]):
        _, state = pred.decode_step(state, prev)
        prev = prefix[:, t]
    ref = []
    for t in range(cont.shape[1]):
        logits, state = pred.decode_step(state, prev)
        ref.append(np.asarray(logits))
        prev = cont[:, t]
    # prefilled: the cache consumed [BOS, prefix[:-1]]; prefix[-1] is
    # the first decode input (the convention score/encode rely on)
    state2 = pred.begin_decode(2, prefix=prefix)
    prev2 = prefix[:, -1]
    for t in range(cont.shape[1]):
        logits2, state2 = pred.decode_step(state2, prev2)
        assert np.array_equal(np.asarray(logits2), ref[t]), t
        prev2 = cont[:, t]
    # 1-D prefix broadcasts across lanes
    state3 = pred.begin_decode(2, prefix=prefix[0])
    logits3, _ = pred.decode_step(state3, np.repeat(prefix[0, -1], 2))
    assert np.array_equal(np.asarray(logits3)[0], np.asarray(logits3)[1])


def test_snapshot_restore_slot_bit_exact():
    """A lane snapshot taken after prefill, restored into a DIFFERENT
    decode state, continues with bit-identical logits — the property the
    radix cache's reuse depends on."""
    pred = _model_pred()
    pred.set_decode_len(32)
    prefix = np.array([7, 3, 7, 3, 7, 1], np.int32)
    sA = pred.begin_decode(2, prefix=prefix)
    snap = pred.snapshot_slot(sA, 1)
    ref, _ = pred.decode_step(sA, np.repeat(prefix[-1], 2))
    # fresh state, garbage in every lane, then restore into lane 0 only
    sB = pred.begin_decode(2)
    for tok in (9, 4, 4):
        _, sB = pred.decode_step(sB, np.repeat(tok, 2))
    sB = pred.reset_slots(sB, np.array([True, True]))
    sB = pred.restore_slot(sB, snap, np.array([True, False]))
    got, _ = pred.decode_step(sB, np.repeat(prefix[-1], 2))
    assert np.array_equal(np.asarray(got)[0], np.asarray(ref)[1])


def test_model_carried_roundtrip():
    """End-to-end on a real jitted model: carried + shared context
    round-trips bit-exactly, including through decompress_range."""
    pred = _model_pred()
    rng = np.random.default_rng(11)
    toks = rng.integers(0, 200, 70).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=16, decode_batch=4, topk=12,
                         container_version=6, context_window=8,
                         context_stripes=2,
                         shared_prefix=np.arange(10, dtype=np.int32))
    blob, _ = comp.compress(toks)
    info = read_index(blob)
    assert {e.recipe_kind for e in info.entries} == \
        {RECIPE_SHARED, RECIPE_CARRY}
    dec = LLMCompressor(_model_pred(), chunk_size=16, decode_batch=3,
                        topk=12)
    assert np.array_equal(dec.decompress(blob), toks)
    assert np.array_equal(dec.decompress_range(blob, 2, 4), toks[32:64])


# ------------------------------------------------- service + prefix cache
def test_service_shared_prefix_jobs_hit_cache_and_roundtrip():
    """Shared-prefix jobs through the scheduler: later slots restore the
    cached post-prefill snapshot instead of re-running prefill (hits > 0,
    prefill steps strictly below the cache-off run), and every archive
    still round-trips bit-exactly."""
    sp = golden_self_tokens(20, seed=41)
    jobs = [golden_self_tokens(48, seed=50 + i) for i in range(4)]

    def run(cache_on):
        svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=16,
                                 topk=8)
        if not cache_on:
            svc.scheduler.prefix_cache = None
        handles = [svc.submit_compress(t, shared_prefix=sp) for t in jobs]
        blobs = [h.result()[0] for h in handles]
        return svc, blobs

    svc_on, blobs_on = run(True)
    svc_off, blobs_off = run(False)
    assert blobs_on == blobs_off        # the cache changes compute only
    for blob, toks in zip(blobs_on, jobs):
        info = read_index(blob)
        assert all(e.recipe_kind == RECIPE_SHARED for e in info.entries)
        assert np.array_equal(_comp().decompress(blob), toks)
    snap = svc_on.snapshot()["prefix_cache"]
    assert snap["hits"] > 0 and snap["tokens_reused"] > 0
    assert snap["entries"] >= 1
    assert 0 < svc_on.stats.prefill_steps < svc_off.stats.prefill_steps
    off = svc_off.snapshot()["prefix_cache"]
    assert off["hits"] == 0 and off["misses"] == 0


# ------------------------------------------------------------------ CLI
def _cli_setup(tmp_path, monkeypatch, n=64):
    import repro.cli as cli
    pred = GoldenPredictor(vocab_size=258, seed=0)
    monkeypatch.setattr(cli, "_predictor", lambda name: pred)
    data = np.random.default_rng(19).integers(
        0, 200, n, dtype=np.uint8).tobytes()
    src = tmp_path / "data.bin"
    src.write_bytes(data)
    return cli, data, src


def test_cli_context_window_writes_v6_and_info_prints_recipes(
        tmp_path, monkeypatch, capsys):
    """`llmc compress --context-window` produces a carried v6 archive;
    `llmc info` prints the per-chunk recipe column, the context mix, and
    the (empty) prefix dictionary."""
    cli, data, src = _cli_setup(tmp_path, monkeypatch)
    arc, out = tmp_path / "a.llmc", tmp_path / "out.bin"
    # --slots bounds the stripe count: 2 stripes over 4 chunks makes
    # genuine carry chains (at the 16-slot default every chunk would
    # head its own one-chunk chain and no carry recipe would survive)
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--context-window", "8",
                     "--slots", "2"]) == 0
    blob = arc.read_bytes()
    assert blob[4] == 6 and blob[-4:] == b"LC6F"
    assert any(e.recipe_kind == RECIPE_CARRY
               for e in read_index(blob).entries)
    assert cli.main(["info", str(arc)]) == 0
    shown = capsys.readouterr().out
    assert "context" in shown and "carry(8)" in shown
    assert "contexts:" in shown
    assert "shared prefixes: none" in shown
    assert cli.main(["decompress", str(arc), str(out)]) == 0
    assert out.read_bytes() == data


def test_cli_shared_prefix_file_roundtrip_and_info(
        tmp_path, monkeypatch, capsys):
    cli, data, src = _cli_setup(tmp_path, monkeypatch)
    pref = tmp_path / "sys.txt"
    pref.write_bytes(b"system: compress nicely")
    arc, out = tmp_path / "a.llmc", tmp_path / "out.bin"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--shared-prefix", str(pref)]) == 0
    info = read_index(arc.read_bytes())
    assert len(info.shared_prefixes) == 1
    assert all(e.recipe_kind == RECIPE_SHARED for e in info.entries)
    assert cli.main(["info", str(arc)]) == 0
    shown = capsys.readouterr().out
    assert "shared prefix [0]:" in shown and "23 tokens" in shown
    assert cli.main(["decompress", str(arc), str(out)]) == 0
    assert out.read_bytes() == data


def test_cli_sidecar_records_chunk_context(tmp_path, monkeypatch, capsys):
    """The JSON sidecar carries each chunk's recipe so offline analysis
    can segment ratio by context kind."""
    import json
    cli, data, src = _cli_setup(tmp_path, monkeypatch)
    arc = tmp_path / "a.llmc"
    assert cli.main(["compress", str(src), str(arc), "--chunk", "16",
                     "--topk", "8", "--context-window", "8",
                     "--slots", "2", "--sidecar"]) == 0
    side = tmp_path / "a.llmc.diag.json"
    assert side.exists()
    diag = json.loads(side.read_text())
    ctxs = [c.get("context") for c in diag["chunks"]]
    assert any(c == "carry(8)" for c in ctxs)


def test_cli_context_flags_reject_non_service_paths(tmp_path, monkeypatch):
    cli, data, src = _cli_setup(tmp_path, monkeypatch)
    arc = tmp_path / "a.llmc"
    with pytest.raises(SystemExit, match="context"):
        cli.main(["compress", str(src), str(arc), "--v3",
                  "--context-window", "4"])
    pref = tmp_path / "p.bin"
    pref.write_bytes(b"pp")
    with pytest.raises(SystemExit, match="context"):
        cli.main(["compress", str(src), str(arc), "--codec", "ac",
                  "--shared-prefix", str(pref)])


def test_service_carried_compress_matches_grouped_bytes():
    """The scheduler's carried encode writes byte-identical containers
    to the grouped compressor's for the same context plan — the service
    reuses assign_context_recipes/recipe_context, so the two paths
    cannot drift."""
    toks = golden_self_tokens(90, seed=61)
    svc = CompressionService(GoldenPredictor(), slots=4, chunk_size=16,
                             topk=8)
    blob_svc, _ = svc.submit_compress(toks, context_window=6).result()
    ref = _comp(context_window=6, context_stripes=4)
    blob_ref, _ = ref.compress(toks)
    assert blob_svc == blob_ref
    assert np.array_equal(_comp().decompress(blob_svc), toks)
