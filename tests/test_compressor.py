"""Lossless round-trip of the full LLM compressor across model families."""
import numpy as np
import pytest

from helpers import tiny
from repro.core import LLMCompressor
from repro.models import init_params
from repro.serve.engine import ModelPredictor

import jax


def _pred(family, **kw):
    cfg = tiny(family, vocab_size=258, **kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    extra = {}
    if family == "encdec":
        extra["frames"] = jax.random.normal(jax.random.PRNGKey(9),
                                            (1, 8, cfg.d_model))
    return ModelPredictor(params, cfg, bos_id=257, extra_batch=extra)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_roundtrip_families(family):
    pred = _pred(family)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, 300).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=32, topk=16, decode_batch=8)
    blob, stats = comp.compress(data)
    out = comp.decompress(blob)
    assert np.array_equal(out, data)
    assert stats.n_tokens == data.size


@pytest.mark.parametrize("codec", ["ac", "rans"])
def test_roundtrip_codecs(codec):
    """Both entropy backends round-trip the same model; the container
    advertises the codec and the sizes agree to per-chunk overhead."""
    pred = _pred("dense")
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, 200).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=32, topk=16, decode_batch=4,
                         codec=codec)
    blob, _ = comp.compress(data)
    assert blob[19] == {"ac": 0, "rans": 1}[codec]
    assert np.array_equal(comp.decompress(blob), data)


def test_codecs_cross_decode_via_container():
    """A compressor configured for one codec decodes a container written
    by the other — the codec travels in the header, not the object."""
    pred = _pred("dense")
    rng = np.random.default_rng(11)
    data = rng.integers(0, 256, 90).astype(np.int32)
    ac_comp = LLMCompressor(pred, chunk_size=32, topk=16, decode_batch=4,
                            codec="ac")
    rans_comp = LLMCompressor(pred, chunk_size=32, topk=16, decode_batch=4,
                              codec="rans")
    assert np.array_equal(rans_comp.decompress(ac_comp.compress(data)[0]),
                          data)
    assert np.array_equal(ac_comp.decompress(rans_comp.compress(data)[0]),
                          data)


def test_unknown_codec_rejected():
    pred = _pred("dense")
    with pytest.raises(ValueError):
        LLMCompressor(pred, chunk_size=32, codec="huffman")


def test_roundtrip_full_vocab_path():
    pred = _pred("dense")
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, 150).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=25, topk=0, decode_batch=4)
    out = comp.decompress(comp.compress(data)[0])
    assert np.array_equal(out, data)


def test_roundtrip_trained_model_beats_gzip():
    """The central claim at micro scale: model-generated text is highly
    compressible by the model."""
    from repro.core.baselines import gzip_ratio
    pred = _pred("dense")
    # "train-free" analog: generate at low temperature => low entropy for
    # the SAME model; compression must exploit it losslessly.
    gen = pred.generate(400, batch=2, temperature=0.15, seed=1,
                        vocab_limit=256)
    data = gen.ravel()
    comp = LLMCompressor(pred, chunk_size=64, topk=32, decode_batch=8)
    blob, stats = comp.compress(data)
    out = comp.decompress(blob)
    assert np.array_equal(out, data)
    ratio = data.size / len(blob)
    graw = gzip_ratio(bytes(bytearray(data.astype(np.uint8))))
    # an untrained model at low temperature emits low-entropy text that the
    # SAME model compresses well — the paper's mechanism in miniature
    assert ratio > 1.2, ratio
    assert ratio > graw * 0.9, (ratio, graw)


def test_container_rejects_mismatched_config():
    pred = _pred("dense")
    comp = LLMCompressor(pred, chunk_size=32, topk=16)
    blob, _ = comp.compress(np.arange(40, dtype=np.int32) % 250)
    other = LLMCompressor(pred, chunk_size=64, topk=16)
    with pytest.raises(ValueError):
        other.decompress(blob)
    with pytest.raises(ValueError):
        comp.decompress(b"XXXX" + blob[4:])


def test_escape_heavy_stream_lossless():
    """Worst case: random data, tiny top-k => mostly escapes; still exact."""
    pred = _pred("dense")
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, 120).astype(np.int32)
    comp = LLMCompressor(pred, chunk_size=30, topk=2, decode_batch=4)
    blob, stats = comp.compress(data)
    assert stats.n_escapes > 0
    assert np.array_equal(comp.decompress(blob), data)
