"""Train a predictor LM with the production training stack (checkpointing,
auto-resume, watchdog fault tolerance, grad compression).

  PYTHONPATH=src:. python examples/train_lm.py
Equivalent to:
  python -m repro.launch.train --arch qwen3_1_7b --smoke --steps 100 \
      --ckpt-dir /tmp/lm_ckpt --watchdog
"""
import sys

sys.path[:0] = ["src", "."]
sys.argv = [sys.argv[0], "--arch", "qwen3_1_7b", "--smoke",
            "--steps", "60", "--batch", "8", "--seq-len", "128",
            "--ckpt-dir", "/tmp/lm_ckpt", "--ckpt-every", "20"]

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
