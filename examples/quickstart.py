"""Quickstart: train a small byte-level predictor, generate 'LLM text',
compress it losslessly with the model, compare against gzip.

  PYTHONPATH=src:. python examples/quickstart.py
"""
import sys
import time

sys.path[:0] = ["src", "."]
import numpy as np


def main():
    from benchmarks.prep import predictor, llm_dataset
    from repro.core import LLMCompressor
    from repro.core.baselines import gzip_ratio
    from repro.data.tokenizer import encode

    print("loading/training predictor (cached after first run)...")
    pred = predictor("pred-small")
    data = llm_dataset("wiki", 2048, gen_model="pred-small")
    print(f"sample: {data[:80]!r}...")

    comp = LLMCompressor(pred, chunk_size=64, topk=32, decode_batch=16)
    t0 = time.time()
    blob, stats = comp.compress(encode(data))
    print(f"compressed {len(data)}B -> {len(blob)}B "
          f"(ratio {len(data)/len(blob):.2f}x) in {time.time()-t0:.1f}s; "
          f"gzip gets {gzip_ratio(data):.2f}x")
    out = comp.decompress(blob)
    assert np.array_equal(out, encode(data)), "round-trip failed!"
    print("lossless round-trip verified.")


if __name__ == "__main__":
    main()
