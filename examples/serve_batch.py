"""Serve mixed compression traffic from one process: many concurrent
compress AND decompress jobs of different lengths multiplexed through
the continuous-batching service — one jitted model program, fixed batch
shape, slots refilled from the priority queue as chunk-streams finish.

  PYTHONPATH=src:. python examples/serve_batch.py
"""
import sys
import time

sys.path[:0] = ["src", "."]
import numpy as np


def main():
    from benchmarks.prep import predictor, llm_dataset
    from repro.data.tokenizer import encode
    from repro.service import CompressionService

    pred = predictor("pred-small")
    svc = CompressionService(pred, slots=8, chunk_size=64, topk=16)

    # eight documents of very different lengths — the ragged shape a
    # multi-tenant service actually sees
    docs = [encode(llm_dataset("wiki", n, gen_model="pred-small", seed=s))
            for s, n in enumerate((300, 90, 700, 150, 40, 500, 220, 1000))]

    t0 = time.time()
    compress_handles = [svc.submit_compress(d) for d in docs]
    blobs = [h.result()[0] for h in compress_handles]
    dt_c = time.time() - t0
    total = sum(d.size for d in docs)
    print(f"compressed {len(docs)} docs ({total} tokens) -> "
          f"{sum(len(b) for b in blobs)}B in {dt_c:.1f}s "
          f"[{svc.stats.model_steps} steps, "
          f"occupancy {svc.stats.occupancy:.2f}]")

    # decompress all of them concurrently — and interleave one more
    # compression in the same batch (mixed traffic, no recompilation)
    t0 = time.time()
    dec_handles = [svc.submit_decompress(b) for b in blobs]
    extra = svc.submit_compress(docs[0], priority=-1)   # jumps the queue
    for d, h in zip(docs, dec_handles):
        assert np.array_equal(h.result(), d), "LOSSLESS VIOLATION"
    extra_blob, _ = extra.result()
    assert extra_blob == blobs[0]
    print(f"decompressed {len(docs)} docs (+1 priority compress) "
          f"bit-exact in {time.time() - t0:.1f}s "
          f"[total occupancy {svc.stats.occupancy:.2f}, "
          f"{svc.stats.refills} slot refills]")


if __name__ == "__main__":
    main()
