"""Serve a small model with batched requests: score a batch of chunked
documents (compression scoring path) and run lock-step batched decode —
the two production serving shapes.

  PYTHONPATH=src:. python examples/serve_batch.py
"""
import sys
import time

sys.path[:0] = ["src", "."]
import numpy as np


def main():
    import jax.numpy as jnp
    from benchmarks.prep import predictor, llm_dataset
    from repro.data.tokenizer import encode
    from repro.serve.steps import make_score_step, make_serve_step
    from repro.launch.mesh import local_mesh
    from repro.models import init_cache

    pred = predictor("pred-small")
    cfg = pred.cfg
    mesh = local_mesh()

    # batched scoring (prefill shape): 8 requests x 128 tokens
    reqs = np.stack([encode(llm_dataset("wiki", 128, gen_model="pred-small",
                                        seed=s))[:128] for s in range(8)])
    score = make_score_step(cfg, mesh, topk=16, s_block=64, global_batch=8)
    t0 = time.time()
    ids, qpmf = score(pred.params, {"tokens": jnp.asarray(reqs)})
    print(f"scored 8x128 tokens -> topk ids {ids.shape}, pmf {qpmf.shape} "
          f"in {time.time()-t0:.2f}s")

    # batched lock-step decode (serve shape)
    serve = make_serve_step(cfg, mesh, batch=8, topk=16)
    cache = init_cache(cfg, 8, 64)
    prev = jnp.zeros((8,), jnp.int32)
    t0 = time.time()
    for _ in range(32):
        ids, qpmf, cache = serve(pred.params, cache, prev)
        prev = ids[:, 0]  # greedy
    print(f"decoded 32 steps x 8 streams in {time.time()-t0:.2f}s "
          f"({32*8/(time.time()-t0):.0f} tok/s)")


if __name__ == "__main__":
    main()
