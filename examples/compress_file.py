"""End-to-end driver: losslessly compress/decompress any file with a
trained predictor (the paper's system as a CLI tool).

  PYTHONPATH=src:. python examples/compress_file.py compress  IN OUT.llmc [codec]
  PYTHONPATH=src:. python examples/compress_file.py decompress IN.llmc OUT

codec: rans (default) or ac. Decompression reads the codec from the
container header, so the argument only matters when compressing.
"""
import sys
import time

sys.path[:0] = ["src", "."]


def main():
    from benchmarks.prep import predictor
    from repro.core import LLMCompressor
    from repro.data.tokenizer import decode, encode

    mode, src, dst = sys.argv[1], sys.argv[2], sys.argv[3]
    codec = sys.argv[4] if len(sys.argv) > 4 else "rans"
    pred = predictor("pred-base")
    comp = LLMCompressor(pred, chunk_size=128, topk=48, decode_batch=32,
                         codec=codec)
    data = open(src, "rb").read()
    t0 = time.time()
    if mode == "compress":
        blob, stats = comp.compress(encode(data))
        open(dst, "wb").write(blob)
        print(f"{len(data)}B -> {len(blob)}B "
              f"({len(data)/max(1,len(blob)):.2f}x, {stats.n_escapes} escapes, "
              f"{time.time()-t0:.1f}s)")
    elif mode == "decompress":
        toks = comp.decompress(data)
        open(dst, "wb").write(decode(toks))
        print(f"{len(data)}B -> decoded {toks.size} tokens "
              f"({time.time()-t0:.1f}s)")
    else:
        raise SystemExit("mode must be compress|decompress")


if __name__ == "__main__":
    main()
