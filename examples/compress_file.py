"""End-to-end driver: losslessly compress/decompress any file with a
trained predictor — now a thin wrapper over the ``llmc`` CLI
(src/repro/cli.py, also installed as a console script), which routes
through the continuous-batching service and writes seekable v4
containers.

  PYTHONPATH=src:. python examples/compress_file.py compress  IN OUT.llmc [codec]
  PYTHONPATH=src:. python examples/compress_file.py decompress IN.llmc OUT
  PYTHONPATH=src:. python examples/compress_file.py info IN.llmc

codec: rans (default) or ac. Decompression reads the codec from the
container header, so the argument only matters when compressing.
For chunk ranges / slot counts / predictor choice, use ``llmc`` directly.
"""
import sys

sys.path[:0] = ["src", "."]


def main():
    from repro.cli import main as llmc
    mode = sys.argv[1]
    if mode == "compress":
        argv = ["compress", sys.argv[2], sys.argv[3]]
        if len(sys.argv) > 4:
            argv += ["--codec", sys.argv[4]]
    elif mode == "decompress":
        argv = ["decompress", sys.argv[2], sys.argv[3]]
    elif mode == "info":
        argv = ["info", sys.argv[2]]
    else:
        raise SystemExit("mode must be compress|decompress|info")
    return llmc(argv)


if __name__ == "__main__":
    sys.exit(main())
