"""Trace timeline recording + Chrome-trace export + phase attribution.

DESIGN.md §13. Three layers on top of the span vocabulary of
``obs.trace``:

* ``TimelineRecorder`` — a bounded ring buffer of **span events**. When
  a recorder is installed (``timeline.install(rec)`` / ``with rec:``),
  every closing ``obs.trace.span`` appends one ``SpanEvent`` (name,
  nesting path, thread id, start time, duration, optional job/chunk
  tags). The buffer is a fixed-capacity ring: sustained load overwrites
  the oldest events and counts the drops — recording can never grow
  memory without bound. When no recorder is installed the cost per span
  is one module-attribute check (the <2% disabled-overhead gate).

* Chrome-trace export — ``rec.to_chrome_trace()`` emits the Trace Event
  Format dict (``{"traceEvents": [...], "displayTimeUnit": "ms"}``,
  complete ``"X"`` events with microsecond ``ts``/``dur``) that
  chrome://tracing and Perfetto load directly; ``rec.save(path)``
  writes it as JSON. Timestamps come from the same
  ``time.perf_counter`` clock the spans measure with, zeroed at the
  recorder's start so traces from one process line up.

* ``PhaseReport`` — rolls span events up into a per-job wall-time
  breakdown: **exclusive** seconds (child-span time subtracted) per
  phase — model / coder / scheduler / router / prefix_cache / other —
  plus an ``unattributed`` residual so the phases always sum to the
  report's total wall. ``PhaseReport.from_events`` attributes a
  ``[t0, t1]`` window (a job's submit→done interval, clipping events at
  the edges); ``phases_from_registry`` derives the same breakdown from
  the ``span.<path>.seconds`` histograms alone (no recorder, zero extra
  overhead — what benchmarks/run.py puts in the bench history).
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

#: phase name -> span-name prefixes (first match wins, in this order).
#: Matching is on the span *name* (the last path segment), so nesting
#: cannot reclassify a span: model.decode_step inside service.step is
#: model time, and the step span's exclusive time is scheduler time.
PHASE_PREFIXES = (
    ("model", ("model.",)),
    ("coder", ("coder.", "rans.", "compress.encode", "decode.coder")),
    ("router", ("router.", "compress.route")),
    ("prefix_cache", ("prefix_cache.",)),
    ("scheduler", ("service.", "scheduler.", "compress.job",
                   "decompress.job", "decode.group", "decode.verify_round")),
    ("host", ("host.", "container.", "data.")),
)

UNATTRIBUTED = "unattributed"


def phase_of(name: str) -> str:
    """Phase bucket for a span name (see PHASE_PREFIXES); 'other' when
    no prefix matches."""
    for phase, prefixes in PHASE_PREFIXES:
        for p in prefixes:
            if name.startswith(p):
                return phase
    return "other"


@dataclass
class SpanEvent:
    """One closed span, as recorded at ``Span.__exit__`` time."""
    name: str           # span label (last path segment)
    path: str           # slash-joined nesting path
    t0: float           # start, seconds on the recorder's clock
    dur: float          # wall seconds
    tid: int            # recording thread's ident
    tags: Optional[dict] = None     # e.g. {"job": 3, "chunk": 7}

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


class TimelineRecorder:
    """Bounded ring buffer of span events + Chrome-trace export.

    Install with ``timeline.install(rec)`` (or use the recorder as a
    context manager) to start receiving events from every ``obs.span``
    in the process; ``timeline.uninstall()`` stops recording. One
    recorder at a time — installing a second replaces the first.
    """

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.t_start = time.perf_counter()
        self._ring: list = [None] * self.capacity
        self._n = 0                      # total events ever recorded
        self._lock = threading.Lock()

    # ------------------------------------------------------------ recording
    def record(self, name: str, path: str, t0: float, dur: float,
               tags: Optional[dict] = None) -> None:
        """Append one event (called from ``Span.__exit__``). Lock-held
        only for the two index ops — recording is cheap and safe from
        any thread."""
        ev = SpanEvent(name=name, path=path, t0=t0 - self.t_start,
                       dur=dur, tid=threading.get_ident(), tags=tags)
        with self._lock:
            self._ring[self._n % self.capacity] = ev
            self._n += 1

    @property
    def dropped(self) -> int:
        """Events overwritten by the ring (0 until capacity overflows)."""
        return max(0, self._n - self.capacity)

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def events(self) -> list:
        """Retained events, oldest first (start-time order within each
        thread; recording order is span-exit order)."""
        with self._lock:
            n, ring = self._n, list(self._ring)
        if n <= self.capacity:
            out = ring[:n]
        else:
            head = n % self.capacity
            out = ring[head:] + ring[:head]
        out.sort(key=lambda e: (e.t0, -e.dur))
        return out

    def now(self) -> float:
        """Current time on the recorder's clock (for [t0, t1] windows)."""
        return time.perf_counter() - self.t_start

    # -------------------------------------------------------------- export
    def to_chrome_trace(self, process_name: str = "repro") -> dict:
        """Trace Event Format dict: complete ('X') events, µs units —
        loads in chrome://tracing and ui.perfetto.dev unmodified."""
        trace_events = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": process_name},
        }]
        for ev in self.events():
            rec = {
                "name": ev.name, "cat": phase_of(ev.name), "ph": "X",
                "ts": round(ev.t0 * 1e6, 3),
                "dur": round(ev.dur * 1e6, 3),
                "pid": 1, "tid": ev.tid,
                "args": {"path": ev.path},
            }
            if ev.tags:
                rec["args"].update(ev.tags)
            trace_events.append(rec)
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    # ------------------------------------------------------ install helpers
    def __enter__(self) -> "TimelineRecorder":
        install(self)
        return self

    def __exit__(self, *exc) -> bool:
        if active() is self:
            uninstall()
        return False


# ------------------------------------------------------- process-wide hook
_recorder: Optional[TimelineRecorder] = None


def install(rec: TimelineRecorder) -> TimelineRecorder:
    """Start recording every span in the process into ``rec`` (replaces
    any previously installed recorder); returns ``rec``."""
    global _recorder
    _recorder = rec
    return rec


def uninstall() -> Optional[TimelineRecorder]:
    """Stop recording; returns the recorder that was installed."""
    global _recorder
    rec, _recorder = _recorder, None
    return rec


def active() -> Optional[TimelineRecorder]:
    """The installed recorder, or None. Hot paths may consult this to
    stop sampling spans (record every step) while a timeline is live."""
    return _recorder


# --------------------------------------------------------- phase rollup
@dataclass
class PhaseReport:
    """Per-job (or per-window) wall-time attribution.

    ``phases`` maps phase name -> **exclusive** wall seconds; it always
    contains an ``unattributed`` entry (window wall not covered by any
    span), so ``sum(phases.values()) == total_s`` up to float rounding.
    ``coverage`` is the fraction of the window covered by at least one
    span event (the ≥90% acceptance signal).
    """
    total_s: float
    phases: dict = field(default_factory=dict)
    n_events: int = 0
    dropped_events: int = 0

    @property
    def coverage(self) -> float:
        if self.total_s <= 0:
            return 0.0
        covered = self.total_s - self.phases.get(UNATTRIBUTED, 0.0)
        return max(0.0, min(1.0, covered / self.total_s))

    def to_dict(self) -> dict:
        return {
            "total_s": self.total_s,
            "phases": {k: round(v, 9) for k, v in sorted(
                self.phases.items()) if v > 0 or k == UNATTRIBUTED},
            "coverage": round(self.coverage, 4),
            "n_events": self.n_events,
            "dropped_events": self.dropped_events,
        }

    @classmethod
    def from_events(cls, events, t0: float = None, t1: float = None,
                    dropped: int = 0) -> "PhaseReport":
        """Attribute the wall-time window ``[t0, t1]`` to phases.

        Defaults the window to the events' own extent. Events are
        clipped to the window; nested spans contribute only their
        exclusive time (duration minus direct children, per thread), so
        a model span inside a scheduler step counts as model, and the
        step's remaining time as scheduler. Time no span covers lands
        in ``unattributed``.
        """
        evs = [e for e in events if e.dur >= 0]
        if t0 is None:
            t0 = min((e.t0 for e in evs), default=0.0)
        if t1 is None:
            t1 = max((e.t1 for e in evs), default=t0)
        total = max(0.0, t1 - t0)
        # clip to the window, drop events fully outside it
        win = []
        for e in evs:
            a, b = max(e.t0, t0), min(e.t1, t1)
            if b > a:
                win.append((a, b, e))
        phases: dict = {}
        covered = 0.0
        # per-thread sweep: events sorted by (start, -duration) nest
        # properly (a parent sorts before its children), so a stack
        # yields each event's exclusive time in one pass
        by_tid: dict = {}
        for rec in win:
            by_tid.setdefault(rec[2].tid, []).append(rec)
        for tid_events in by_tid.values():
            tid_events.sort(key=lambda r: (r[0], -(r[1] - r[0])))
            stack: list = []    # [a, b, event, child_time]
            cover_end = None

            def close(frame):
                a, b, e, child = frame
                excl = max(0.0, (b - a) - child)
                ph = phase_of(e.name)
                phases[ph] = phases.get(ph, 0.0) + excl
                if stack:
                    stack[-1][3] += b - a

            for a, b, e in tid_events:
                while stack and a >= stack[-1][1]:
                    close(stack.pop())
                # union coverage for this thread (threads overlap in
                # wall time; coverage counts wall once — use the union
                # across ALL threads below)
                stack.append([a, b, e, 0.0])
            while stack:
                close(stack.pop())
        # wall coverage: union of all event intervals across threads
        ivs = sorted((a, b) for a, b, _ in win)
        end = None
        for a, b in ivs:
            if end is None or a > end:
                covered += b - a
                end = b
            elif b > end:
                covered += b - end
                end = b
        phases[UNATTRIBUTED] = max(0.0, total - covered)
        # exclusive sums can overshoot the union when threads overlap;
        # the report stays honest: phases describe thread-time, the
        # unattributed term describes wall — both are real quantities
        return cls(total_s=total, phases=phases, n_events=len(win),
                   dropped_events=dropped)

    @classmethod
    def from_recorder(cls, rec: TimelineRecorder, t0: float = None,
                      t1: float = None) -> "PhaseReport":
        return cls.from_events(rec.events(), t0=t0, t1=t1,
                               dropped=rec.dropped)


def phases_from_registry(reg) -> dict:
    """Phase -> exclusive seconds from the ``span.<path>.seconds``
    histograms alone (no recorder needed). The nesting path IS the tree:
    a path's exclusive time is its sum minus its direct children's sums.
    Sampled spans (scheduler step 1-in-N) under-count proportionally —
    this is the cheap trajectory signal, the recorder is the precise one.
    """
    sums: dict = {}
    for name, m in getattr(reg, "_metrics", {}).items():
        if not (name.startswith("span.") and name.endswith(".seconds")):
            continue
        path = name[len("span."):-len(".seconds")]
        sums[path] = getattr(m, "sum", 0.0)
    phases: dict = {}
    for path, s in sums.items():
        child_time = sum(cs for cp, cs in sums.items()
                         if cp.startswith(path + "/")
                         and "/" not in cp[len(path) + 1:])
        leaf = path.rsplit("/", 1)[-1]
        ph = phase_of(leaf)
        phases[ph] = phases.get(ph, 0.0) + max(0.0, s - child_time)
    return phases
