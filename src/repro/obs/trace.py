"""Host-side span tracing with optional mirroring into JAX device traces.

``with obs.trace.span("decode.verify_round"): ...`` times a region of
host code and records the wall time into a log2 histogram named
``span.<path>.seconds`` — where ``<path>`` is the slash-joined nesting
path (``service.step/model.decode_step``), built from a thread-local
span stack, so one histogram exists per distinct call *position*, not
just per label.

When JAX is in the process, every span also enters a
``jax.profiler.TraceAnnotation`` with the same label, so capturing a
device profile (XProf/Perfetto) shows the host spans interleaved with
the XLA ops they bracket — one vocabulary across host and device
timelines. ``TraceAnnotation`` is a no-op-cheap TraceMe when no profiler
session is active; mirroring can still be forced off with
``set_jax_mirror(False)``. JAX is never imported by this module — the
mirror activates only if something else already imported jax.

Spans follow the registry switch: ``span()`` returns a shared null
context manager when the target registry (argument, else the process
default) is disabled, so a disabled process pays one attribute check
per span site.
"""
from __future__ import annotations

import sys
import threading
import time
from . import metrics as _metrics

_tls = threading.local()
_enabled = True          # module master switch (obs.trace.enable(False))
_jax_mirror = True       # mirror into jax.profiler.TraceAnnotation
_TraceAnnotation = None  # resolved lazily; False = unavailable


def enable(on: bool = True) -> None:
    global _enabled
    _enabled = bool(on)


def set_jax_mirror(on: bool) -> None:
    global _jax_mirror
    _jax_mirror = bool(on)


def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def current() -> str:
    """Slash-joined path of the innermost open span ('' outside spans)."""
    s = _stack()
    return "/".join(s) if s else ""


def _resolve_jax():
    """Find jax.profiler.TraceAnnotation iff jax is already imported."""
    global _TraceAnnotation
    if _TraceAnnotation is None and "jax" in sys.modules:
        try:
            from jax.profiler import TraceAnnotation
            _TraceAnnotation = TraceAnnotation
        except Exception:       # pragma: no cover - jax without profiler
            _TraceAnnotation = False
    return _TraceAnnotation


class _NullSpan:
    """Shared no-op context manager for disabled tracing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()

#: shared no-op span — for call sites that sample their own spans
#: (e.g. the scheduler times every Nth step) and need the "not this
#: time" branch to cost one attribute read
NULL = _NULL


class Span:
    __slots__ = ("name", "_reg", "_t0", "_jax", "path")

    def __init__(self, name: str, reg):
        self.name = name
        self._reg = reg
        self._jax = None
        self.path = name

    def __enter__(self):
        stack = _stack()
        stack.append(self.name)
        self.path = "/".join(stack)
        if _jax_mirror:
            ta = _resolve_jax()
            if ta:
                self._jax = ta(self.name)
                self._jax.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if self._jax is not None:
            self._jax.__exit__(*exc)
        _stack().pop()
        self._reg.histogram(
            "span." + self.path + ".seconds",
            "wall seconds spent in this span path").observe(dt)
        return False


def span(name: str, registry=None):
    """Open a traced region. Records into ``registry`` (default: the
    process-global one). Returns a shared null context manager when
    tracing or the target registry is disabled."""
    reg = registry if registry is not None else _metrics.registry()
    if not (_enabled and reg.enabled):
        return _NULL
    return Span(name, reg)
