"""Host-side span tracing with optional mirroring into JAX device traces.

``with obs.trace.span("decode.verify_round"): ...`` times a region of
host code and records the wall time into a log2 histogram named
``span.<path>.seconds`` — where ``<path>`` is the slash-joined nesting
path (``service.step/model.decode_step``), built from a thread-local
span stack, so one histogram exists per distinct call *position*, not
just per label.

When JAX is in the process, every span also enters a
``jax.profiler.TraceAnnotation`` with the same label, so capturing a
device profile (XProf/Perfetto) shows the host spans interleaved with
the XLA ops they bracket — one vocabulary across host and device
timelines. ``TraceAnnotation`` is a no-op-cheap TraceMe when no profiler
session is active; mirroring can still be forced off with
``set_jax_mirror(False)``. JAX is never imported by this module — the
mirror activates only if something else already imported jax.

Spans follow the registry switch: ``span()`` returns a shared null
context manager when the target registry (argument, else the process
default) is disabled, so a disabled process pays one attribute check
per span site.

When a ``obs.timeline.TimelineRecorder`` is installed, every closing
span additionally appends one event (name, path, start, duration,
thread, tags) to the recorder's ring buffer — the raw material for
Chrome-trace export and per-job phase attribution (DESIGN.md §13).
With no recorder installed that costs one module-attribute check.
"""
from __future__ import annotations

import sys
import threading
import time
from . import metrics as _metrics
from . import timeline as _timeline

_tls = threading.local()
_enabled = True          # module master switch (obs.trace.enable(False))
_jax_mirror = True       # mirror into jax.profiler.TraceAnnotation
_TraceAnnotation = None  # resolved lazily; False = unavailable


def enable(on: bool = True) -> None:
    global _enabled
    _enabled = bool(on)


def set_jax_mirror(on: bool) -> None:
    global _jax_mirror
    _jax_mirror = bool(on)


def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def current() -> str:
    """Slash-joined path of the innermost open span ('' outside spans)."""
    s = _stack()
    return "/".join(s) if s else ""


def _resolve_jax():
    """Find jax.profiler.TraceAnnotation iff jax is already imported."""
    global _TraceAnnotation
    if _TraceAnnotation is None and "jax" in sys.modules:
        try:
            from jax.profiler import TraceAnnotation
            _TraceAnnotation = TraceAnnotation
        except Exception:       # pragma: no cover - jax without profiler
            _TraceAnnotation = False
    return _TraceAnnotation


class _NullSpan:
    """Shared no-op context manager for disabled tracing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()

#: shared no-op span — for call sites that sample their own spans
#: (e.g. the scheduler times every Nth step) and need the "not this
#: time" branch to cost one attribute read
NULL = _NULL


class Span:
    __slots__ = ("name", "_reg", "_t0", "_jax", "path", "tags", "_mirror")

    def __init__(self, name: str, reg, tags=None, mirror=True):
        self.name = name
        self._reg = reg
        self._jax = None
        self.path = name
        self.tags = tags
        self._mirror = mirror

    def __enter__(self):
        stack = _stack()
        stack.append(self.name)
        self.path = "/".join(stack)
        if _jax_mirror and self._mirror:
            ta = _resolve_jax()
            if ta:
                self._jax = ta(self.name)
                self._jax.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if self._jax is not None:
            self._jax.__exit__(*exc)
        _stack().pop()
        if self._reg is not None:
            self._reg.histogram(
                "span." + self.path + ".seconds",
                "wall seconds spent in this span path").observe(dt)
        rec = _timeline._recorder
        if rec is not None:
            rec.record(self.name, self.path, self._t0, dt, self.tags)
        return False


def span(name: str, registry=None, tags=None, mirror=True):
    """Open a traced region. Records into ``registry`` (default: the
    process-global one). Returns a shared null context manager when
    tracing or the target registry is disabled. ``tags`` (e.g.
    ``{"job": 3, "chunk": 7}``) ride along on timeline events only —
    they never fan out histogram names. ``mirror=False`` skips the
    jax.profiler.TraceAnnotation mirror for per-step hot-loop spans
    whose TraceMe cost would dominate the region they time.

    A process-wide timeline recorder (obs.timeline.install) overrides
    the registry gate: spans still land on the timeline even when their
    target registry is disabled or is not the recording service's own —
    the recorder is process-scoped, so the timeline must see every span
    in the process (a service's private registry would otherwise hide
    the coder/model spans that record against the global one). Such
    timeline-only spans skip the histogram observe."""
    if not _enabled:
        return _NULL
    reg = registry if registry is not None else _metrics.registry()
    if reg.enabled:
        return Span(name, reg, tags, mirror)
    if _timeline._recorder is None:
        return _NULL
    return Span(name, None, tags, mirror)   # timeline-only span
