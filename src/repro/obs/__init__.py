"""repro.obs — unified observability: metrics, spans, logs, diagnostics.

One import surface for the whole stack::

    from repro import obs

    obs.registry().counter("compress.jobs").inc()
    with obs.trace.span("decode.verify_round"):
        ...
    obs.log("scheduler.progress", steps=n, occupancy=occ)

See DESIGN.md §10 for the naming scheme, the span hierarchy, and the
overhead budget (<2% enabled on the service bench, ~0 disabled —
CI-gated by ``benchmarks/run.py telemetry_overhead``).
"""
from __future__ import annotations

from . import bench_history  # noqa: F401  (BenchHistory / BenchRecord)
from . import timeline  # noqa: F401  (TimelineRecorder / PhaseReport)
from . import trace  # noqa: F401  (obs.trace.span / obs.trace.current)
from .diagnostics import (  # noqa: F401
    ChunkDiagnostics,
    JobDiagnostics,
    read_sidecar,
    sidecar_path,
    write_sidecar,
)
from .logs import (  # noqa: F401
    configure,
    console,
    exception_record,
    format_event,
    get_logger,
    log,
    log_error,
    log_exception,
)
from .metrics import (  # noqa: F401
    ROUTER_CHUNKS_FALLBACK,
    ROUTER_CHUNKS_LLM,
    ROUTER_FLIPS,
    ROUTER_PROBE_SKIPS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    set_registry,
)
from .timeline import PhaseReport, TimelineRecorder  # noqa: F401
from .trace import span  # noqa: F401

__all__ = [
    "ChunkDiagnostics",
    "ROUTER_CHUNKS_FALLBACK",
    "ROUTER_CHUNKS_LLM",
    "ROUTER_FLIPS",
    "ROUTER_PROBE_SKIPS",
    "Counter",
    "Gauge",
    "Histogram",
    "JobDiagnostics",
    "MetricsRegistry",
    "PhaseReport",
    "TimelineRecorder",
    "bench_history",
    "configure",
    "console",
    "exception_record",
    "format_event",
    "get_logger",
    "log",
    "log_error",
    "log_exception",
    "read_sidecar",
    "registry",
    "set_registry",
    "sidecar_path",
    "span",
    "timeline",
    "trace",
    "write_sidecar",
]
