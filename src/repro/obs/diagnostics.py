"""Per-chunk / per-job compression diagnostics.

The paper's ratio claim is a measurement claim — compression ratio *is*
realized cross-entropy under the generating model — so the system
records, per chunk, the quantities the adaptive codec router (ROADMAP)
will route on:

* ``coded_bits`` — the quantized code length actually paid by the
  entropy coder (``precision - log2(freq)`` summed over coded symbols,
  escapes charged their uniform bits). ``coded_bits / n_tokens`` is the
  chunk's realized bits/token under the *quantized* model.
* ``ideal_bits`` — the un-quantized model cross-entropy (compress side
  only; the decoder never needs it). ``coded - ideal`` is the
  quantization + top-k overhead.
* escape count, speculative-decode round/acceptance/rollback counts,
  codec id — the model-fit and wall-clock signals.

``JobDiagnostics`` aggregates a job's chunks and serializes to a JSON
**sidecar** (``<container>.diag.json`` by convention): diagnostics ride
NEXT TO the container, never inside it — telemetry must not change
output bytes (the byte-identity property tests in tests/test_obs.py pin
this).
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import asdict, dataclass, field
from typing import Optional

SIDECAR_SUFFIX = ".diag.json"


@dataclass
class ChunkDiagnostics:
    """One chunk's compression telemetry (either direction)."""
    chunk_index: int
    n_tokens: int
    stream_bytes: int
    coded_bits: float = 0.0      # quantized code length (excl. framing)
    ideal_bits: float = 0.0      # model cross-entropy, compress side only
    n_escapes: int = 0
    draft_rounds: int = 0        # speculative decode only
    draft_accepted: int = 0      # drafted tokens accepted (bonus yield)
    rollbacks: int = 0
    codec: str = ""              # per-chunk codec name (v5 routing)
    context: str = ""            # context recipe, e.g. "carry(64)" (v6)

    @property
    def bits_per_token(self) -> float:
        """Realized payload bits/token (stream bytes are ground truth)."""
        return 8.0 * self.stream_bytes / self.n_tokens \
            if self.n_tokens else 0.0

    @property
    def cross_entropy(self) -> float:
        """Model cross-entropy in bits/token (0 when not recorded)."""
        return self.ideal_bits / self.n_tokens if self.n_tokens else 0.0

    @property
    def escape_rate(self) -> float:
        return self.n_escapes / self.n_tokens if self.n_tokens else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        if not d["context"]:
            # context is a v6-only concept; keep v2-v5 sidecars
            # byte-identical to their pre-v6 form
            del d["context"]
        d["bits_per_token"] = round(self.bits_per_token, 4)
        d["cross_entropy"] = round(self.cross_entropy, 4)
        d["escape_rate"] = round(self.escape_rate, 5)
        return d


@dataclass
class JobDiagnostics:
    """A job's aggregated telemetry + its per-chunk records."""
    job_id: int = 0
    kind: str = ""
    codec: str = ""
    n_tokens: int = 0
    container_bytes: int = 0
    chunks: list = field(default_factory=list)   # [ChunkDiagnostics]
    wall_s: float = 0.0          # submit→done wall (0 when not recorded)
    phases: Optional[dict] = None   # PhaseReport.to_dict() (DESIGN.md §13)

    @property
    def payload_bytes(self) -> int:
        return sum(c.stream_bytes for c in self.chunks)

    @property
    def bits_per_token(self) -> float:
        n = sum(c.n_tokens for c in self.chunks)
        return 8.0 * self.payload_bytes / n if n else 0.0

    @property
    def cross_entropy(self) -> float:
        n = sum(c.n_tokens for c in self.chunks)
        return sum(c.ideal_bits for c in self.chunks) / n if n else 0.0

    @property
    def escape_rate(self) -> float:
        n = sum(c.n_tokens for c in self.chunks)
        return sum(c.n_escapes for c in self.chunks) / n if n else 0.0

    @property
    def draft_acceptance(self) -> Optional[float]:
        """Accepted drafted tokens per offered draft slot, or None when
        the job never ran the speculative path."""
        rounds = sum(c.draft_rounds for c in self.chunks)
        if not rounds:
            return None
        return sum(c.draft_accepted for c in self.chunks) / rounds

    def to_dict(self) -> dict:
        d = {
            "job_id": self.job_id, "kind": self.kind, "codec": self.codec,
            "n_tokens": self.n_tokens,
            "container_bytes": self.container_bytes,
            "payload_bytes": self.payload_bytes,
            "bits_per_token": round(self.bits_per_token, 4),
            "cross_entropy": round(self.cross_entropy, 4),
            "escape_rate": round(self.escape_rate, 5),
            "chunks": [c.to_dict() for c in self.chunks],
        }
        acc = self.draft_acceptance
        if acc is not None:
            d["draft_acceptance"] = round(acc, 4)
        # attribution fields only when recorded — pre-§13 sidecars stay
        # byte-identical
        if self.wall_s:
            d["wall_s"] = round(self.wall_s, 6)
        if self.phases is not None:
            d["phases"] = self.phases
        return d

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def sidecar_path(container_path) -> pathlib.Path:
    """Conventional sidecar location for a container file."""
    p = pathlib.Path(container_path)
    return p.with_name(p.name + SIDECAR_SUFFIX)


def write_sidecar(container_path, diag: JobDiagnostics) -> pathlib.Path:
    """Write the job's diagnostics next to its container; returns the
    sidecar path."""
    p = sidecar_path(container_path)
    p.write_text(diag.to_json())
    return p


def read_sidecar(container_path) -> dict:
    return json.loads(sidecar_path(container_path).read_text())
