"""Append-only bench trajectory store (results/history.jsonl).

Every ``benchmarks/run.py`` invocation appends one record per bench —
never overwrites — so the repo accumulates a performance *trajectory*
instead of the latest snapshot. ``tools/bench_regress.py`` gates CI on
it: latest vs trailing median, >15% wall regression or any ratio
regression fails (DESIGN.md §13).

Record schema (``SCHEMA`` version 1), one JSON object per line::

    {"schema": 1, "bench": "service_throughput", "commit": "c36df73",
     "ts": "2026-08-08T12:00:00+00:00", "quick": false,
     "us_per_call": 1234.5, "derived": "jobs_s=81.0;speedup=5.02",
     "values": {"jobs_s": 81.0, "speedup": 5.02},
     "metrics": {...compact registry snapshot...},
     "phases": {"model": 1.2, "coder": 0.3, ...}}

``values`` is ``derived`` parsed into floats — the regression gate
reads it without re-parsing strings. ``metrics`` keeps counter/gauge
values and histogram count/sum/quantiles, dropping bucket maps (the
trajectory needs the summary, not the full shape). Corrupt lines are
skipped on load (an interrupted append must not poison the trajectory).
"""
from __future__ import annotations

import datetime
import json
import pathlib
import subprocess
from dataclasses import dataclass, field
from typing import Optional

SCHEMA = 1

#: required fields and their types (validation is structural, not
#: value-judging — the regression gate decides what's "bad")
_REQUIRED = {
    "schema": int,
    "bench": str,
    "commit": str,
    "ts": str,
    "quick": bool,
    "us_per_call": (int, float),
    "derived": str,
    "values": dict,
    "metrics": dict,
    "phases": dict,
}


def git_commit(repo_root=None) -> str:
    """Short HEAD hash, or '' outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=repo_root, capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.SubprocessError):
        return ""


def parse_derived(derived: str) -> dict:
    """'k=v;k2=v2' -> {k: float} (non-numeric values are dropped)."""
    out = {}
    for part in (derived or "").split(";"):
        part = part.strip()
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.strip().rstrip("x")       # "speedup=5.02x" style
        try:
            out[k.strip()] = float(v)
        except ValueError:
            continue
    return out


def compact_metrics(snapshot: dict) -> dict:
    """Registry snapshot -> trajectory form: scalar values, histogram
    summaries (count/sum/mean/p50/p95/p99), no bucket maps."""
    out = {}
    for name, m in snapshot.items():
        if m.get("type") == "histogram":
            out[name] = {k: m[k] for k in
                         ("count", "sum", "mean", "p50", "p95", "p99")
                         if k in m}
        else:
            out[name] = m.get("value")
    return out


@dataclass
class BenchRecord:
    bench: str
    us_per_call: float
    derived: str = ""
    commit: str = ""
    ts: str = ""
    quick: bool = False
    values: dict = field(default_factory=dict)
    metrics: dict = field(default_factory=dict)
    phases: dict = field(default_factory=dict)
    schema: int = SCHEMA

    @classmethod
    def build(cls, bench: str, us_per_call: float, derived: str = "",
              registry=None, quick: bool = False,
              commit: Optional[str] = None,
              ts: Optional[str] = None) -> "BenchRecord":
        """Assemble a record from a finished bench run. ``registry`` (the
        bench's MetricsRegistry) supplies the metrics snapshot and the
        span-derived phase breakdown."""
        from . import timeline as _timeline
        metrics: dict = {}
        phases: dict = {}
        if registry is not None:
            metrics = compact_metrics(registry.snapshot())
            phases = {k: round(v, 6) for k, v in
                      _timeline.phases_from_registry(registry).items()}
        if ts is None:
            ts = datetime.datetime.now(datetime.timezone.utc).isoformat(
                timespec="seconds")
        return cls(
            bench=bench, us_per_call=float(us_per_call), derived=derived,
            commit=git_commit() if commit is None else commit, ts=ts,
            quick=quick, values=parse_derived(derived), metrics=metrics,
            phases=phases)

    def to_dict(self) -> dict:
        return {
            "schema": self.schema, "bench": self.bench,
            "commit": self.commit, "ts": self.ts, "quick": self.quick,
            "us_per_call": self.us_per_call, "derived": self.derived,
            "values": self.values, "metrics": self.metrics,
            "phases": self.phases,
        }


def validate_record(d: dict) -> list:
    """Structural problems with a history row ([] when schema-valid)."""
    problems = []
    if not isinstance(d, dict):
        return [f"record is {type(d).__name__}, not an object"]
    for key, typ in _REQUIRED.items():
        if key not in d:
            problems.append(f"missing field {key!r}")
        elif not isinstance(d[key], typ):
            problems.append(
                f"field {key!r} is {type(d[key]).__name__}")
    if isinstance(d.get("schema"), int) and d["schema"] > SCHEMA:
        problems.append(f"schema {d['schema']} is newer than {SCHEMA}")
    vals = d.get("values")
    if isinstance(vals, dict):
        for k, v in vals.items():
            if not isinstance(v, (int, float)):
                problems.append(f"values[{k!r}] is not numeric")
    return problems


class BenchHistory:
    """The results/history.jsonl accessor: append + filtered reads."""

    def __init__(self, path):
        self.path = pathlib.Path(path)

    def append(self, record: BenchRecord) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(record.to_dict(),
                               separators=(",", ":")) + "\n")

    def load(self, bench: Optional[str] = None) -> list:
        """All schema-valid rows (oldest first), optionally one bench's.
        Invalid/corrupt lines are skipped, not fatal."""
        if not self.path.exists():
            return []
        rows = []
        for line in self.path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            if validate_record(d):
                continue
            if bench is None or d["bench"] == bench:
                rows.append(d)
        return rows

    def benches(self) -> list:
        """Distinct bench names present, sorted."""
        return sorted({r["bench"] for r in self.load()})

    def latest(self, bench: str) -> Optional[dict]:
        rows = self.load(bench)
        return rows[-1] if rows else None

    def trailing(self, bench: str, n: int = 10) -> list:
        """Up to ``n`` rows *before* the latest one (the baseline pool
        the regression gate medians over)."""
        rows = self.load(bench)
        return rows[max(0, len(rows) - 1 - n):-1] if len(rows) > 1 else []
