"""Structured logging: one event name + key=value fields per line.

``obs.log("scheduler.progress", steps=4096, occupancy=0.97)`` emits

    scheduler.progress steps=4096 occupancy=0.97

through the stdlib ``repro`` logger (so handlers, capture, and level
control all behave normally under pytest / services), and every
``obs.log_error``/``obs.log_exception`` call additionally increments
``errors.total`` and ``errors.<event>`` counters in the process-global
registry — failures are *countable* in ``stats()``/exposition, not just
greppable in text.

``exception_record(exc)`` is the structured replacement for
``traceback.format_exc()`` string concatenation: a JSON-serializable
dict with the exception type, message, and frame list, suitable for
error sidecar files (see launch/dryrun.py).

The repo lint (tools/lint_no_print.py, wired into CI) forbids bare
``print(`` anywhere in src/repro outside cli.py — operational output
goes through this module so it carries a level, a logger name, and a
counter.
"""
from __future__ import annotations

import logging
import os
import sys
import traceback

from . import metrics as _metrics

_LOGGER_NAME = "repro"
_configured = False


def get_logger(name: str = _LOGGER_NAME) -> logging.Logger:
    """The repo logger, lazily fitted with a stderr handler + level from
    $REPRO_LOG_LEVEL (default INFO) unless the application configured
    logging itself."""
    global _configured
    logger = logging.getLogger(name)
    if not _configured:
        _configured = True
        root = logging.getLogger(_LOGGER_NAME)
        if not root.handlers and not logging.getLogger().handlers:
            h = logging.StreamHandler()
            h.setFormatter(logging.Formatter(
                "%(asctime)s %(levelname).1s %(name)s %(message)s",
                datefmt="%H:%M:%S"))
            root.addHandler(h)
        root.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO").upper())
    return logger


def configure(stream=None, level: str | None = None) -> logging.Logger:
    """Explicitly fit the repro logger with exactly one handler writing
    to ``stream`` (default stderr) — for CLI entrypoints whose
    operational log *is* their stdout contract (launch/train.py: the
    watchdog test greps the trainer's stdout for train.resume /
    train.done). Replaces any handler a previous configuration installed
    and marks the logger configured so ``get_logger`` leaves it alone."""
    global _configured
    _configured = True
    root = logging.getLogger(_LOGGER_NAME)
    for h in list(root.handlers):
        root.removeHandler(h)
    h = logging.StreamHandler(stream)
    h.setFormatter(logging.Formatter(
        "%(asctime)s %(levelname).1s %(name)s %(message)s",
        datefmt="%H:%M:%S"))
    root.addHandler(h)
    root.setLevel((level or os.environ.get("REPRO_LOG_LEVEL",
                                           "INFO")).upper())
    return root


def console(msg="", *, err: bool = False) -> None:
    """Raw console line for CLI-style tools (benchmarks/, tools/) whose
    stdout IS their contract — result tables, gate verdicts, usage text.
    Unlike ``log()`` there is no level/timestamp prefix; unlike bare
    ``print()`` it is the one funnel the no-print lint allows, so every
    operational emit site is enumerable."""
    stream = sys.stderr if err else sys.stdout
    stream.write(str(msg) + "\n")


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    return repr(s) if " " in s or "=" in s else s


def format_event(event: str, fields: dict) -> str:
    if not fields:
        return event
    return event + " " + " ".join(
        f"{k}={_fmt_value(v)}" for k, v in fields.items())


def log(event: str, _level: int = logging.INFO, **fields) -> None:
    """Emit one structured line: ``event k=v k=v ...``."""
    get_logger().log(_level, format_event(event, fields))


def log_error(event: str, **fields) -> None:
    """ERROR-level structured line + errors.total / errors.<event>
    counters in the process-global registry."""
    reg = _metrics.registry()
    reg.counter("errors.total", "structured error events").inc()
    reg.counter("errors." + event).inc()
    log(event, _level=logging.ERROR, **fields)


def log_exception(event: str, exc: BaseException, **fields) -> None:
    """log_error + exception type/message fields + DEBUG traceback."""
    log_error(event, error=f"{type(exc).__name__}: {exc}", **fields)
    get_logger().debug("".join(traceback.format_exception(
        type(exc), exc, exc.__traceback__)))


def exception_record(exc: BaseException) -> dict:
    """JSON-serializable structured form of an exception + traceback."""
    return {
        "type": type(exc).__name__,
        "message": str(exc),
        "traceback": [
            {"file": f.filename, "line": f.lineno, "func": f.name,
             "code": f.line or ""}
            for f in traceback.extract_tb(exc.__traceback__)
        ],
    }
