"""Low-overhead metrics registry: counters, gauges, log2-bucket histograms.

Design (DESIGN.md §10)
----------------------
* **Instruments are always functional.** A ``Counter`` is an attribute
  add on a Python int — load-bearing scheduler state (occupancy,
  model-step counts) reads straight through them, so there is no
  "metrics off means the scheduler forgets how many steps it ran".
* **``enabled`` gates the optional work.** Hot paths consult
  ``registry.enabled`` before doing anything beyond the core counters —
  per-slot code-length accumulation, histogram observes, span timing,
  periodic log lines. With ``enabled=False`` the telemetry cost of a
  scheduler step is one boolean attribute check (~0; gated in CI by
  ``benchmarks/run.py telemetry_overhead``).
* **Process-global default + injectable instances.** Module-level code
  (spans, structured logs, dryrun error counters) records into
  ``obs.registry()``; components that need isolation (a
  ``CompressionService`` whose ``stats()`` must describe *its own*
  traffic) construct or accept their own ``MetricsRegistry``. Inject
  ``obs.registry()`` to aggregate a component into the process view.

Naming scheme: dot-separated lowercase ``<subsystem>.<noun>[_<unit>]``
(``scheduler.model_steps``, ``compress.escapes``,
``chunk.bits_per_token``, ``span.<path>.seconds``). Prometheus
exposition mangles dots and slashes to underscores.

Histogram buckets are fixed powers of two: value v lands in the bucket
``(2**(e-1), 2**e]`` with ``e = frexp(v)[1]``, clamped to e ∈ [-31, 32]
(64 buckets + a zero bucket). One scheme serves seconds (µs..minutes)
and bits/token (0.01..1000) without per-metric configuration, and two
snapshots taken at different times always have aligned bucket edges —
what a trajectory tracker (results/BENCH_*.metrics.json) needs.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Union

_EXP_LO = -31            # smallest bucket exponent (le = 2**-31 ≈ 4.7e-10)
_EXP_HI = 32             # largest  bucket exponent (le = 2**32)
_NBUCKETS = _EXP_HI - _EXP_LO + 2   # + zero bucket + overflow-into-last

# Canonical router-decision counter names (DESIGN.md §11). Defined here —
# not in core/ — so the compressor, the service scheduler, and dashboards
# all key the same strings; drift between producers would silently split
# one decision stream across two metric names.
ROUTER_CHUNKS_LLM = "router.chunks_llm"
ROUTER_CHUNKS_FALLBACK = "router.chunks_fallback"
ROUTER_PROBE_SKIPS = "router.probe_skips"
ROUTER_FLIPS = "router.flips"


class Counter:
    """Monotonic counter. ``value`` is plain read/write on purpose: the
    SchedulerStats compatibility view assigns through it."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed log2-bucket histogram (see module docstring)."""

    __slots__ = ("name", "help", "counts", "count", "sum")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.counts = [0] * _NBUCKETS
        self.count = 0
        self.sum = 0.0

    @staticmethod
    def bucket_index(v: float) -> int:
        if v <= 0.0:
            return 0
        e = math.frexp(v)[1]            # v in (2**(e-1), 2**e]
        return min(max(e, _EXP_LO), _EXP_HI) - _EXP_LO + 1

    @staticmethod
    def bucket_le(idx: int) -> float:
        """Upper bound of bucket ``idx`` (0 is the v<=0 bucket)."""
        if idx == 0:
            return 0.0
        return 2.0 ** (idx - 1 + _EXP_LO)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.counts[self.bucket_index(v)] += 1

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(float(v))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket holding
        the q'th observation) — coarse by design, trajectory-stable."""
        if not self.count:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.bucket_le(i)
        return self.bucket_le(_NBUCKETS - 1)

    def nonzero_buckets(self) -> dict:
        """{le: count} for occupied buckets (sparse snapshot form)."""
        return {self.bucket_le(i): c
                for i, c in enumerate(self.counts) if c}


class MetricsRegistry:
    """Name -> instrument store with snapshot/exposition surfaces.

    Thread-safe for instrument *creation*; increments are plain attribute
    arithmetic (the GIL makes them atomic enough for telemetry, and the
    hot paths must not pay a lock).
    """

    def __init__(self, enabled: bool = True, name: str = ""):
        self.enabled = bool(enabled)
        self.name = name
        self._metrics: dict = {}
        self._lock = threading.Lock()

    # ----------------------------------------------------------- factories
    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, help)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str):
        """Metric by name, or None (read-side: no accidental creation)."""
        return self._metrics.get(name)

    def value(self, name: str, default=0):
        m = self._metrics.get(name)
        return default if m is None or isinstance(m, Histogram) else m.value

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Structured dump: {name: typed dict}, JSON-serializable."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[name] = {"type": "gauge", "value": m.value}
            else:
                out[name] = {
                    "type": "histogram", "count": m.count,
                    "sum": m.sum, "mean": m.mean,
                    "p50": m.quantile(0.5), "p95": m.quantile(0.95),
                    "p99": m.quantile(0.99),
                    "buckets": {repr(le): c
                                for le, c in m.nonzero_buckets().items()},
                }
        return out

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (text/plain; version 0.0.4)."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {_prom_help(m.help)}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_prom_num(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_num(m.value)}")
            else:
                lines.append(f"# TYPE {pname} histogram")
                acc = 0
                for i, c in enumerate(m.counts):
                    if not c:
                        continue
                    acc += c
                    le = _prom_num(m.bucket_le(i))
                    lines.append(f'{pname}_bucket{{le="{le}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {_prom_num(m.sum)}")
                lines.append(f"{pname}_count {m.count}")
                # bucket-resolution quantiles as companion gauges (the
                # native histogram type has no quantile series; scrapers
                # that can't run histogram_quantile() still get p50/95/99)
                for q, suffix in ((0.5, "p50"), (0.95, "p95"),
                                  (0.99, "p99")):
                    qname = f"{pname}_{suffix}"
                    lines.append(f"# TYPE {qname} gauge")
                    lines.append(f"{qname} {_prom_num(m.quantile(q))}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    if not out or out[0].isdigit():
        out = "m_" + out
    return "repro_" + out


def _prom_help(text: str) -> str:
    """Escape HELP text per the 0.0.4 exposition format: backslash and
    newline only (HELP lines; label values would also escape quotes)."""
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _prom_num(v) -> str:
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return repr(f) if f != int(f) else str(int(f))


# --------------------------------------------------------- process default
_default = MetricsRegistry(name="default")


def registry() -> MetricsRegistry:
    """The process-global default registry."""
    return _default


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry; returns the previous one."""
    global _default
    old = _default
    _default = reg
    return old
