"""repro — JAX framework reproducing 'Lossless Compression of LLM-Generated
Text via Next-Token Prediction' at production scale."""
__version__ = "0.1.0"
