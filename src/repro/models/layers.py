"""Core neural layers (pure JAX, shard-friendly).

Attention comes in three interchangeable implementations:
  * ``attention_masked``       — q-chunked online-softmax over the full KV
                                 (baseline; causal mask applied, masked
                                 positions still burn FLOPs — visible in the
                                 roofline "useful FLOPs" ratio).
  * ``attention_block_causal`` — triangular (q-chunk, kv-chunk) schedule that
                                 only computes unmasked blocks (beyond-paper
                                 perf iteration; ~2x FLOP cut at long S).
  * Pallas flash kernel        — kernels/flash_attention.py (TPU target).

All math in float32 accumulators, activations in cfg.dtype.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30

# Trace-time mesh context: jit tracing does not expose the target mesh
# (jax.sharding.get_abstract_mesh() is empty unless set_mesh is active),
# so the step builders wrap their bodies in mesh_context(mesh) and shard()
# reads it to emit constraints with only the axes that exist.
_MESH_VAR = contextvars.ContextVar("repro_mesh", default=None)
_LAYOUT_VAR = contextvars.ContextVar("repro_layout", default="train")


@contextlib.contextmanager
def mesh_context(mesh, layout: str = "train"):
    tok = _MESH_VAR.set(mesh)
    tok2 = _LAYOUT_VAR.set(layout)
    try:
        yield
    finally:
        _MESH_VAR.reset(tok)
        _LAYOUT_VAR.reset(tok2)


def shard(x, *axes):
    """Soft sharding hint against the mesh_context mesh. Axis names not in
    the mesh are dropped (e.g. 'pod' on the single-pod mesh) — naming a
    missing axis raises inside jit and a skipped constraint measurably
    de-shards activations (batch replicated across 'data' in the backward;
    found via 16x-inflated collective bytes in the dry-run)."""
    mesh = _MESH_VAR.get()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def filt(a):
        if isinstance(a, (tuple, list)):
            t = tuple(x for x in a if x in names)
            return t if t else None
        return a if a in names else None

    spec = P(*(filt(a) for a in axes))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


import os

BF16_WIRE = os.environ.get("REPRO_BF16_WIRE", "0") == "1"
EXPLICIT_TP = os.environ.get("REPRO_EXPLICIT_TP", "0") == "1"
# EXPLICIT_TP: lower the TP down-projections (attention out, MLP down) with
# an explicit shard_map (FSDP gather + local matmul + **bf16** psum). The
# implicit-pjit path all-reduces the dot output, which on the CPU dry-run
# backend is fp32 (bf16 dots lower to fp32) — 2x the wire bytes a TPU
# lowering would move. Explicit collectives make the wire dtype a design
# decision instead of a backend artifact. §Perf iteration I5.
# When set, a barrier after each residual add stops XLA from hoisting the
# rms_norm fp32 upcast above the TP all-reduce — activations cross the
# wire in bf16 (2x fewer collective bytes). §Perf iteration I5.


def residual_barrier(x):
    if BF16_WIRE:
        return jax.lax.optimization_barrier(x)
    return x


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float = 1e6):
    """Rotary embedding. x (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                 # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def tp_down_proj(h, w, *, fsdp_axes=("embed",)):
    """Down-projection contracting a TP-sharded inner dim.
    h (B,S,F) sharded (batch, None, 'model'); w (F, D) sharded
    ('model', 'data'). With EXPLICIT_TP and an active mesh: shard_map with
    FSDP weight gather + local matmul + bf16 psum; otherwise plain einsum
    (pjit inserts the all-reduce)."""
    mesh = _MESH_VAR.get()
    if not EXPLICIT_TP or mesh is None or "model" not in mesh.axis_names             or mesh.shape["model"] == 1:
        return residual_barrier(jnp.einsum("bsf,fd->bsd", h, w))
    from jax.experimental.shard_map import shard_map
    names = set(mesh.axis_names)
    ba = tuple(a for a in ("pod", "data") if a in names)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    bspec = ba if ba and h.shape[0] % nb == 0 else None
    dp = mesh.shape.get("data", 1)
    w_fsdp = ("data" in names and dp > 1 and w.shape[1] % dp == 0
              and _LAYOUT_VAR.get() == "train")

    def mapped(h_loc, w_loc):
        if w_fsdp:
            w_loc = jax.lax.all_gather(w_loc, "data", axis=1, tiled=True)
        out = jnp.einsum("bsf,fd->bsd", h_loc, w_loc)
        # wire dtype = model dtype (bf16 in production): the psum payload is
        # an explicit design choice, not a backend lowering artifact
        return jax.lax.psum(out.astype(h.dtype), "model")

    return shard_map(
        mapped, mesh=mesh,
        in_specs=(P(bspec, None, "model"),
                  P("model", "data" if w_fsdp else None)),
        out_specs=P(bspec, None, None), check_rep=False)(h, w)


def swiglu(x, w_gate, w_up, w_down, *, tp_axis="model"):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate)) * \
        jnp.einsum("bsd,df->bsf", x, w_up)
    h = shard(h, ("pod", "data"), None, tp_axis)
    return tp_down_proj(h, w_down)


# --------------------------------------------------------------------- attn
def _mask_bias(q_pos, k_pos, *, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def attention_masked(q, k, v, *, causal=True, window=None,
                     q_offset=0, k_offset=0, q_chunk=512):
    """Baseline attention: scan over q chunks, each attends the full KV with
    an additive mask; online softmax keeps memory at O(q_chunk * Sk).

    q (B,Sq,H,hd), k/v (B,Sk,K,hd), GQA via head grouping. Returns (B,Sq,H,hd).
    """
    B, Sq0, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Sq0)
    if Sq0 % qc:  # pad q rows; padded rows are sliced off the output
        q = jnp.pad(q, ((0, 0), (0, qc - Sq0 % qc), (0, 0), (0, 0)))
    Sq = q.shape[1]
    n_chunks = max(1, Sq // qc)
    qs = q.reshape(B, n_chunks, qc, K, G, hd)
    k_pos = k_offset + jnp.arange(Sk)

    def body(i):
        qi = qs[:, i]                                               # (B,qc,K,G,hd)
        q_pos = q_offset + i * qc + jnp.arange(qc)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale + bias
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
        o = o / jnp.sum(p, axis=-1)[..., None].transpose(0, 3, 1, 2, 4)
        return o.astype(q.dtype)

    out = jax.lax.map(body, jnp.arange(n_chunks))                   # (n,B,qc,K,G,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out[:, :Sq0]


def attention_block_causal(q, k, v, *, causal=True, window=None,
                           q_offset=0, k_offset=0, q_chunk=512):
    """Block-sparse causal attention: a scan over only the (qi, kj) chunk
    pairs that contain unmasked entries. Cuts the masked-dense FLOP waste
    (~2x for causal, more for SWA). Online softmax across kv blocks.
    Requires q_offset == k_offset == 0 (training/prefill use)."""
    B, Sq0, H, hd = q.shape
    _, Sk0, K, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Sq0)
    if Sq0 % qc:
        q = jnp.pad(q, ((0, 0), (0, qc - Sq0 % qc), (0, 0), (0, 0)))
    if Sk0 % qc:
        k = jnp.pad(k, ((0, 0), (0, qc - Sk0 % qc), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, qc - Sk0 % qc), (0, 0), (0, 0)))
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // qc, Sk // qc

    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if (not causal or j <= i)
             and (not window or (i - j) * qc < window + qc)]
    pairs = jnp.array(pairs, dtype=jnp.int32)                       # (npair, 2)

    qs = q.reshape(B, nq, qc, K, G, hd)
    ks = k.reshape(B, nk, qc, K, hd)
    vs = v.reshape(B, nk, qc, K, hd)

    def body(carry, pair):
        m_all, l_all, acc_all = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qs, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(ks, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vs, j, 1, keepdims=False)
        q_pos = i * qc + jnp.arange(qc)
        k_pos = j * qc + jnp.arange(qc)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale + bias
        m_i = jax.lax.dynamic_index_in_dim(m_all, i, 1, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l_all, i, 1, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc_all, i, 1, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bkgqh", p, vj.astype(jnp.float32))
        a_new = a_i * alpha[..., None] + o
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, m_new, i, 1)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l_new, i, 1)
        acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, a_new, i, 1)
        return (m_all, l_all, acc_all), None

    m0 = jnp.full((B, nq, K, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, K, G, qc), jnp.float32)
    a0 = jnp.zeros((B, nq, K, G, qc, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), pairs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]                    # (B,nq,K,G,qc,hd)
    out = jnp.moveaxis(out, 4, 2).reshape(B, Sq, H, hd)
    return out[:, :Sq0].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=None):
    """Single-step attention over a preallocated KV cache.

    q (B,1,H,hd); caches (B,S,K,hd); pos () or (B,) int32 = index of the
    new token per lane (each lane's cache holds valid entries at
    [0..pos_b-1] plus the new one at pos_b). Per-lane positions are what
    make continuous batching possible: a refilled slot restarts at
    pos_b = 0 while its neighbours keep decoding — masked lanes
    contribute exp(NEG_INF - m) == 0.0 exactly, so each lane's output is
    bit-identical to a fresh-cache decode at the same position.
    """
    B, _, H, hd = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    idx = jnp.arange(S)
    pos = jnp.asarray(pos)
    posv = pos[None] if pos.ndim == 0 else pos          # (1,) or (B,)
    valid = idx[None, :] <= posv[:, None]               # (1|B, S)
    if window:
        valid &= idx[None, :] > posv[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention_dense(q, k, v, *, causal=True, window=None,
                    q_offset=0, k_offset=0, q_chunk=None):
    """Loop-free masked attention (single einsum chain). Used by the
    dry-run COST PROBES: XLA's HloCostAnalysis counts while-loop bodies
    once, so probes must not contain loops. Memory-naive (materializes
    S x S scores) — never used on a real workload path."""
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    s = jnp.einsum("bqkgh,bskh->bkgqs",
                   q.reshape(B, Sq, K, G, hd).astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    bias = _mask_bias(q_offset + jnp.arange(Sq), k_offset + jnp.arange(Sk),
                      causal=causal, window=window)
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


ATTN_IMPLS = {
    "masked": attention_masked,
    "block_causal": attention_block_causal,
    "dense": attention_dense,
}
