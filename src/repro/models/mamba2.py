"""Mamba2 — SSD (state-space duality, arXiv:2405.21060) blocks.

Chunked SSD forward for training/prefill (the quadratic intra-chunk part is
also implemented as a Pallas kernel, kernels/ssd_scan.py), and the O(1)
recurrent decode step.

Per layer:  x -> [z | xc | B | C | dt] projections; causal conv1d over
(xc,B,C); SSD recurrence with per-head scalar decay A; gated output.
State per head: (P, N) with P=headdim, N=ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rms_norm, shard


def _conv1d_causal(x, w, state=None):
    """Causal depthwise conv. x (B,S,C), w (K,C). If `state` (B,K-1,C) is
    given, it prefixes x (for decode); returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x  (B,S,H,P)   inputs per head
    dt (B,S,H)     positive step sizes
    A  (H,)        negative per-head decay rates
    Bm (B,S,N), Cm (B,S,N)  input/output projections (single group)
    Returns y (B,S,H,P), final_state (B,H,P,N).
    """
    Bsz, S0, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S0)
    if S0 % Q:  # pad sequence to a chunk multiple (dt=0 => identity steps)
        pad = Q - S0 % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S = x.shape[1]
    nc = S // Q
    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A  # (B,nc,Q,H) log-decay per step (negative)
    cum = jnp.cumsum(a, axis=2)                     # inclusive cumsum within chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q_i,Q_j,H)
    ii, jj = jnp.meshgrid(jnp.arange(Q), jnp.arange(Q), indexing="ij")
    causal = (ii >= jj)[None, None, :, :, None]
    # mask BEFORE exp: exp of large positive (acausal) entries would give
    # inf * 0 = NaN in the backward pass
    L = jnp.exp(jnp.where(causal, seg, -jnp.inf))   # decay from j to i

    # intra-chunk: y_intra[i] = sum_j L[i,j] (C_i . B_j) dt_j x_j
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # (B,nc,Q,Q)
    W = G[..., None] * L                            # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", W, dtc, xc)

    # chunk-boundary states: S_c = decay(chunk) S_{c-1} + sum_j decay(end-j) dt_j x_j B_j
    chunk_decay = jnp.exp(cum[:, :, -1])            # (B,nc,H)
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)    # (B,nc,Q,H) decay j -> chunk end
    S_in = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn", end_decay, dtc, xc, Bc)

    def scan_body(s_prev, inp):
        dec, s_in = inp                             # (B,H), (B,H,P,N)
        s_new = s_prev * dec[:, :, None, None] + s_in
        return s_new, s_prev                        # emit state ENTERING the chunk

    s0 = initial_state if initial_state is not None else \
        jnp.zeros((Bsz, H, Pd, N), x.dtype)
    s0 = s0.astype(jnp.float32)
    final, s_enter = jax.lax.scan(
        scan_body,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32),
         jnp.moveaxis(S_in, 1, 0).astype(jnp.float32)))
    s_enter = jnp.moveaxis(s_enter, 0, 1)           # (B,nc,H,P,N)

    # inter-chunk: y_inter[i] = exp(cum_i) * C_i . S_enter
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp",
                         jnp.exp(cum), Cc, s_enter.astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)[:, :S0]
    return y.astype(x.dtype), final.astype(x.dtype)


def ssm_block(cfg: ModelConfig, lp: dict, x):
    """Full mamba2 layer (training/prefill). x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    di, N, H, Pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, lp["in_z"])
    xc = jnp.einsum("bsd,de->bse", h, lp["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", h, lp["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, lp["in_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, lp["in_dt"])
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, _ = _conv1d_causal(conv_in, lp["conv_w"])
    xc, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    xc = shard(xc, ("pod", "data"), None, None)
    dt = jax.nn.softplus(dt_raw + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xc.reshape(B, S, H, Pd), dt, A, Bm, Cm,
                       chunk=cfg.ssm_chunk)
    y = y + lp["D_skip"][None, None, :, None] * xc.reshape(B, S, H, Pd)
    y = (y.reshape(B, S, di) * jax.nn.silu(z)).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, lp["out_proj"])


# ------------------------------------------------------------------- decode
def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    di, N, H, Pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "state": jnp.zeros((n_layers, batch, H, Pd, N), jnp.float32),
    }


def ssm_decode_step(cfg: ModelConfig, lp: dict, x, conv_state, ssm_state):
    """One-token mamba2 step. x (B,1,D) -> (y (B,1,D), conv_state, ssm_state)."""
    B = x.shape[0]
    di, N, H, Pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, lp["in_z"])
    xc = jnp.einsum("bsd,de->bse", h, lp["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", h, lp["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, lp["in_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, lp["in_dt"])
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_state = _conv1d_causal(conv_in, lp["conv_w"], conv_state)
    xc, Bm, Cm = jnp.split(conv_out[:, 0], [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0] + lp["dt_bias"])            # (B,H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = xc.reshape(B, H, Pd)
    dA = jnp.exp(dt * A)                                           # (B,H)
    upd = (dt[..., None, None] * xh[..., None] *
           Bm[:, None, None, :])                                   # (B,H,P,N)
    ssm_state = ssm_state * dA[..., None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", ssm_state.astype(x.dtype), Cm)
    y = y + lp["D_skip"][None, :, None] * xh
    y = (y.reshape(B, 1, di) * jax.nn.silu(z)).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, lp["out_proj"]), conv_state, ssm_state


def forward(params, cfg: ModelConfig, batch: dict, *, return_hidden=False, **_):
    """Teacher-forced scoring for the pure-SSM family."""
    from .transformer import _scan_blocks, embed_tokens, lm_logits
    x = embed_tokens(cfg, params, batch["tokens"])
    x = _scan_blocks(cfg, params["layers"], x,
                     lambda h, lp: ssm_block(cfg, lp, h))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return lm_logits(cfg, params, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    c = init_ssm_cache(cfg, batch, cfg.n_layers, dtype)
    c["pos"] = jnp.zeros((batch,), jnp.int32)   # per-lane (slot-resettable)
    return c


def decode_step(params, cfg: ModelConfig, cache, prev_tokens):
    from .transformer import embed_tokens, lm_logits
    x = embed_tokens(cfg, params, prev_tokens[:, None])

    def body(carry, xs):
        h = carry
        lp, cs, ss = xs
        h, cs, ss = ssm_decode_step(cfg, lp, h, cs, ss)
        return h, (cs, ss)

    from .transformer import scan_xs
    x, (conv_new, state_new) = scan_xs(
        cfg, body, x, (params["layers"], cache["conv"], cache["state"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"conv": conv_new, "state": state_new,
                    "pos": cache["pos"] + 1}
