"""Single source of truth for parameter trees.

Every model family declares its parameters as a nested dict of ``Leaf``
entries (shape, logical axes, init). From the schema we derive:
  * ``init_params``  — real arrays (smoke tests, measured benchmarks)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run; no allocation)
  * ``param_axes``   — logical-axis tree consumed by sharding/specs.py

Logical axis names (mapped to mesh axes in sharding/specs.py):
  embed    d_model rows (FSDP axis)
  heads    fused q-head dim (TP)         kv_heads  fused kv-head dim
  mlp      ffn hidden (TP)               vocab     vocabulary (TP)
  expert   MoE expert (EP)               ssm_inner mamba inner channels (TP)
  layers   stacked-layer axis (never sharded)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Leaf:
    shape: tuple
    axes: tuple            # logical axis name (or None) per dim
    init: str = "normal"   # normal | zeros | ones | small_normal | a_log | conv
    scale: float = 1.0


def _attn_leaves(cfg: ModelConfig, L: Optional[int], cross: bool = False) -> dict:
    """Attention block leaves; L=None means unstacked (shared block)."""
    D, hd = cfg.d_model, cfg.head_dim
    Hp, Kp = cfg.padded_heads, cfg.padded_kv_heads
    pre = (L,) if L else ()
    lax = ("layers",) if L else ()
    s_in = 1.0 / np.sqrt(D)
    s_out = 1.0 / np.sqrt(Hp * hd)
    p = "x" if cross else ""
    leaves = {
        f"w{p}q": Leaf(pre + (D, Hp * hd), lax + ("embed", "heads"), "normal", s_in),
        f"w{p}k": Leaf(pre + (D, Kp * hd), lax + ("embed", "kv_heads"), "normal", s_in),
        f"w{p}v": Leaf(pre + (D, Kp * hd), lax + ("embed", "kv_heads"), "normal", s_in),
        f"w{p}o": Leaf(pre + (Hp * hd, D), lax + ("heads", "embed"), "normal", s_out),
    }
    if cfg.qk_norm and not cross:
        leaves["q_norm"] = Leaf(pre + (hd,), lax + (None,), "ones")
        leaves["k_norm"] = Leaf(pre + (hd,), lax + (None,), "ones")
    return leaves


def _mlp_leaves(cfg: ModelConfig, L: Optional[int]) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    pre = (L,) if L else ()
    lax = ("layers",) if L else ()
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    return {
        "wi_gate": Leaf(pre + (D, F), lax + ("embed", "mlp"), "normal", s_in),
        "wi_up": Leaf(pre + (D, F), lax + ("embed", "mlp"), "normal", s_in),
        "wo_mlp": Leaf(pre + (F, D), lax + ("mlp", "embed"), "normal", s_out),
    }


def _moe_leaves(cfg: ModelConfig, L: int) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    # Experts take the TP ('model') axis => per-expert F stays unsharded;
    # D rows keep the FSDP ('embed' -> data) axis.
    # 'expert_embed': expert D rows keep the 2D sharding even in the serve
    # layout (resident experts would not fit HBM) — see sharding/specs.py.
    return {
        "router": Leaf((L, D, E), ("layers", "embed", None), "normal", s_in),
        "we_gate": Leaf((L, E, D, F), ("layers", "expert", "expert_embed", None), "normal", s_in),
        "we_up": Leaf((L, E, D, F), ("layers", "expert", "expert_embed", None), "normal", s_in),
        "we_down": Leaf((L, E, F, D), ("layers", "expert", None, "expert_embed"), "normal", s_out),
    }


def _ssm_leaves(cfg: ModelConfig, L: int) -> dict:
    D = cfg.d_model
    di, N, Hs, KC = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    s = 1.0 / np.sqrt(D)
    return {
        "ln": Leaf((L, D), ("layers", None), "ones"),
        "in_z": Leaf((L, D, di), ("layers", "embed", "ssm_inner"), "normal", s),
        "in_x": Leaf((L, D, di), ("layers", "embed", "ssm_inner"), "normal", s),
        "in_B": Leaf((L, D, N), ("layers", "embed", None), "normal", s),
        "in_C": Leaf((L, D, N), ("layers", "embed", None), "normal", s),
        "in_dt": Leaf((L, D, Hs), ("layers", "embed", "ssm_inner"), "normal", s),
        "conv_w": Leaf((L, KC, di + 2 * N), ("layers", None, "ssm_inner"), "conv"),
        "A_log": Leaf((L, Hs), ("layers", "ssm_inner"), "a_log"),
        "D_skip": Leaf((L, Hs), ("layers", "ssm_inner"), "ones"),
        "dt_bias": Leaf((L, Hs), ("layers", "ssm_inner"), "zeros"),
        "out_proj": Leaf((L, di, D), ("layers", "ssm_inner", "embed"),
                         "normal", 1.0 / np.sqrt(di)),
    }


def _norm(L: Optional[int], name: str, D: int) -> dict:
    if L:
        return {name: Leaf((L, D), ("layers", None), "ones")}
    return {name: Leaf((D,), (None,), "ones")}


def schema(cfg: ModelConfig) -> dict:
    """Nested dict of Leaf for the given config."""
    D, L, Vp = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    # embed table: rows replicated, D takes the FSDP axis — a vocab-sharded
    # table turns every lookup into an all-gather + full remat (measured:
    # XLA "involuntary full rematerialization"); the LM head keeps vocab->TP.
    tree: dict = {"embed": Leaf((Vp, D), ("vocab_rows", "embed"), "normal", 1.0)}
    if not cfg.tie_embeddings:
        # lm_head D replicated: FSDP-sharding D makes XLA produce the logits
        # as data-partial products + a (B,S,V_loc) fp32 all-reduce (measured
        # 3 x 2.4 GiB per microbatch on qwen3-1.7b); a replicated D costs
        # only D*V_loc bytes per chip.
        tree["lm_head"] = Leaf((D, Vp), ("embed_head", "vocab"), "normal", 1.0 / np.sqrt(D))
    tree.update(_norm(None, "final_norm", D))

    if cfg.family in ("dense", "vlm"):
        layers = {**_attn_leaves(cfg, L), **_mlp_leaves(cfg, L),
                  **_norm(L, "ln1", D), **_norm(L, "ln2", D)}
        tree["layers"] = layers
    elif cfg.family == "moe":
        layers = {**_attn_leaves(cfg, L), **_moe_leaves(cfg, L),
                  **_norm(L, "ln1", D), **_norm(L, "ln2", D)}
        tree["layers"] = layers
    elif cfg.family == "ssm":
        tree["layers"] = _ssm_leaves(cfg, L)
    elif cfg.family == "hybrid":
        tree["layers"] = _ssm_leaves(cfg, L)
        tree["shared_attn"] = {**_attn_leaves(cfg, None), **_mlp_leaves(cfg, None),
                               **_norm(None, "ln1", D), **_norm(None, "ln2", D)}
    elif cfg.family == "encdec":
        Le = cfg.n_enc_layers
        tree["enc_layers"] = {**_attn_leaves(cfg, Le), **_mlp_leaves(cfg, Le),
                              **_norm(Le, "ln1", D), **_norm(Le, "ln2", D)}
        tree["enc_final_norm"] = Leaf((D,), (None,), "ones")
        tree["dec_layers"] = {**_attn_leaves(cfg, L), **_attn_leaves(cfg, L, cross=True),
                              **_mlp_leaves(cfg, L),
                              **_norm(L, "ln1", D), **_norm(L, "ln_x", D),
                              **_norm(L, "ln2", D)}
    else:
        raise ValueError(cfg.family)
    return tree


def _init_leaf(leaf: Leaf, key, dtype) -> jnp.ndarray:
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    if leaf.init == "a_log":  # mamba2: A in [1, 16) -> log
        u = jax.random.uniform(key, leaf.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if leaf.init == "conv":
        fan = leaf.shape[-2] if len(leaf.shape) > 1 else 4
        return (jax.random.normal(key, leaf.shape, jnp.float32) / np.sqrt(fan)).astype(dtype)
    return (leaf.scale * jax.random.normal(key, leaf.shape, jnp.float32)).astype(dtype)


def init_params(cfg: ModelConfig, key) -> dict:
    sch = schema(cfg)
    flat, treedef = jax.tree_util.tree_flatten(
        sch, is_leaf=lambda x: isinstance(x, Leaf))
    keys = jax.random.split(key, len(flat))
    dtype = jnp.dtype(cfg.dtype)
    arrs = [_init_leaf(leaf, k, dtype) for leaf, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(cfg: ModelConfig) -> dict:
    sch = schema(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype),
        sch, is_leaf=lambda x: isinstance(x, Leaf))


def param_axes(cfg: ModelConfig) -> dict:
    sch = schema(cfg)
    return jax.tree_util.tree_map(
        lambda l: l.axes, sch, is_leaf=lambda x: isinstance(x, Leaf))


def count_params(cfg: ModelConfig) -> int:
    sch = schema(cfg)
    flat, _ = jax.tree_util.tree_flatten(sch, is_leaf=lambda x: isinstance(x, Leaf))
    return int(sum(np.prod(l.shape) for l in flat))
