"""Top-k token-choice Mixture-of-Experts with expert parallelism.

Dispatch design (token-replicated EP, MaxText-flavoured):

* Tokens are sharded over the batch axes (pod, data) and *replicated* over
  the TP/EP axis ('model'), exactly like every other activation in the
  model — no extra resharding on entry.
- Experts are sharded over 'model' (E_loc = E / tp); expert weights keep the
  FSDP axis on D (all-gathered over 'data' at use, like dense FSDP).
* Each model shard routes all of its local tokens, keeps only the
  (token, slot) pairs owned by its local experts, packs them into an
  (E_loc, C, D) capacity buffer with a sort-based rank (no (T,E) one-hot
  blowup), runs the expert FFNs as one batched einsum, scatters back, and
  psums partial outputs over 'model'.
* Communication per layer = FSDP weight all-gather + one psum over
  'model' — there is **no all-to-all**; the trade is E-way routing compute
  replication (router is D*E, negligible). An a2a variant is a recorded
  perf-iteration candidate (EXPERIMENTS.md §Perf).

The same routine with tp=1 is the single-device reference path used in
smoke tests and as the oracle for the distributed test.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rms_norm, shard


def _route(x_flat, router_w, top_k):
    """x (T,D) -> (weights (T,k) fp32, experts (T,k) int32). Softmax over the
    selected top-k logits (qwen3/mixtral convention)."""
    logits = jnp.einsum("td,de->te", x_flat, router_w).astype(jnp.float32)
    vals, experts = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(vals, axis=-1)
    return weights, experts


def _rank_within_expert(flat_experts, n_experts):
    """Position of each (token,slot) within its expert's arrival order.
    Sort-based: O(Tk log Tk) local, no (Tk, E) one-hot materialization."""
    Tk = flat_experts.shape[0]
    order = jnp.argsort(flat_experts, stable=True)
    se = jnp.sort(flat_experts)
    first = jnp.searchsorted(se, jnp.arange(n_experts))
    rank_sorted = jnp.arange(Tk) - first[se]
    return jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def _expert_ffn(buf, w_gate, w_up, w_down):
    """buf (E,C,D) through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn_local(x_flat, lp, cfg: ModelConfig, *, shard_id=0, n_shards=1,
                  gathered=None, dropless=False):
    """Dispatch + expert compute for the experts owned by `shard_id`.
    Returns the *partial* output (full output iff n_shards == 1).

    dropless=True sets capacity C = T: since top-k experts are distinct per
    token, no expert can receive more than T tokens, so nothing is ever
    dropped. The compression/serving paths REQUIRE dropless — capacity
    drops depend on the whole dispatch group, so a capacity-dropped scoring
    pass and the decompressor's decode pass could disagree, breaking
    losslessness. Training uses the standard capacity factor."""
    T, D = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_shards
    C = T if dropless else max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    w_gate, w_up, w_down = gathered if gathered is not None else (
        lp["we_gate"], lp["we_up"], lp["we_down"])

    weights, experts = _route(x_flat, lp["router"], k)      # (T,k)
    fe = experts.reshape(-1)                                # (Tk,)
    rank = _rank_within_expert(fe, E)
    local = (fe >= shard_id * E_loc) & (fe < (shard_id + 1) * E_loc)
    keep = (rank < C) & local
    le = fe - shard_id * E_loc                              # local expert id
    dest = jnp.where(keep, le * C + rank, E_loc * C)        # overflow slot
    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E_loc * C + 1, D), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[tok_idx], mode="drop",
                           unique_indices=False)
    out_buf = _expert_ffn(buf[:-1].reshape(E_loc, C, D),
                          w_gate, w_up, w_down)
    out_buf = jnp.concatenate(
        [out_buf.reshape(E_loc * C, D), jnp.zeros((1, D), out_buf.dtype)], 0)
    y_slots = out_buf[dest] * (weights.reshape(-1)[:, None] *
                               keep[:, None]).astype(out_buf.dtype)
    return jnp.sum(y_slots.reshape(T, k, D), axis=1)


def moe_block(cfg: ModelConfig, lp: dict, x, *, mesh=None, dropless=False,
              dispatch_group: int = 0):
    """Full MoE FFN sub-block (post-norm residual applied by caller).
    x (B,S,D). With a mesh, runs the EP shard_map path; otherwise the
    single-shard reference path. `dispatch_group` > 0 splits the tokens
    into groups of that size before dispatch (bounds the dropless buffer
    for long prefills; any grouping is exact when dropless)."""
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    if mesh is None or "model" not in mesh.axis_names or \
            mesh.shape["model"] == 1:
        if dropless and dispatch_group and x_flat.shape[0] > dispatch_group:
            G = dispatch_group
            T = x_flat.shape[0]
            assert T % G == 0, (T, G)
            y = jax.lax.map(
                lambda xg: moe_ffn_local(xg, lp, cfg, dropless=True),
                x_flat.reshape(T // G, G, D))
            return y.reshape(B, S, D)
        y = moe_ffn_local(x_flat, lp, cfg, dropless=dropless)
        return y.reshape(B, S, D)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from .layers import _LAYOUT_VAR
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    dp = mesh.shape.get("data", 1)
    serve = (_LAYOUT_VAR.get() == "serve" and dp > 1
             and cfg.d_model % dp == 0)

    if serve:
        # Serve layout: tokens are few (decode) — REPLICATE them over
        # 'data' and contract each chip's resident D-slice of its local
        # experts; psum partials over ('data','model'). No weight gather.
        def mapped_serve(xf, router, wg, wu, wd):
            shard_m = jax.lax.axis_index("model")
            shard_d = jax.lax.axis_index("data")
            D_loc = cfg.d_model // dp
            lp_loc = {"router": router}
            E, k = cfg.n_experts, cfg.top_k
            E_loc = E // tp
            T = xf.shape[0]
            C = T  # dropless
            weights, experts = _route(xf, router, k)
            fe = experts.reshape(-1)
            rank = _rank_within_expert(fe, E)
            local = (fe >= shard_m * E_loc) & (fe < (shard_m + 1) * E_loc)
            keep = (rank < C) & local
            le = fe - shard_m * E_loc
            dest = jnp.where(keep, le * C + rank, E_loc * C)
            tok_idx = jnp.repeat(jnp.arange(T), k)
            x_slice = jax.lax.dynamic_slice(
                xf, (0, shard_d * D_loc), (T, D_loc))
            buf = jnp.zeros((E_loc * C + 1, D_loc), xf.dtype)
            buf = buf.at[dest].set(x_slice[tok_idx], mode="drop")
            bufe = buf[:-1].reshape(E_loc, C, D_loc)
            # D-partial up/gate, psum over data, then local down D-slice
            hg = jnp.einsum("ecd,edf->ecf", bufe, wg)
            hu = jnp.einsum("ecd,edf->ecf", bufe, wu)
            hg = jax.lax.psum(hg, "data")
            hu = jax.lax.psum(hu, "data")
            h = jax.nn.silu(hg) * hu
            out = jnp.einsum("ecf,efd->ecd", h, wd)   # (E_loc, C, D_loc)
            out = jnp.concatenate(
                [out.reshape(E_loc * C, D_loc),
                 jnp.zeros((1, D_loc), out.dtype)], 0)
            y_slots = out[dest] * (weights.reshape(-1)[:, None] *
                                   keep[:, None]).astype(out.dtype)
            y = jnp.sum(y_slots.reshape(T, k, D_loc), axis=1)
            # assemble full D by all-gather over data (tiny: T x D_loc),
            # sum expert contributions over model
            y = jax.lax.all_gather(y, "data", axis=1, tiled=True)
            return jax.lax.psum(y, "model")

        y = shard_map(
            mapped_serve, mesh=mesh,
            in_specs=(P(None, None), P(None, None),
                      P("model", "data", None), P("model", "data", None),
                      P("model", None, "data")),
            out_specs=P(None, None),
            check_rep=False,
        )(x_flat, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
        return y.reshape(B, S, D)

    def mapped(xf, router, wg, wu, wd):
        # FSDP gather of expert weights over 'data' (D rows axis=2 of (E,D,F))
        if "data" in mesh.axis_names and mesh.shape["data"] > 1:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        shard_id = jax.lax.axis_index("model")
        lp_loc = {"router": router, "we_gate": wg, "we_up": wu, "we_down": wd}

        def run(xg):
            return moe_ffn_local(xg, lp_loc, cfg, shard_id=shard_id,
                                 n_shards=tp, gathered=(wg, wu, wd),
                                 dropless=dropless)

        if dropless and dispatch_group and xf.shape[0] > dispatch_group:
            G = dispatch_group
            T = xf.shape[0]
            assert T % G == 0, (T, G)
            y = jax.lax.map(run, xf.reshape(T // G, G, xf.shape[1]))
            y = y.reshape(T, xf.shape[1])
        else:
            y = run(xf)
        return jax.lax.psum(y, "model")

    y = shard_map(
        mapped, mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=P(batch_axes, None),
        check_rep=False,
    )(x_flat, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
    return y.reshape(B, S, D)


def moe_dense_block(cfg: ModelConfig, lp: dict, x, *, positions,
                    attn_impl="masked", q_chunk=512, mesh=None,
                    dropless=False, dispatch_group=0):
    """Attention + MoE FFN transformer block."""
    from .transformer import attn_block
    a, _ = attn_block(cfg, lp, rms_norm(x, lp["ln1"], cfg.norm_eps),
                      positions=positions, attn_impl=attn_impl,
                      q_chunk=q_chunk)
    x = x + a
    x = x + moe_block(cfg, lp, rms_norm(x, lp["ln2"], cfg.norm_eps),
                      mesh=mesh, dropless=dropless,
                      dispatch_group=dispatch_group)
    return x


def forward(params, cfg: ModelConfig, batch: dict, *, attn_impl="masked",
            q_chunk=512, mesh=None, dropless=False, dispatch_group=0,
            return_hidden=False):
    from .transformer import _scan_blocks, embed_tokens, lm_logits
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x = _scan_blocks(cfg, params["layers"], x,
                     lambda h, lp: moe_dense_block(
                         cfg, lp, h, positions=positions,
                         attn_impl=attn_impl, q_chunk=q_chunk, mesh=mesh,
                         dropless=dropless, dispatch_group=dispatch_group))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return lm_logits(cfg, params, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    from .transformer import init_cache as dense_init_cache
    return dense_init_cache(cfg, batch, max_len, dtype)


def decode_step(params, cfg: ModelConfig, cache, prev_tokens, *, mesh=None,
                dropless=True):
    from .transformer import (_decode_attn_one, embed_tokens, lm_logits)
    pos = cache["pos"]
    x = embed_tokens(cfg, params, prev_tokens[:, None])

    def body(carry, xs):
        h = carry
        lp, kc, vc = xs
        a, kc, vc = _decode_attn_one(cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps),
                                     kc, vc, pos)
        h = h + a
        h = h + moe_block(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps),
                          mesh=mesh, dropless=dropless)
        return h, (kc, vc)

    from .transformer import scan_xs
    x, (k_new, v_new) = scan_xs(
        cfg, body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
