from .api import (abstract_params, count_params, decode_step, forward,
                  init_cache, init_params, loss_fn, module_for, param_axes)
