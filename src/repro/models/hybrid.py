"""Zamba2-style hybrid: stacks of Mamba2 (SSD) layers with ONE shared
attention+MLP block applied after every `hybrid_ssm_per_block` SSM layers
(arXiv:2411.15242 — the shared block reuses the same weights at every
application; each application keeps its own KV cache).

Layout: n_layers SSM layers total. n_apply = n_layers // per_block shared-
attention applications; leftover SSM layers (n_layers % per_block) run at
the end. The main body is a nested scan: outer over groups (carrying the
residual), inner over the group's SSM layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rms_norm
from .mamba2 import init_ssm_cache, ssm_block, ssm_decode_step
from .transformer import (_decode_attn_one, dense_block, embed_tokens,
                          lm_logits, scan_xs)


def _split_layers(cfg: ModelConfig, layers):
    per = cfg.hybrid_ssm_per_block
    n_apply = cfg.n_layers // per
    main = n_apply * per
    grouped = jax.tree_util.tree_map(
        lambda a: a[:main].reshape((n_apply, per) + a.shape[1:]), layers)
    rest = jax.tree_util.tree_map(lambda a: a[main:], layers)
    n_rest = cfg.n_layers - main
    return grouped, rest, n_apply, n_rest


def forward(params, cfg: ModelConfig, batch: dict, *, attn_impl="masked",
            q_chunk=512, return_hidden=False, **_):
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    grouped, rest, n_apply, n_rest = _split_layers(cfg, params["layers"])
    shared = params["shared_attn"]

    def ssm_body(carry, lp):
        return ssm_block(cfg, lp, carry), None

    attn_fn = lambda h: dense_block(cfg, shared, h, positions=positions,
                                    attn_impl=attn_impl, q_chunk=q_chunk)
    if cfg.remat:
        # remat per-layer, NOT per-group: checkpointing a scan-of-scan makes
        # the 512-way SPMD backward blow up compile time (>20 min measured)
        ssm_body = jax.checkpoint(ssm_body, prevent_cse=False)
        attn_fn = jax.checkpoint(attn_fn, prevent_cse=False)

    def group_body(carry, group_params):
        h, _ = scan_xs(cfg, ssm_body, carry, group_params)
        return attn_fn(h), None

    x, _ = scan_xs(cfg, group_body, x, grouped)
    if n_rest:
        x, _ = scan_xs(cfg, ssm_body, x, rest)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return lm_logits(cfg, params, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_apply = cfg.n_layers // cfg.hybrid_ssm_per_block
    Kp, hd = cfg.padded_kv_heads, cfg.head_dim
    c = init_ssm_cache(cfg, batch, cfg.n_layers, dtype)
    c["k"] = jnp.zeros((n_apply, batch, max_len, Kp, hd), dtype)
    c["v"] = jnp.zeros((n_apply, batch, max_len, Kp, hd), dtype)
    c["pos"] = jnp.zeros((batch,), jnp.int32)   # per-lane (slot-resettable)
    return c


def decode_step(params, cfg: ModelConfig, cache, prev_tokens, **_):
    pos = cache["pos"]
    x = embed_tokens(cfg, params, prev_tokens[:, None])
    grouped, rest, n_apply, n_rest = _split_layers(cfg, params["layers"])
    per = cfg.hybrid_ssm_per_block
    main = n_apply * per
    conv_g = jax.tree_util.tree_map(
        lambda a: a[:main].reshape((n_apply, per) + a.shape[1:]),
        cache["conv"])
    state_g = jax.tree_util.tree_map(
        lambda a: a[:main].reshape((n_apply, per) + a.shape[1:]),
        cache["state"])
    shared = params["shared_attn"]

    def ssm_body(carry, xs):
        lp, cs, ss = xs
        h, cs, ss = ssm_decode_step(cfg, lp, carry, cs, ss)
        return h, (cs, ss)

    def group_body(carry, xs):
        gp, cs, ss, kc, vc = xs
        h, (cs, ss) = scan_xs(cfg, ssm_body, carry, (gp, cs, ss))
        a, kc, vc = _decode_attn_one(
            cfg, shared, rms_norm(h, shared["ln1"], cfg.norm_eps), kc, vc, pos)
        h = h + a
        from .layers import swiglu
        h = h + swiglu(rms_norm(h, shared["ln2"], cfg.norm_eps),
                       shared["wi_gate"], shared["wi_up"], shared["wo_mlp"])
        return h, (cs, ss, kc, vc)

    x, (conv_new, state_new, k_new, v_new) = scan_xs(
        cfg, group_body, x, (grouped, conv_g, state_g, cache["k"], cache["v"]))
    conv_out = [conv_new.reshape((main,) + conv_new.shape[2:])]
    state_out = [state_new.reshape((main,) + state_new.shape[2:])]
    if n_rest:
        rest_conv = cache["conv"][main:]
        rest_state = cache["state"][main:]
        x, (rc, rs) = scan_xs(cfg, ssm_body, x, (rest, rest_conv, rest_state))
        conv_out.append(rc)
        state_out.append(rs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    new_cache = {
        "conv": jnp.concatenate(conv_out, 0),
        "state": jnp.concatenate(state_out, 0),
        "k": k_new, "v": v_new, "pos": pos + 1,
    }
    return logits, new_cache
