"""Family dispatch: a single forward/init_cache/decode_step API over the
six model families."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import encdec, hybrid, mamba2, moe, transformer
from .schema import abstract_params, count_params, init_params, param_axes

_FAMS = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
}


def module_for(cfg: ModelConfig):
    return _FAMS[cfg.family]


def forward(params, cfg: ModelConfig, batch: dict, **kw):
    """Teacher-forced scoring -> logits (B, S, padded_vocab)."""
    return module_for(cfg).forward(params, cfg, batch, **kw)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None, **kw):
    return module_for(cfg).init_cache(cfg, batch, max_len, dtype, **kw)


def decode_step(params, cfg: ModelConfig, cache, prev_tokens, **kw):
    """(logits (B, padded_vocab), new_cache)."""
    if cfg.family in ("moe",):
        return moe.decode_step(params, cfg, cache, prev_tokens, **kw)
    kw.pop("mesh", None)
    return module_for(cfg).decode_step(params, cfg, cache, prev_tokens, **kw)


def _ce_from_logits(logits, targets, vocab_size):
    """Cross entropy via one-hot einsum. take_along_axis/gather on a
    sharded vocab dim makes XLA replicate the full fp32 logits across the
    batch axis ("involuntary full rematerialization" — measured: a 2.4 GiB
    all-gather per microbatch on qwen3-1.7b); the one-hot contraction
    partitions cleanly (psum over the model axis)."""
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, vocab_size, dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", lg, onehot.astype(jnp.float32))
    return lse - tgt


def loss_fn(params, cfg: ModelConfig, batch: dict, *, loss_block: int = 0,
            **kw):
    """Next-token cross entropy (paper Eq. 16). batch['tokens'] (B,S):
    input tokens[:, :-1], target tokens[:, 1:].

    loss_block > 0 evaluates the LM head + CE per position-block
    (jax.lax.map + remat) so fp32 logits are materialized only per block —
    §Perf iteration; 0 keeps the single-shot head."""
    # Keep the full S tokens as input (token counts stay divisible by the
    # batch mesh axes — the MoE shard_map requires it); the final position
    # predicts a PAD target with zero mask.
    tokens = batch["tokens"]
    inp = dict(batch)
    inp["tokens"] = tokens
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = (jnp.ones(targets.shape, jnp.float32) if "mask" not in batch
            else batch["mask"].astype(jnp.float32))
    mask = mask.at[:, -1].set(0.0)
    if loss_block:
        from repro.models.transformer import lm_logits
        hidden = forward(params, cfg, inp, return_hidden=True, **kw)
        B, S, D = hidden.shape
        sb = loss_block
        pad = (-S) % sb
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nblk = hidden.shape[1] // sb
        hb = jnp.moveaxis(hidden.reshape(B, nblk, sb, D), 1, 0)
        tb = jnp.moveaxis(targets.reshape(B, nblk, sb), 1, 0)

        @jax.checkpoint
        def blk(args):
            h, t = args
            return _ce_from_logits(lm_logits(cfg, params, h), t,
                                   cfg.padded_vocab)

        nll = jax.lax.map(blk, (hb, tb))
        nll = jnp.moveaxis(nll, 0, 1).reshape(B, nblk * sb)
    else:
        logits = forward(params, cfg, inp, **kw)
        nll = _ce_from_logits(logits, targets, cfg.padded_vocab)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


__all__ = ["forward", "init_cache", "decode_step", "loss_fn", "module_for",
           "init_params", "abstract_params", "param_axes", "count_params"]
