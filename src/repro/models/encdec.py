"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: `input_specs()` /
batches provide precomputed frame embeddings (B, S_enc, D) directly.
Encoder: bidirectional attention + sinusoidal positions. Decoder: causal
self-attention (RoPE — adaptation from whisper's learned embeddings so the
assigned 32k decode shapes are well-defined; recorded in DESIGN.md) +
cross-attention over encoder states + MLP.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rms_norm, swiglu
from .transformer import (_decode_attn_one, _scan_blocks, attn_block,
                          decode_attention, embed_tokens, lm_logits, scan_xs)


def sinusoidal(S: int, D: int, dtype=jnp.float32):
    pos = np.arange(S)[:, None]
    dim = np.arange(D // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / D)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


def encode(params, cfg: ModelConfig, frames):
    """frames (B, S_enc, D) -> encoder states (B, S_enc, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal(x.shape[1], x.shape[2], x.dtype)

    def block(h, lp):
        a, _ = attn_block(cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps),
                          positions=None, causal=False)
        h = h + a
        return h + swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                          lp["wi_gate"], lp["wi_up"], lp["wo_mlp"])

    x = _scan_blocks(cfg, params["enc_layers"], x, block)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _dec_block(cfg, lp, h, enc_kv, positions, attn_impl, q_chunk):
    a, _ = attn_block(cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps),
                      positions=positions, attn_impl=attn_impl,
                      q_chunk=q_chunk)
    h = h + a
    xa, _ = attn_block(cfg, lp, rms_norm(h, lp["ln_x"], cfg.norm_eps),
                       positions=None, prefix="x", causal=False,
                       kv_override=enc_kv, attn_impl=attn_impl,
                       q_chunk=q_chunk)
    h = h + xa
    return h + swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                      lp["wi_gate"], lp["wi_up"], lp["wo_mlp"])


def forward(params, cfg: ModelConfig, batch: dict, *, attn_impl="masked",
            q_chunk=512, return_hidden=False, **_):
    """batch: frames (B,S_enc,D) + tokens (B,S_dec) -> logits (B,S_dec,Vp)."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])

    def block(h, lp):
        # project encoder states with this layer's cross-attn K/V
        B, Se, D = enc.shape
        Kp, hd = cfg.padded_kv_heads, cfg.head_dim
        k = jnp.einsum("bsd,dh->bsh", enc, lp["wxk"]).reshape(B, Se, Kp, hd)
        v = jnp.einsum("bsd,dh->bsh", enc, lp["wxv"]).reshape(B, Se, Kp, hd)
        return _dec_block(cfg, lp, h, (k, v, None), positions,
                          attn_impl, q_chunk)

    x = _scan_blocks(cfg, params["dec_layers"], x, block)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return lm_logits(cfg, params, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               source_len: int | None = None):
    # int8 KV not plumbed for enc-dec (cross-attn cache is prefill-written);
    # self-attn cache stays in model dtype.
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, Kp, hd = cfg.n_layers, cfg.padded_kv_heads, cfg.head_dim
    Se = source_len or cfg.max_source_len
    return {
        "k": jnp.zeros((L, batch, max_len, Kp, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Kp, hd), dtype),
        # cross-attn K/V precomputed from encoder states at prefill
        "xk": jnp.zeros((L, batch, Se, Kp, hd), dtype),
        "xv": jnp.zeros((L, batch, Se, Kp, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),   # per-lane (slot-resettable)
    }


def precompute_cross_kv(params, cfg: ModelConfig, frames):
    """Run the encoder once and cache every decoder layer's cross K/V."""
    enc = encode(params, cfg, frames)
    B, Se, D = enc.shape
    Kp, hd = cfg.padded_kv_heads, cfg.head_dim

    def per_layer(lp):
        k = jnp.einsum("bsd,dh->bsh", enc, lp["wxk"]).reshape(B, Se, Kp, hd)
        v = jnp.einsum("bsd,dh->bsh", enc, lp["wxv"]).reshape(B, Se, Kp, hd)
        return k, v

    k, v = jax.vmap(per_layer)(params["dec_layers"])
    return k, v


def decode_step(params, cfg: ModelConfig, cache, prev_tokens, **_):
    pos = cache["pos"]
    x = embed_tokens(cfg, params, prev_tokens[:, None])

    def body(carry, xs):
        h = carry
        lp, kc, vc, xk, xv = xs
        a, kc, vc = _decode_attn_one(
            cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps), kc, vc, pos)
        h = h + a
        # cross attention: full (static) source, no causal mask
        B = h.shape[0]
        Hp, Kp, hd = cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim
        hq = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", hq, lp["wxq"]).reshape(B, 1, Hp, hd)
        o = decode_attention(q, xk, xv, jnp.asarray(xk.shape[1] - 1))
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, Hp * hd), lp["wxo"])
        h = h + swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                       lp["wi_gate"], lp["wi_up"], lp["wo_mlp"])
        return h, (kc, vc)

    x, (k_new, v_new) = scan_xs(
        cfg, body, x, (params["dec_layers"], cache["k"], cache["v"],
                       cache["xk"], cache["xv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {**cache, "k": k_new, "v": v_new, "pos": pos + 1}
