"""Dense decoder-only transformer (llama/qwen family) with scan-over-layers,
remat, GQA, RoPE, qk-norm, and sliding-window attention.

The attention + MLP block functions here are reused by the MoE, hybrid,
encoder-decoder and VLM families.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .layers import (ATTN_IMPLS, decode_attention, residual_barrier,
                     rms_norm, rope, shard, swiglu, tp_down_proj, NEG_INF)


# ------------------------------------------------------------ shared blocks
def attn_block(cfg: ModelConfig, lp: dict, x, *, positions,
               attn_impl="masked", prefix="", kv_override=None,
               causal=True, q_chunk=512):
    """Pre-norm attention block (residual applied by caller).
    kv_override: (k, v, kv_positions) for cross-attention."""
    B, S, D = x.shape
    hd, Hp, Kp = cfg.head_dim, cfg.padded_heads, cfg.padded_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}q"]).reshape(B, S, Hp, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}k"]).reshape(B, S, Kp, hd)
        v = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}v"]).reshape(B, S, Kp, hd)
        kv_positions = positions
    else:
        k, v, kv_positions = kv_override
    if cfg.qk_norm and not prefix:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if positions is not None:  # rotary (None => absolute/sinusoidal handled outside)
        q = rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = rope(k, kv_positions, cfg.rope_theta)
    q = shard(q, ("pod", "data"), None, "model", None)
    k = shard(k, ("pod", "data"), None, None, None)
    impl = ATTN_IMPLS[attn_impl]
    o = impl(q, k, v, causal=causal, window=cfg.sliding_window, q_chunk=q_chunk)
    o = o.reshape(B, S, Hp * hd)
    return tp_down_proj(o, lp[f"w{prefix}o"]), (k, v)


def dense_block(cfg: ModelConfig, lp: dict, x, *, positions,
                attn_impl="masked", q_chunk=512, causal=True):
    a, _ = attn_block(cfg, lp, rms_norm(x, lp["ln1"], cfg.norm_eps),
                      positions=positions, attn_impl=attn_impl,
                      q_chunk=q_chunk, causal=causal)
    x = residual_barrier(x + a)
    x = residual_barrier(
        x + swiglu(rms_norm(x, lp["ln2"], cfg.norm_eps),
                   lp["wi_gate"], lp["wi_up"], lp["wo_mlp"]))
    return x


def embed_tokens(cfg: ModelConfig, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, ("pod", "data"), None, None)


def lm_logits(cfg: ModelConfig, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = shard(logits, ("pod", "data"), None, "model")
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding ids
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, NEG_INF, logits.astype(jnp.float32)).astype(logits.dtype)
    return logits


def scan_xs(cfg: ModelConfig, body, carry, xs):
    """lax.scan when cfg.scan_layers else an unrolled Python loop (cost
    probes need loop-free HLO — see launch/dryrun.py)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _scan_blocks(cfg: ModelConfig, layers_params, x, block_fn):
    """Scan `block_fn(x, layer_params) -> x` over stacked layers with remat."""
    def body(carry, lp):
        return block_fn(carry, lp), None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, layers_params)
    else:
        L = jax.tree_util.tree_leaves(layers_params)[0].shape[0]
        for i in range(L):
            lp = jax.tree_util.tree_map(lambda a: a[i], layers_params)
            x, _ = body(x, lp)
    return x


# ------------------------------------------------------------------ forward
def forward(params, cfg: ModelConfig, batch: dict, *,
            attn_impl="masked", q_chunk=512, return_hidden=False):
    """Teacher-forced scoring: batch['tokens'] (B,S) -> logits (B,S,Vp).
    logits[:, t] predicts tokens[:, t+1] (standard causal LM convention;
    the compressor adapter handles the BOS shift)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype)   # (B, n_img, D) stub frontend
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
    positions = jnp.arange(S)
    block = partial(dense_block, cfg, positions=positions,
                    attn_impl=attn_impl, q_chunk=q_chunk)
    x = _scan_blocks(cfg, params["layers"],
                     x, lambda h, lp: block(lp, h))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm" and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1]:]     # only text positions score
    if return_hidden:
        return x
    return lm_logits(cfg, params, x)


# -------------------------------------------------------------------- cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """KV cache. kv_cache_dtype="int8" stores quantized K/V with per-
    (position, head) fp16 scales — halves decode HBM traffic vs bf16
    (§Perf iteration; decompression is decode/memory-bound). Losslessness
    is unaffected: compressor and decompressor run the same program.

    ``pos`` is PER-LANE (B,): every batch lane carries its own decode
    position, so the continuous-batching scheduler (repro.service) can
    reset one slot to a fresh context while the rest keep stepping —
    lock-step callers simply see all lanes advance together."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    L, Kp, hd = cfg.n_layers, cfg.padded_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((L, batch, S, Kp, hd), jnp.int8),
            "v": jnp.zeros((L, batch, S, Kp, hd), jnp.int8),
            "k_scale": jnp.zeros((L, batch, S, Kp), jnp.float16),
            "v_scale": jnp.zeros((L, batch, S, Kp), jnp.float16),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, S, Kp, hd), dtype),
        "v": jnp.zeros((L, batch, S, Kp, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _quant_kv(x):
    """x (B,1,K,hd) -> (int8, fp16 scale (B,1,K))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    scale = (amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequant_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def _cache_slot(cfg: ModelConfig, pos, cache_len):
    """Physical slot for absolute position `pos` (ring buffer under SWA)."""
    return pos % cache_len if cfg.sliding_window else pos


def decode_requires_lockstep(cfg, mesh=None) -> bool:
    """True when decode for ``cfg`` takes the seq-sharded TP attention
    path (KV heads don't divide TP, no sliding window, explicit-TP or
    serve layout): that path collapses per-lane cache positions to a
    single max, so it is lock-step only — no per-slot refill. ``mesh``
    defaults to the ambient mesh context; callers outside the context
    (the service scheduler's up-front refusal) pass the predictor's mesh
    explicitly. One predicate shared with ``_use_seq_sharded_decode`` so
    the refusal and the dispatch cannot drift."""
    from .layers import _MESH_VAR, _LAYOUT_VAR, EXPLICIT_TP
    mesh = _MESH_VAR.get() if mesh is None else mesh
    explicit = EXPLICIT_TP or _LAYOUT_VAR.get() == "serve"
    if not explicit or mesh is None \
            or "model" not in getattr(mesh, "axis_names", ()):
        return False
    tp = mesh.shape["model"]
    return (tp > 1 and getattr(cfg, "padded_kv_heads", 0) % tp != 0
            and not getattr(cfg, "sliding_window", 0))


def _use_seq_sharded_decode(cfg):
    """Flash-decode combine applies when the cache seq dim is TP-sharded
    (KV heads don't divide TP) — see cache_pspecs."""
    from .layers import _MESH_VAR
    mesh = _MESH_VAR.get()
    return mesh if decode_requires_lockstep(cfg, mesh) else None


def _seq_sharded_decode_attn(cfg, mesh, q, k_new, v_new, kc, vc, pos,
                             scales=None):
    """Flash-decode over a SEQUENCE-sharded KV cache (KV heads don't divide
    TP, e.g. kv=8 on model=16). shard_map: each model shard updates its
    local slice, computes a partial online softmax, and partials combine
    with a log-sum-exp psum — O(B·H·hd) wire bytes instead of XLA's
    cache-sized gather (§Perf iteration C2). Returns (o, kc, vc, scales).

    This TP path keeps the lock-step assumption: all lanes share one
    position (the service scheduler's per-slot reset is a single-host /
    replicated-cache feature; see DESIGN.md §8)."""
    from jax.experimental.shard_map import shard_map
    pos = jnp.max(jnp.asarray(pos))     # uniform across lanes by contract
    B, _, Hp, hd = q.shape
    S = kc.shape[1]
    tp = mesh.shape["model"]
    S_loc = S // tp
    names = set(mesh.axis_names)
    ba = tuple(a for a in ("pod", "data") if a in names)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    bspec = ba if ba and B % nb == 0 else None
    int8 = scales is not None

    def mapped(q, k_new, v_new, kc_loc, vc_loc, *sc):
        shard = jax.lax.axis_index("model")
        in_mine = (pos >= shard * S_loc) & (pos < (shard + 1) * S_loc)
        slot_loc = jnp.where(in_mine, pos - shard * S_loc, 0)

        def upd4(c, n):
            return jnp.where(in_mine, jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, slot_loc, 0, 0)), c)

        def upd3(c, n):
            return jnp.where(in_mine, jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, slot_loc, 0)), c)

        if int8:
            ks_loc, vs_loc = sc
            kq, k_sc = _quant_kv(k_new)
            vq, v_sc = _quant_kv(v_new)
            kc_loc, vc_loc = upd4(kc_loc, kq), upd4(vc_loc, vq)
            ks_loc, vs_loc = upd3(ks_loc, k_sc), upd3(vs_loc, v_sc)
            k_eff = _dequant_kv(kc_loc, ks_loc)
            v_eff = _dequant_kv(vc_loc, vs_loc)
        else:
            kc_loc, vc_loc = upd4(kc_loc, k_new), upd4(vc_loc, v_new)
            k_eff, v_eff = kc_loc, vc_loc
        K = k_eff.shape[2]
        G = Hp // K
        qg = q[:, 0].reshape(-1, K, G, hd)
        s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                       k_eff.astype(jnp.float32)) / jnp.sqrt(float(hd))
        idx = shard * S_loc + jnp.arange(S_loc)
        s = jnp.where((idx <= pos)[None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m_loc)
        l_loc = jnp.sum(p, axis=-1, keepdims=True)
        o_loc = jnp.einsum("bkgs,bskh->bkgh", p, v_eff.astype(jnp.float32))
        m_g = jax.lax.pmax(m_loc, "model")
        w = jnp.exp(m_loc - m_g)                 # (b,K,G,1)
        l = jax.lax.psum(l_loc * w, "model")     # (b,K,G,1)
        o = jax.lax.psum(o_loc * w, "model")     # (b,K,G,hd)
        o = o / jnp.maximum(l, 1e-30)
        out = o.reshape(-1, 1, Hp, hd).astype(q.dtype)
        if int8:
            return out, kc_loc, vc_loc, ks_loc, vs_loc
        return out, kc_loc, vc_loc

    kv_spec = P(bspec, "model", None, None)
    sc_spec = P(bspec, "model", None)
    q_spec = P(bspec, None, None, None)
    if int8:
        o, kc, vc, ks, vs = shard_map(
            mapped, mesh=mesh,
            in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec,
                      sc_spec, sc_spec),
            out_specs=(q_spec, kv_spec, kv_spec, sc_spec, sc_spec),
            check_rep=False)(q, k_new, v_new, kc, vc, *scales)
        return o, kc, vc, (ks, vs)
    o, kc, vc = shard_map(
        mapped, mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec),
        out_specs=(q_spec, kv_spec, kv_spec),
        check_rep=False)(q, k_new, v_new, kc, vc)
    return o, kc, vc, None


def _decode_attn_one(cfg, lp, x, kc, vc, pos, prefix="", scales=None):
    """One-token attention vs. a (B,S,K,hd) cache; returns out, new kc/vc
    (+ new scales when the cache is int8-quantized).

    ``pos`` is (B,): each lane reads/writes its own cache position
    (scatter update + per-lane causal mask), which is what lets the
    service scheduler hold lanes at different chunk offsets. With all
    lanes equal this computes exactly what the old scalar-pos path did."""
    B, _, D = x.shape
    hd, Hp, Kp = cfg.head_dim, cfg.padded_heads, cfg.padded_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}q"]).reshape(B, 1, Hp, hd)
    k = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}k"]).reshape(B, 1, Kp, hd)
    v = jnp.einsum("bsd,dh->bsh", x, lp[f"w{prefix}v"]).reshape(B, 1, Kp, hd)
    if cfg.qk_norm and not prefix:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    S = kc.shape[1]
    mesh_ss = _use_seq_sharded_decode(cfg) if not prefix else None
    if mesh_ss is not None:
        o, kc, vc, new_scales = _seq_sharded_decode_attn(
            cfg, mesh_ss, q, k, v, kc, vc, pos, scales=scales)
        o = o.reshape(B, 1, Hp * hd)
        out = tp_down_proj(o, lp[f"w{prefix}o"])
        if scales is not None:
            return out, kc, vc, new_scales
        return out, kc, vc
    slot = _cache_slot(cfg, pos, S)                     # (B,)
    lanes = jnp.arange(B)
    new_scales = None
    if scales is not None:      # int8 cache path
        ks, vs = scales
        kq, k_sc = _quant_kv(k)
        vq, v_sc = _quant_kv(v)
        kc = kc.at[lanes, slot].set(kq[:, 0])
        vc = vc.at[lanes, slot].set(vq[:, 0])
        ks = ks.at[lanes, slot].set(k_sc[:, 0])
        vs = vs.at[lanes, slot].set(v_sc[:, 0])
        new_scales = (ks, vs)
        k_eff = _dequant_kv(kc, ks).astype(x.dtype)
        v_eff = _dequant_kv(vc, vs).astype(x.dtype)
    else:
        kc = kc.at[lanes, slot].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[lanes, slot].set(v[:, 0].astype(vc.dtype))
        k_eff, v_eff = kc, vc
    if cfg.sliding_window:
        # ring buffer: slot s holds abs position pos - ((pos - s) mod S);
        # valid if >= 0 — computed per lane
        s_idx = jnp.arange(S)
        abs_pos = pos[:, None] - jnp.mod(pos[:, None] - s_idx[None, :], S)
        o = _ring_attention(q, k_eff, v_eff, abs_pos >= 0)
    else:
        o = decode_attention(q, k_eff, v_eff, pos)
    o = o.reshape(B, 1, Hp * hd)
    out = tp_down_proj(o, lp[f"w{prefix}o"])
    if scales is not None:
        return out, kc, vc, new_scales
    return out, kc, vc


def _ring_attention(q, kc, vc, valid):
    """valid (B, S) per-lane mask over the ring-buffer cache."""
    B, _, H, hd = q.shape
    _, S, K, _ = kc.shape
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / jnp.sqrt(float(hd))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, vc.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def decode_step(params, cfg: ModelConfig, cache, prev_tokens):
    """One autoregressive step: (cache, prev (B,)) -> (logits (B,Vp), cache)."""
    pos = cache["pos"]
    x = embed_tokens(cfg, params, prev_tokens[:, None])

    int8 = cfg.kv_cache_dtype == "int8"

    def body(carry, xs):
        h = carry
        if int8:
            lp, kc, vc, ks, vs = xs
            a, kc, vc, (ks, vs) = _decode_attn_one(
                cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps), kc, vc, pos,
                scales=(ks, vs))
        else:
            lp, kc, vc = xs
            a, kc, vc = _decode_attn_one(
                cfg, lp, rms_norm(h, lp["ln1"], cfg.norm_eps), kc, vc, pos)
        h = h + a
        h = h + swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                       lp["wi_gate"], lp["wi_up"], lp["wo_mlp"])
        return h, (kc, vc, ks, vs) if int8 else (kc, vc)

    if int8:
        x, (k_new, v_new, ks_new, vs_new) = scan_xs(
            cfg, body, x, (params["layers"], cache["k"], cache["v"],
                           cache["k_scale"], cache["v_scale"]))
    else:
        x, (k_new, v_new) = scan_xs(
            cfg, body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    if int8:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache
