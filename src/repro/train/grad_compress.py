"""Int8 gradient compression with error feedback.

At 1000+ nodes the cross-pod (DCN) gradient all-reduce is the slow link;
8-bit quantization cuts it 4× vs fp32 (2× vs bf16). Error feedback keeps
the *accumulated* quantization error bounded, preserving convergence
(1-bit Adam / PowerSGD lineage).

On a real multi-pod deployment the quantize/dequantize pair brackets the
cross-pod reduce-scatter (quantize -> int8 a2a/reduce -> dequantize); under
single-program pjit the reduce is implicit, so the training loop applies
the identical numerical transform at the same point in the dataflow —
convergence behaviour (what we can measure here) is identical, link-bytes
accounting for the roofline uses the int8 width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err_state):
    """Apply int8 round-trip with error feedback per leaf.
    Returns (effective_grads, new_err_state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq, g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))
