"""Distributed training step builder.

make_train_step(cfg, mesh, ...) returns a jitted (params, opt_state, batch)
-> (params, opt_state, metrics) function with:
  * gradient accumulation over microbatches (lax.scan) — activation memory
    O(microbatch), FSDP all-gathers of layer i+1 overlap layer i's compute
    inside the layer scan (XLA latency-hiding on TPU);
  * per-layer remat (jax.checkpoint around the scanned block);
  * AdamW with sharded (ZeRO) states, global-norm clip, lr schedule;
  * optional int8 gradient compression with error feedback (DCN reduce).

The same builder is used by the smoke tests (1-device mesh), the measured
CPU runs, and the 512-device dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import api as model_api
from repro.sharding.specs import batch_pspecs, param_pspecs
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, mesh, *,
                    opt: Optional[AdamWConfig] = None,
                    num_microbatches: int = 1,
                    attn_impl: str = "masked",
                    global_batch: Optional[int] = None,
                    donate: bool = True,
                    loss_block: int = 0):
    opt = opt or AdamWConfig()
    pspecs = param_pspecs(cfg, mesh)
    fam_kw = {}
    if cfg.family == "moe" and mesh is not None and \
            "model" in mesh.axis_names and mesh.shape["model"] > 1:
        fam_kw["mesh"] = mesh

    def loss_on(params, mb):
        return model_api.loss_fn(params, cfg, mb, attn_impl=attn_impl,
                                 loss_block=loss_block, **fam_kw)

    def _train_step_body(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_on)(params, batch)
        else:
            def mb_slice(i):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape((num_microbatches,
                                         x.shape[0] // num_microbatches)
                                        + x.shape[1:])[i], batch)

            def accum(carry, i):
                g_acc, l_acc = carry
                loss, g = jax.value_and_grad(loss_on)(params, mb_slice(i))
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32)),
                jnp.arange(num_microbatches))
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_opt["step"]}
        return new_params, new_opt, metrics

    def train_step(params, opt_state, batch):
        from repro.models.layers import mesh_context
        with mesh_context(mesh):
            return _train_step_body(params, opt_state, batch)

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())

    gb = global_batch or 1
    bspecs = batch_pspecs(cfg, mesh, global_batch=gb)
    opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
    if opt.grad_compress:
        opt_specs["err"] = pspecs
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree_util.tree_map(sh, pspecs),
             jax.tree_util.tree_map(sh, opt_specs),
             {k: sh(v) for k, v in bspecs.items()})
    out_sh = (jax.tree_util.tree_map(sh, pspecs),
              jax.tree_util.tree_map(sh, opt_specs),
              {"loss": sh(P()), "grad_norm": sh(P()), "step": sh(P())})
    return jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1) if donate else ())


def init_train_state(cfg: ModelConfig, key, opt: Optional[AdamWConfig] = None):
    """Single-host init (smoke tests / measured runs)."""
    params = model_api.init_params(cfg, key)
    opt_state = init_opt_state(params, opt or AdamWConfig())
    return params, opt_state
