"""Fault-tolerant checkpointing.

* msgpack-serialized pytrees (params + optimizer + pipeline state + RNG),
  arrays stored with full LOGICAL shape — restore reshards onto ANY mesh
  (elastic scaling).
* atomic write: serialize to <dir>/tmp-<step>, fsync, rename to
  <dir>/step-<step>; a 'latest' pointer file is written last.
* integrity: a manifest with per-array SHA1 is verified on load; corrupt
  or partial checkpoints are skipped by `restore_latest` (it walks back).
* retention: keep the newest `keep` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _tree_paths(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in paths]


def save_checkpoint(ckpt_dir, step: int, tree: dict, *, keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp-{step}"
    final = ckpt_dir / f"step-{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, treedef = _flatten(tree)
    names = _tree_paths(tree)
    manifest = {"step": step, "arrays": []}
    payload = {}
    for name, leaf in zip(names, flat):
        arr = np.asarray(leaf)
        key = name.replace("/", ".")
        payload[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
        manifest["arrays"].append(
            {"name": key, "dtype": str(arr.dtype), "shape": list(arr.shape),
             "sha1": hashlib.sha1(arr.tobytes()).hexdigest()})
    with open(tmp / "arrays.msgpack", "wb") as f:
        f.write(msgpack.packb(payload))
        f.flush()
        os.fsync(f.fileno())
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "treedef.txt").write_text(str(treedef))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic on POSIX
    (ckpt_dir / "latest.tmp").write_text(final.name)
    os.replace(ckpt_dir / "latest.tmp", ckpt_dir / "latest")
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step-*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def _verify(path: pathlib.Path) -> bool:
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        with open(path / "arrays.msgpack", "rb") as f:
            payload = msgpack.unpackb(f.read())
        for ent in manifest["arrays"]:
            raw = payload[ent["name"]]["data"]
            if hashlib.sha1(raw).hexdigest() != ent["sha1"]:
                return False
        return True
    except Exception:  # noqa: BLE001 — any corruption => invalid
        return False


def load_checkpoint(path, like: dict, *, shardings=None) -> dict:
    """Restore into the structure of `like` (shapes must match logically);
    `shardings` (optional pytree of NamedSharding) reshards onto the
    current mesh — elastic restore."""
    path = pathlib.Path(path)
    with open(path / "arrays.msgpack", "rb") as f:
        payload = msgpack.unpackb(f.read())
    flat, treedef = _flatten(like)
    names = _tree_paths(like)
    out = []
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    for name, leaf, sh in zip(names, flat, sh_flat):
        ent = payload[name.replace("/", ".")]
        arr = np.frombuffer(ent["data"], dtype=np.dtype(ent["dtype"]))
        arr = arr.reshape(ent["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(ckpt_dir, like: dict, *, shardings=None):
    """Walk checkpoints newest-first, skipping invalid/corrupt ones.
    Returns (tree, step) or (None, -1)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None, -1
    candidates = sorted((p for p in ckpt_dir.glob("step-*") if p.is_dir()),
                        reverse=True)
    latest = ckpt_dir / "latest"
    if latest.exists():
        pointed = ckpt_dir / latest.read_text().strip()
        if pointed in candidates:
            candidates.remove(pointed)
            candidates.insert(0, pointed)
    for cand in candidates:
        if _verify(cand):
            step = json.loads((cand / "manifest.json").read_text())["step"]
            return load_checkpoint(cand, like, shardings=shardings), step
    return None, -1
