"""AdamW with global-norm clipping, sharded states (ZeRO-style: m/v take the
same sharding as the parameter, so FSDP params => FSDP optimizer states),
plus optional int8 gradient compression with error feedback
(train/grad_compress.py)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_compress: bool = False  # int8 + error feedback on the DP reduce


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * jnp.minimum(warm, cos)


def init_opt_state(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compress:
        state["err"] = jax.tree_util.tree_map(zeros32, params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    if cfg.grad_compress:
        from .grad_compress import compress_decompress
        grads, new_err = compress_decompress(grads, state["err"])
    else:
        new_err = None

    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    if new_err is not None:
        new_state["err"] = new_err
    return new_params, new_state, gnorm
