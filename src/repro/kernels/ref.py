"""Pure-jnp oracles for every Pallas kernel. Deliberately naive and
readable — the kernel tests assert_allclose against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q (B,H,Sq,hd), k/v (B,K,Sk,hd) -> (B,H,Sq,hd). GQA by head grouping."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale or hd ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q (B,H,hd); caches (B,K,S,hd); lengths (B,) valid prefix lengths.
    -> (B,H,hd)."""
    B, H, hd = q.shape
    K, S = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    kk = jnp.repeat(k_cache, G, axis=1)
    vv = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_ref(x, dt, A, Bm, Cm):
    """Intra-chunk SSD (one chunk, zero entering state) + chunk state.

    x (B,Q,H,P), dt (B,Q,H), A (H,), Bm/Cm (B,Q,N)
    -> y (B,Q,H,P), state_out (B,H,P,N)
    """
    a = dt * A                                   # (B,Q,H) log decays
    cum = jnp.cumsum(a, axis=1)
    seg = cum[:, :, None, :] - cum[:, None, :, :]
    Q = x.shape[1]
    ii, jj = jnp.meshgrid(jnp.arange(Q), jnp.arange(Q), indexing="ij")
    L = jnp.exp(jnp.where((ii >= jj)[None, :, :, None], seg, -jnp.inf))
    G = jnp.einsum("bin,bjn->bij", Cm, Bm)
    W = G[..., None] * L
    y = jnp.einsum("bijh,bjh,bjhp->bihp", W, dt, x)
    end = jnp.exp(cum[:, -1:, :] - cum)
    state = jnp.einsum("bjh,bjh,bjhp,bjn->bhpn", end, dt, x, Bm)
    return y, state


def cdf_quantize_ref(probs_unnorm, precision: int):
    """Unnormalized probs (B, V) -> integer CDF interior points (B, V) by
    cumulative rounding (matches core.cdf.quantize_cdf_points)."""
    V = probs_unnorm.shape[-1]
    budget = jnp.float32((1 << precision) - V)
    cum = jnp.cumsum(probs_unnorm.astype(jnp.float32), axis=-1)
    cum = cum / cum[..., -1:]
    pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32)
    return pts + (1 + jnp.arange(V, dtype=jnp.int32))


def cdf_quantize_blocked_ref(logits, precision: int, block_v: int):
    """Blocked-accumulation oracle for ac_cdf._cdf_kernel: same running
    (max, scaled-sum) softmax, same per-block float prefix carry, same
    exactness clamps — term for term, so the kernel must match it
    BIT-identically (flat vs blocked float cumsum differ by ulps, which
    is why cdf_quantize_ref can only be compared to +-1)."""
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    assert V % block_v == 0
    nv = V // block_v
    budget = jnp.float32((1 << precision) - V)
    m = jnp.full((B, 1), NEG_INF, jnp.float32)
    s = jnp.zeros((B, 1), jnp.float32)
    for j in range(nv):
        x = logits[:, j * block_v:(j + 1) * block_v]
        m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
        s = s * jnp.exp(m - m_new) + \
            jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
        m = m_new
    c = jnp.zeros((B, 1), jnp.float32)
    prev = jnp.zeros((B, 1), jnp.int32)
    out = []
    for j in range(nv):
        x = logits[:, j * block_v:(j + 1) * block_v]
        cum = c + jnp.cumsum(jnp.exp(x - m) / s, axis=-1)
        c = cum[:, -1:]
        local = jnp.arange(block_v, dtype=jnp.int32)[None, :]
        idx = j * block_v + local
        pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) + idx + 1
        pts = jnp.minimum(pts, budget.astype(jnp.int32) + idx + 1)
        pts = jnp.maximum(pts, prev + 1 + local)
        pts = jnp.where((j == nv - 1) & (local == block_v - 1),
                        budget.astype(jnp.int32) + jnp.int32(V), pts)
        prev = pts[:, -1:]
        out.append(pts)
    return jnp.concatenate(out, axis=-1)


def topk_cdf_ref(logits, k: int, precision: int):
    """Flat-host oracle for ac_cdf._topk_cdf_kernel (single vocab block):
    lax.top_k + full-vocab softmax + escape + cumulative-rounding CDF —
    the same arithmetic as core.cdf.topk_cdf, restated here so the
    kernel tests stay self-contained."""
    logits = logits.astype(jnp.float32)
    top_vals, ids = jax.lax.top_k(logits, k)
    m = jnp.max(logits, axis=-1, keepdims=True)
    denom = jnp.sum(jnp.exp(logits - m), axis=-1, keepdims=True)
    top_p = jnp.exp(top_vals - m) / denom
    esc = jnp.clip(1.0 - jnp.sum(top_p, axis=-1, keepdims=True), 0.0, 1.0)
    pmf = jnp.concatenate([top_p, esc], axis=-1)
    pmf = pmf / jnp.sum(pmf, axis=-1, keepdims=True)
    budget = jnp.float32((1 << precision) - (k + 1))
    cum = jnp.cumsum(pmf, axis=-1)
    cum = cum / cum[..., -1:]
    pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) \
        + (1 + jnp.arange(k + 1, dtype=jnp.int32))
    zero = jnp.zeros_like(pts[..., :1])
    return ids.astype(jnp.int32), jnp.concatenate([zero, pts], axis=-1)


def topk_cdf_blocked_ref(logits, k: int, precision: int, block_v: int):
    """Blocked oracle for ac_cdf._topk_cdf_kernel with nv > 1: replays
    the kernel's running (max, sum) accumulation and its scratch-first
    k-round extract-max top-k merge, so the multi-block kernel must
    match it bit-identically."""
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    assert V % block_v == 0
    nv = V // block_v
    m = jnp.full((B, 1), NEG_INF, jnp.float32)
    s = jnp.zeros((B, 1), jnp.float32)
    vals = jnp.full((B, k), NEG_INF, jnp.float32)
    tids = jnp.zeros((B, k), jnp.int32)
    for j in range(nv):
        x = logits[:, j * block_v:(j + 1) * block_v]
        m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
        s = s * jnp.exp(m - m_new) + \
            jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
        m = m_new
        work = jnp.concatenate([vals, x], axis=-1)
        gid = j * block_v + jnp.arange(block_v, dtype=jnp.int32)[None, :]
        wid = jnp.concatenate([tids, jnp.broadcast_to(gid, x.shape).astype(
            jnp.int32)], axis=-1)
        iota = jnp.broadcast_to(jnp.arange(work.shape[-1], dtype=jnp.int32),
                                work.shape)
        n = jnp.int32(work.shape[-1])
        new_v, new_i = [], []
        for _ in range(k):
            mx = jnp.max(work, axis=-1, keepdims=True)
            pos = jnp.min(jnp.where(work == mx, iota, n), axis=-1,
                          keepdims=True)
            sel = iota == pos
            new_v.append(mx)
            new_i.append(jnp.sum(jnp.where(sel, wid, 0), axis=-1,
                                 keepdims=True))
            work = jnp.where(sel, NEG_INF, work)
        vals = jnp.concatenate(new_v, axis=-1)
        tids = jnp.concatenate(new_i, axis=-1)
    top_p = jnp.exp(vals - m) / s
    esc = jnp.clip(1.0 - jnp.sum(top_p, axis=-1, keepdims=True), 0.0, 1.0)
    pmf = jnp.concatenate([top_p, esc], axis=-1)
    pmf = pmf / jnp.sum(pmf, axis=-1, keepdims=True)
    budget = jnp.float32((1 << precision) - (k + 1))
    cum = jnp.cumsum(pmf, axis=-1)
    cum = cum / cum[:, -1:]
    pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) \
        + (1 + jnp.arange(k + 1, dtype=jnp.int32))
    zero = jnp.zeros_like(pts[:, :1])
    return tids, jnp.concatenate([zero, pts], axis=-1)
