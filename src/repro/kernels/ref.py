"""Pure-jnp oracles for every Pallas kernel. Deliberately naive and
readable — the kernel tests assert_allclose against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q (B,H,Sq,hd), k/v (B,K,Sk,hd) -> (B,H,Sq,hd). GQA by head grouping."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale or hd ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q (B,H,hd); caches (B,K,S,hd); lengths (B,) valid prefix lengths.
    -> (B,H,hd)."""
    B, H, hd = q.shape
    K, S = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    kk = jnp.repeat(k_cache, G, axis=1)
    vv = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_ref(x, dt, A, Bm, Cm):
    """Intra-chunk SSD (one chunk, zero entering state) + chunk state.

    x (B,Q,H,P), dt (B,Q,H), A (H,), Bm/Cm (B,Q,N)
    -> y (B,Q,H,P), state_out (B,H,P,N)
    """
    a = dt * A                                   # (B,Q,H) log decays
    cum = jnp.cumsum(a, axis=1)
    seg = cum[:, :, None, :] - cum[:, None, :, :]
    Q = x.shape[1]
    ii, jj = jnp.meshgrid(jnp.arange(Q), jnp.arange(Q), indexing="ij")
    L = jnp.exp(jnp.where((ii >= jj)[None, :, :, None], seg, -jnp.inf))
    G = jnp.einsum("bin,bjn->bij", Cm, Bm)
    W = G[..., None] * L
    y = jnp.einsum("bijh,bjh,bjhp->bihp", W, dt, x)
    end = jnp.exp(cum[:, -1:, :] - cum)
    state = jnp.einsum("bjh,bjh,bjhp,bjn->bhpn", end, dt, x, Bm)
    return y, state


def cdf_quantize_ref(probs_unnorm, precision: int):
    """Unnormalized probs (B, V) -> integer CDF interior points (B, V) by
    cumulative rounding (matches core.cdf.quantize_cdf_points)."""
    V = probs_unnorm.shape[-1]
    budget = jnp.float32((1 << precision) - V)
    cum = jnp.cumsum(probs_unnorm.astype(jnp.float32), axis=-1)
    cum = cum / cum[..., -1:]
    pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32)
    return pts + (1 + jnp.arange(V, dtype=jnp.int32))
