"""Jit'd public wrappers over the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; on CPU
(this container) `interpret=True` executes the kernel bodies in Python for
correctness, and the pure-jnp refs remain the default for anything
performance-sensitive (tests select explicitly). The model zoo's XLA paths
(models/layers.py) implement the same algorithms, so the dry-run HLO is
structurally faithful to what the kernels do on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref as _ref
from .ac_cdf import cdf_points as _cdf_points
from .ac_cdf import topk_cdf_points as _topk_cdf_points
from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention as _flash_attention
from .ssd_scan import ssd_intra as _ssd_intra


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, *, causal=True, window=None, impl="auto"):
    """q (B,H,Sq,hd), k/v (B,K,Sk,hd). impl: auto|pallas|interpret|ref."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    interp = impl == "interpret" or not _on_tpu()
    return _flash_attention(q, k, v, causal=causal, window=window,
                            interpret=interp)


@partial(jax.jit, static_argnames=("impl",))
def decode_attention(q, k_cache, v_cache, lengths, *, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    interp = impl == "interpret" or not _on_tpu()
    return _decode_attention(q, k_cache, v_cache, lengths, interpret=interp)


@partial(jax.jit, static_argnames=("impl",))
def ssd_intra(x, dt, A, Bm, Cm, *, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.ssd_intra_ref(x, dt, A, Bm, Cm)
    interp = impl == "interpret" or not _on_tpu()
    return _ssd_intra(x, dt, A, Bm, Cm, interpret=interp)


@partial(jax.jit, static_argnames=("precision", "impl"))
def cdf_points(logits, precision: int = 16, *, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
        return _ref.cdf_quantize_ref(p, precision)
    interp = impl == "interpret" or not _on_tpu()
    return _cdf_points(logits, precision, interpret=interp)


@partial(jax.jit, static_argnames=("k", "precision", "impl"))
def topk_cdf(logits, k: int, precision: int = 16, *, impl="auto"):
    """Fused top-k + escape quantized CDF: (ids (B,k), cdf (B,k+2))."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.topk_cdf_ref(logits, k, precision)
    interp = impl == "interpret" or not _on_tpu()
    return _topk_cdf_points(logits, k, precision, interpret=interp)
