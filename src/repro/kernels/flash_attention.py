"""Flash attention (causal / sliding-window / GQA) as a Pallas TPU kernel.

Layout: q (B,H,Sq,hd), k/v (B,K,Sk,hd). Grid (B, H, nq, nk) with the kv
axis innermost/sequential; running max / denominator / accumulator live in
VMEM scratch across the kv iterations (standard online softmax).

VMEM budget per step (v5e ~16 MiB/core): q,k,v blocks (block_q + 2*block_k)
× hd × 2B plus fp32 scratch block_q×(hd+2)×4B — defaults (block_q=block_k=
256, hd=128) use ≈ 0.5 MiB, leaving room for the MXU pipeline's
double-buffering. Block sizes are multiples of 128 to align the MXU.

Causal/SWA blocks that are fully masked are skipped with pl.when — on TPU
the grid still visits them but the MXU work is predicated away; the FLOP
saving shows up in the §Perf iteration ("block_causal" XLA path is the
mesh-level equivalent).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, block_q, block_k, nk, causal, window):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    # fully-masked block? (causal: kv strictly after the last q row;
    # SWA: kv block entirely before the window of the first q row)
    skip = False
    if causal:
        skip = k_start > q_start + block_q - 1
    live = jnp.logical_not(skip)
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 >
                               q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=256, interpret=False):
    """q (B,H,Sq,hd), k/v (B,K,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        nk=nk, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
