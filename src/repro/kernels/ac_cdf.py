"""Fused quantized-CDF kernel — the paper-specific hot-spot.

Turning next-token logits into integer CDFs for the arithmetic coder is a
vocab-sized memory-bound chain (max -> exp -> cumsum -> normalize ->
round). Left to XLA these materialize V-sized fp32 intermediates per
token; this kernel streams vocab blocks through VMEM once, carrying
(running max, running scaled sum) in scratch, then a second sweep emits
the integer CDF points with a running prefix — two HBM passes total,
nothing materialized.

Quantization is **cumulative rounding** (see core/cdf.py): strictly
monotone, exact total, streaming. Grid (B, 2, nv): pass 0 reduces, pass 1
emits; the pass axis is sequential so scratch carries across.

Two kernels share the layout:

* ``cdf_points``      — full-vocabulary CDF interior points (B, V);
* ``topk_cdf_points`` — fused top-k selection -> (k+1)-symbol quantized
  CDF (+ escape), the device form of ``core.cdf.topk_cdf``: pass 0 also
  merges each block's candidates into a running top-k scratch, pass 1
  emits (ids, cdf) once — the decode loops stop paying a host-side
  ``top_k``/``pmf_to_cdf`` per step.

For padded vocabularies the caller masks pad logits to -inf upstream;
exp(-inf - max) = 0 contributes nothing and pad symbols get exactly one
quantum each (they are never coded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _cdf_kernel(logits_ref, out_ref, m_ref, s_ref, c_ref, p_ref, *,
                block_v, nv, budget):
    p = pl.program_id(1)       # pass: 0 = reduce, 1 = emit
    j = pl.program_id(2)       # vocab block

    @pl.when((p == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        p_ref[...] = jnp.zeros_like(p_ref)

    x = logits_ref[0].astype(jnp.float32)              # (1, block_v)

    @pl.when(p == 0)
    def _reduce():
        m_prev, s_prev = m_ref[...], s_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
        s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + \
            jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(p == 1)
    def _emit():
        m, s = m_ref[...], s_ref[...]
        probs = jnp.exp(x - m) / s                     # normalized block pmf
        cum = c_ref[...] + jnp.cumsum(probs, axis=-1)  # global prefix
        c_ref[...] = cum[:, -1:]
        local = jax.lax.broadcasted_iota(jnp.int32, cum.shape, 1)
        idx = j * block_v + local
        pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) + idx + 1
        # Exactness clamps. The float prefix can drift either way, and a
        # coder CDF must end at exactly 2**precision with strictly
        # increasing points — "off by one at the tail" corrupts streams:
        #   * upper: drift above 1.0 would overshoot the budget;
        #   * lower: drift DOWN across a block boundary would emit a point
        #     <= the previous block's last point (p_ref carries it), so
        #     force >= prev_last + 1 + local (strictly increasing, and
        #     never above the upper clamp: prev_last <= budget + j*block_v
        #     by the upper clamp of the previous block);
        #   * tail: the final point is forced to exactly budget + V —
        #     clamping down (the old code) never pulled a short tail UP.
        pts = jnp.minimum(pts, jnp.int32(budget) + idx + 1)
        pts = jnp.maximum(pts, p_ref[...] + 1 + local)
        pts = jnp.where((j == nv - 1) & (local == block_v - 1),
                        jnp.int32(budget) + jnp.int32(nv * block_v), pts)
        p_ref[...] = pts[:, -1:]
        out_ref[...] = pts


def cdf_points(logits, precision: int, *, block_v=2048, interpret=False):
    """logits (B, V) -> int32 CDF interior points (B, V) (cdf[1:];
    prepend 0 on the host for the coder)."""
    B, V = logits.shape
    block_v = min(block_v, V)
    assert V % block_v == 0
    nv = V // block_v
    budget = float((1 << precision) - V)

    kernel = functools.partial(_cdf_kernel, block_v=block_v, nv=nv,
                               budget=budget)
    return pl.pallas_call(
        kernel,
        grid=(B, 2, nv),
        in_specs=[pl.BlockSpec((1, block_v), lambda b, p, j: (b, j))],
        out_specs=pl.BlockSpec((1, block_v), lambda b, p, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running sum (scaled)
            pltpu.VMEM((1, 1), jnp.float32),   # running prefix of cum prob
            pltpu.VMEM((1, 1), jnp.int32),     # previous block's last point
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(logits)


def _topk_cdf_kernel(logits_ref, ids_ref, cdf_ref, m_ref, s_ref,
                     vals_ref, tids_ref, *, block_v, nv, k, budget):
    p = pl.program_id(1)       # pass: 0 = reduce + top-k merge, 1 = emit
    j = pl.program_id(2)       # vocab block

    @pl.when((p == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        tids_ref[...] = jnp.zeros_like(tids_ref)

    x = logits_ref[...].astype(jnp.float32)            # (1, block_v)

    @pl.when(p == 0)
    def _reduce():
        m_prev, s_prev = m_ref[...], s_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
        s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + \
            jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
        m_ref[...] = m_new
        # merge this block's candidates into the running top-k scratch by
        # k extract-max rounds over [scratch | block]. Scratch-first order
        # + first-index argmax reproduce lax.top_k's tie rule (smallest
        # vocab id wins): scratch entries carry smaller global ids than
        # this block, and were themselves appended in id order.
        work = jnp.concatenate([vals_ref[...], x], axis=-1)  # (1, k+block_v)
        gid = j * block_v + jax.lax.broadcasted_iota(
            jnp.int32, x.shape, 1)
        wid = jnp.concatenate([tids_ref[...], gid], axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, work.shape, 1)
        n = jnp.int32(work.shape[-1])
        new_v, new_i = [], []
        for _ in range(k):
            mx = jnp.max(work, axis=-1, keepdims=True)
            pos = jnp.min(jnp.where(work == mx, iota, n), axis=-1,
                          keepdims=True)
            sel = iota == pos
            new_v.append(mx)
            new_i.append(jnp.sum(jnp.where(sel, wid, 0), axis=-1,
                                 keepdims=True))
            work = jnp.where(sel, NEG_INF, work)
        vals_ref[...] = jnp.concatenate(new_v, axis=-1)
        tids_ref[...] = jnp.concatenate(new_i, axis=-1)

    @pl.when((p == 1) & (j == 0))
    def _emit():
        # mirrors core.cdf.topk_quantized + quantize_cdf_points on the
        # (k+1)-symbol alphabet, term for term — with one vocab block the
        # scratch (m, s, top-k) equals the host's flat reduction and the
        # emitted integers are bit-identical to the host path
        m, s = m_ref[...], s_ref[...]
        top_p = jnp.exp(vals_ref[...] - m) / s                   # (1, k)
        esc = jnp.clip(1.0 - jnp.sum(top_p, axis=-1, keepdims=True),
                       0.0, 1.0)
        pmf = jnp.concatenate([top_p, esc], axis=-1)             # (1, k+1)
        pmf = pmf / jnp.sum(pmf, axis=-1, keepdims=True)
        cum = jnp.cumsum(pmf, axis=-1)
        cum = cum / cum[:, -1:]
        idx = jax.lax.broadcasted_iota(jnp.int32, cum.shape, 1)
        pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) + idx + 1
        ids_ref[...] = tids_ref[...]
        cdf_ref[...] = jnp.concatenate(
            [jnp.zeros_like(pts[:, :1]), pts], axis=-1)          # (1, k+2)


def topk_cdf_points(logits, k: int, precision: int, *, block_v=2048,
                    interpret=False):
    """Fused top-k selection -> quantized (k+1)-symbol CDF: logits (B, V)
    -> (ids (B, k) int32, cdf (B, k+2) int32) with cdf[:, 0] == 0 and
    cdf[:, -1] == 2**precision — the device version of
    ``core.cdf.topk_cdf`` (one HBM pass over the logits; no V-sized
    intermediate, no host pmf cumsum per decode step).

    Caveat: ids match ``lax.top_k`` exactly when at least k logits exceed
    the NEG_INF sentinel; rows padded below that (all-(-inf) tails wider
    than V - k) may order their zero-probability slots differently.
    """
    B, V = logits.shape
    block_v = min(block_v, V)
    assert V % block_v == 0
    nv = V // block_v
    budget = float((1 << precision) - (k + 1))

    kernel = functools.partial(_topk_cdf_kernel, block_v=block_v, nv=nv,
                               k=k, budget=budget)
    return pl.pallas_call(
        kernel,
        grid=(B, 2, nv),
        in_specs=[pl.BlockSpec((1, block_v), lambda b, p, j: (b, j))],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, p, j: (b, 0)),
            pl.BlockSpec((1, k + 2), lambda b, p, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k + 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running sum (scaled)
            pltpu.VMEM((1, k), jnp.float32),   # running top-k values
            pltpu.VMEM((1, k), jnp.int32),     # running top-k vocab ids
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(logits)
