"""Fused quantized-CDF kernel — the paper-specific hot-spot.

Turning next-token logits into integer CDFs for the arithmetic coder is a
vocab-sized memory-bound chain (max -> exp -> cumsum -> normalize ->
round). Left to XLA these materialize V-sized fp32 intermediates per
token; this kernel streams vocab blocks through VMEM once, carrying
(running max, running scaled sum) in scratch, then a second sweep emits
the integer CDF points with a running prefix — two HBM passes total,
nothing materialized.

Quantization is **cumulative rounding** (see core/cdf.py): strictly
monotone, exact total, streaming. Grid (B, 2, nv): pass 0 reduces, pass 1
emits; the pass axis is sequential so scratch carries across.

For padded vocabularies the caller masks pad logits to -inf upstream;
exp(-inf - max) = 0 contributes nothing and pad symbols get exactly one
quantum each (they are never coded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _cdf_kernel(logits_ref, out_ref, m_ref, s_ref, c_ref, *,
                block_v, nv, budget):
    p = pl.program_id(1)       # pass: 0 = reduce, 1 = emit
    j = pl.program_id(2)       # vocab block

    @pl.when((p == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = logits_ref[0].astype(jnp.float32)              # (1, block_v)

    @pl.when(p == 0)
    def _reduce():
        m_prev, s_prev = m_ref[...], s_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
        s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + \
            jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(p == 1)
    def _emit():
        m, s = m_ref[...], s_ref[...]
        probs = jnp.exp(x - m) / s                     # normalized block pmf
        cum = c_ref[...] + jnp.cumsum(probs, axis=-1)  # global prefix
        c_ref[...] = cum[:, -1:]
        idx = j * block_v + jax.lax.broadcasted_iota(
            jnp.int32, cum.shape, 1)
        pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32) + idx + 1
        # clamp the tail to the exact total (float cumsum may drift a ulp)
        pts = jnp.minimum(pts, jnp.int32(budget) + idx + 1)
        out_ref[...] = pts


def cdf_points(logits, precision: int, *, block_v=2048, interpret=False):
    """logits (B, V) -> int32 CDF interior points (B, V) (cdf[1:];
    prepend 0 on the host for the coder)."""
    B, V = logits.shape
    block_v = min(block_v, V)
    assert V % block_v == 0
    nv = V // block_v
    budget = float((1 << precision) - V)

    kernel = functools.partial(_cdf_kernel, block_v=block_v, nv=nv,
                               budget=budget)
    return pl.pallas_call(
        kernel,
        grid=(B, 2, nv),
        in_specs=[pl.BlockSpec((1, block_v), lambda b, p, j: (b, j))],
        out_specs=pl.BlockSpec((1, block_v), lambda b, p, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running sum (scaled)
            pltpu.VMEM((1, 1), jnp.float32),   # running prefix of cum prob
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(logits)
