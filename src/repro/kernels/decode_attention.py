"""Flash-decode: single-token attention over a long KV cache.

The decompression inner loop is decode-bound: one new token attends a KV
cache of up to 512k positions. The kernel streams KV blocks HBM->VMEM with
an online-softmax accumulator — purely memory-bound, so block size is
chosen to saturate HBM bandwidth (block_k=512 × hd=128 × 2B = 128 KiB per
stream; double-buffered by the pipeline).

Layout: q (B,H,hd), caches (B,K,S,hd), lengths (B,) valid prefix lengths
(ragged batch — streams decode in lock-step but may have unequal lengths).
Grid (B, H, nk), kv axis sequential with VMEM scratch carry.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale, block_k, nk):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    live = j * block_k < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1)[:, None])
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k=512,
                     interpret=False):
    """q (B,H,hd), caches (B,K,S,hd), lengths (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    K, S = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)
    q4 = q[:, :, None, :]                              # (B,H,1,hd)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # lengths
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q4, k_cache, v_cache)
    return out[:, :, 0, :]
