"""Mamba2 SSD intra-chunk kernel.

The SSD forward splits into (a) a quadratic *intra-chunk* part — the
compute hot-spot, O(Q^2) per chunk like attention — and (b) a cheap
inter-chunk state recurrence (done outside in lax.scan). This kernel
computes (a) plus each chunk's boundary-state contribution in one pass.

Grid (B, nc, H): one (batch, chunk, head) cell per step; everything for a
cell fits VMEM comfortably (Q=256, P=64, N=128 => ~0.4 MiB fp32).
The Q×Q decay matrix is built in-register from the cumulative log-decay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)  (head-major layout)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    A = a_ref[pl.program_id(2)]                # this head's decay rate (SMEM)
    Bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    a = dt * A                                 # (Q,1) log decay
    cum = jnp.cumsum(a, axis=0)                # (Q,1)
    seg = cum - cum.T                          # (Q,Q) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(ii >= jj, seg, -jnp.inf))
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,Q)
    W = G * L * dt.T                           # fold dt_j into the weights
    y_ref[0, 0] = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)
    # chunk boundary state: sum_j exp(cum_Q - cum_j) dt_j x_j (X) B_j -> (P,N)
    end = jnp.exp(cum[-1:] - cum) * dt         # (Q,1)
    s_ref[0, 0] = jax.lax.dot_general(
        x, Bm * end, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)


def ssd_intra(x, dt, A, Bm, Cm, *, interpret=False):
    """Intra-chunk SSD. x (B,Q,H,P), dt (B,Q,H), A (H,), Bm/Cm (B,Q,N)
    -> y (B,Q,H,P) fp32, state (B,H,P,N) fp32 (zero entering state)."""
    B, Q, H, P = x.shape
    N = Bm.shape[-1]
    # head-major layouts for clean BlockSpecs
    xh = jnp.moveaxis(x, 2, 1)                 # (B,H,Q,P)
    dth = jnp.moveaxis(dt, 2, 1)[..., None]    # (B,H,Q,1)

    out = pl.pallas_call(
        _ssd_kernel,
        grid=(B, 1, H),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # A (H,)
            pl.BlockSpec((1, 1, Q, P), lambda b, c, h: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, h: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, h: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c, h: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, c, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(A.astype(jnp.float32), xh, dth, Bm, Cm)
    y, state = out
    return jnp.moveaxis(y, 1, 2), state
