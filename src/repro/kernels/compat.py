"""JAX version compatibility for the Pallas TPU kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(jax >= 0.5); this container pins 0.4.x. Resolve the name once here so
every kernel works under either spelling.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
