"""Pallas TPU kernels for the perf-critical compute layers:
flash attention (prefill), decode attention (KV streaming), SSD intra-chunk
(mamba2), fused quantized-CDF (arithmetic-coder feed)."""
from .ops import cdf_points, decode_attention, flash_attention, ssd_intra
