"""Slot-based continuous-batching scheduler (DESIGN.md §8).

One fixed-shape jitted decode program serves mixed compress/decompress
traffic: the B slots each hold one chunk-stream; every ``step()`` runs
exactly one model ``decode_step`` over all B lanes plus one vectorized
rANS coder step over the active lanes. The grouped decoder
(``LLMCompressor._decode_group``) runs every step to ``valid.max()`` of
its group, so one long chunk holds the other slots idle; here a finished
slot is refilled from the priority queue on the next step, and the model
program never recompiles (B is constant, the masks are runtime inputs).

Both directions share each step's CDF tables, computed once per step
from the same logits:

* decompress slots pull their next token from the rANS decoder
  (per-slot streams attached/detached on refill);
* compress slots run teacher-forced "exact" scoring (DESIGN.md §6):
  the ground-truth token is fed back, its (start, freq) interval
  recorded in the per-slot LIFO encoder, and the slot's stream is
  flushed the moment the chunk completes (out-of-order completion —
  the v4 index footer puts the chunks back in order).

Bit-exactness across batch compositions: each lane's logits are a
function of that lane's cache and input only (attention/SSM/MoE-dropless
are lane-independent by construction — the same property the lock-step
decoder already relies on), and per-slot cache positions make a refilled
lane's computation identical to a fresh-cache decode. So a container
compressed by the service decodes through ``LLMCompressor`` and vice
versa, regardless of what traffic shared the batch.

Telemetry (DESIGN.md §10): the scheduler owns a ``MetricsRegistry``
(private by default, injectable). Its load-bearing counters
(``scheduler.model_steps`` …) are ALWAYS maintained — ``SchedulerStats``
is now a thin attribute view over them — while everything optional
(per-slot code-length accrual for chunk diagnostics, the
``chunk.bits_per_token`` histogram, step spans, periodic progress lines)
is gated on ``registry.enabled``, and none of it can change output
bytes: every telemetry read happens *after* the coder ops it describes.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro import obs
from repro.core import rans
from repro.core.cdf import DEFAULT_PRECISION, full_cdf_jit, topk_cdf_jit
from repro.core.compressor import ContainerError
from repro.obs import ChunkDiagnostics, MetricsRegistry
from .session import COMPRESS, ChunkTask

_HELP = {
    "model_steps": "fixed-shape decode_step invocations",
    "lane_steps": "model_steps x B (capacity offered)",
    "token_steps": "active-lane tokens actually coded",
    "chunks_completed": "chunk tasks finished (either direction)",
    "refills": "slot assignments from the queue",
    "chunk_failures": "chunk tasks that completed with an error",
    "escapes": "escape symbols coded (top-k mode, both directions)",
    "prefill_steps": "lane-steps spent consuming context prefixes (v6)",
}


class _CounterField:
    """Read/write attribute backed by a ``scheduler.<name>`` counter, so
    ``stats.model_steps += 1`` and ``registry.value(...)`` are one value."""

    __slots__ = ("metric",)

    def __init__(self, name: str):
        self.metric = "scheduler." + name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return obj.registry.counter(self.metric).value

    def __set__(self, obj, v) -> None:
        obj.registry.counter(self.metric).value = v


class SchedulerStats:
    """Compatibility view over the scheduler's registry counters.

    Pre-PR-7 code (tests, service_bench) reads and writes
    ``stats.model_steps`` etc. as plain attributes; those now pass
    through to ``scheduler.*`` counters in a ``MetricsRegistry``.
    Constructed standalone it carries its own private registry, so
    ``SchedulerStats()`` in one test cannot see another test's traffic.
    Calling the instance returns the structured snapshot.
    """

    model_steps = _CounterField("model_steps")
    lane_steps = _CounterField("lane_steps")
    token_steps = _CounterField("token_steps")
    chunks_completed = _CounterField("chunks_completed")
    refills = _CounterField("refills")
    chunk_failures = _CounterField("chunk_failures")
    escapes = _CounterField("escapes")
    prefill_steps = _CounterField("prefill_steps")

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry(name="scheduler")
        for f in _HELP:
            self.registry.counter("scheduler." + f, _HELP[f])

    @property
    def steps(self) -> int:
        """Alias for ``model_steps`` (ISSUE-era name)."""
        return self.model_steps

    @property
    def occupancy(self) -> float:
        """Fraction of offered lane-steps that coded a real token.
        0.0 when ``run()`` completed without executing a step (e.g. every
        job rejected at submit) — never a ZeroDivisionError."""
        lane = self.lane_steps
        if lane == 0:
            return 0.0
        return self.token_steps / lane

    def snapshot(self) -> dict:
        out = {f: getattr(self, f) for f in _HELP}
        out["occupancy"] = self.occupancy
        return out

    def __call__(self) -> dict:
        return self.snapshot()

    def __repr__(self) -> str:  # close to the old dataclass repr
        body = ", ".join(f"{f}={getattr(self, f)}" for f in _HELP)
        return f"SchedulerStats({body})"


class SlotScheduler:
    """Continuous-batching executor over ``n_slots`` model lanes.

    The scheduler is codec-fixed to rANS (codec id 1): the interleaved
    coder is what makes one vectorized coder step per position possible.
    Legacy AC containers take the grouped path in the service API.
    """

    #: emit a ``scheduler.progress`` log line every N model steps
    #: (0 disables; only when the registry is enabled)
    log_every = 4096

    #: time one full ``service.step`` span every N model steps (sampled:
    #: a per-step span costs more than the whole telemetry budget on a
    #: model-free predictor; the histogram notes the sampling rate)
    span_every = 16

    def __init__(self, predictor, *, n_slots: int, chunk_size: int,
                 topk: int = 0, precision: int = DEFAULT_PRECISION,
                 registry: MetricsRegistry | None = None,
                 prefix_cache=None, router=None):
        if not 0 < precision <= rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} outside rANS range "
                             f"(1..{rans.MAX_PRECISION})")
        # The seq-sharded TP decode path collapses per-lane cache positions
        # with jnp.max — lock-step only; running it under slot refill would
        # corrupt streams silently. Refuse up front (same predicate the
        # model's decode dispatch uses, so the two cannot drift); such
        # predictors must use the grouped decoder.
        cfg = getattr(predictor, "cfg", None)
        if cfg is not None:
            from repro.models.transformer import decode_requires_lockstep
            if decode_requires_lockstep(cfg, getattr(predictor, "mesh",
                                                     None)):
                raise ValueError(
                    "continuous batching needs per-lane cache positions; "
                    "the seq-sharded TP decode path (padded_kv_heads not "
                    "divisible by TP) is lock-step only — use a replicated-"
                    "cache predictor or LLMCompressor's grouped decoder")
        self.predictor = predictor
        self.B = int(n_slots)
        self.C = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self._esc_bits = rans.uniform_bits(predictor.vocab_size)

        B, C = self.B, self.C
        self._queue: list = []          # heap of (priority, seq, task)
        self._seq = 0
        self._tasks: list[ChunkTask | None] = [None] * B
        self._active = np.zeros(B, bool)
        self._is_dec = np.zeros(B, bool)
        self._t = np.zeros(B, np.int64)         # next position per slot
        self._valid = np.zeros(B, np.int64)
        self._prev = np.zeros(B, np.int32)
        self._tok_buf = np.zeros((B, C), np.int32)   # per-slot chunk tokens
        self._dec = rans.BatchedRansDecoder([b""] * B)
        self._enc = rans.SlotRansEncoder(B)
        self._state = None              # model decode state, created lazily
        self._used = np.zeros(B, bool)  # lanes that have held a chunk
        # v6 context prefill: a slot whose _cpos < _ctxlen is consuming its
        # context prefix — it takes a model step but is excluded from both
        # coder masks; _ctx holds the per-slot context tokens and _cachekey
        # the prefix to snapshot into the radix cache once prefill ends
        self.prefix_cache = prefix_cache
        self.router = router            # probe-vs-realized calibration sink
        self._ctx: list = [None] * B
        self._ctxlen = np.zeros(B, np.int64)
        self._cpos = np.zeros(B, np.int64)
        self._cachekey: list = [None] * B
        # decode-length geometry the model state was built for: every
        # lane runs at chunk_size + _ctx_budget positions. Cache length
        # is coding geometry (it changes the jitted program's logits
        # bitwise), so this must equal each job's recorded ctx_budget
        # exactly — not merely bound it
        self._ctx_budget = 0
        self.registry = registry if registry is not None \
            else MetricsRegistry(name="scheduler")
        self.stats = SchedulerStats(self.registry)
        # hot-path counters, resolved once (property/setter would re-hash
        # the metric name every model step)
        self._c_steps = self.registry.counter("scheduler.model_steps")
        self._c_lanes = self.registry.counter("scheduler.lane_steps")
        self._c_tokens = self.registry.counter("scheduler.token_steps")
        self._c_chunks = self.registry.counter("scheduler.chunks_completed")
        self._c_refills = self.registry.counter("scheduler.refills")
        self._c_failures = self.registry.counter("scheduler.chunk_failures")
        self._c_escapes = self.registry.counter("scheduler.escapes")
        self._c_prefill = self.registry.counter("scheduler.prefill_steps")
        self._h_bpt = self.registry.histogram(
            "chunk.bits_per_token", "realized payload bits/token per chunk")
        self._h_step = self.registry.histogram(
            "span.service.step.seconds",
            f"wall seconds per scheduler step (1-in-{self.span_every} "
            f"sampled; every step while a timeline recorder is installed)")
        # per-slot diagnostics accrual (registry.enabled only). Decode
        # lanes: the coder's interval freq for position t lands in
        # _fbuf[b, t] (one fancy write per step, all log2 math deferred
        # to _finish_slot); compress lanes cost nothing per step — the
        # slot encoder's recorded steps are priced at flush. _nesc
        # counts escape symbols per slot (both directions).
        self._lanes = np.arange(B)
        self._fbuf = np.ones((B, C), np.int64)
        self._nesc = np.zeros(B, np.int64)
        # router-decision counters (DESIGN.md §11) — only move when the
        # service submits routed chunks (task.fallback attached)
        self._c_route_llm = self.registry.counter(
            obs.ROUTER_CHUNKS_LLM, "chunks routed to the LLM entropy path")
        self._c_route_fb = self.registry.counter(
            obs.ROUTER_CHUNKS_FALLBACK,
            "chunks routed to a fallback byte codec")
        self._c_route_flips = self.registry.counter(
            obs.ROUTER_FLIPS,
            "chunks where LLM encode ran but the fallback stream won")

    # ------------------------------------------------------------- intake
    def submit(self, task: ChunkTask, priority: int = 0) -> None:
        if task.valid == 0:         # empty chunk: no coded bytes, no slot
            task.complete(b"" if task.kind == COMPRESS
                          else np.zeros(0, np.int32))
            return
        need = int(getattr(task, "ctx_budget", 0))
        if need != self._ctx_budget:
            # geometry change: rebuild the model state while fully idle
            # (queued work counts as busy — its chunks must encode at the
            # geometry they were submitted under), never mid-flight
            if self._state is not None:
                if self._active.any() or self._queue:
                    raise ValueError(
                        f"task needs context budget {need} but the decode "
                        f"state runs at {self._ctx_budget} with work in "
                        f"flight; drain before mixing context geometries")
                self._state = None
                if self.prefix_cache is not None:
                    self.prefix_cache.clear()   # snapshots shape-mismatch
            self._ctx_budget = need
        ctx = getattr(task, "ctx", None)
        if ctx is not None and ctx.size > need:
            raise ValueError(
                f"chunk {task.chunk_index}: context of {ctx.size} tokens "
                f"exceeds the job's declared budget ({need})")
        if task.kind != COMPRESS and len(task.stream) < rans._STATE_BYTES:
            # any chunk that coded >= 1 token carries at least the coder
            # state flush; shorter means a corrupt length varint — fail at
            # submit, not mid-step in a shared batch (where the attach
            # would raise a bare ValueError and strand the slot)
            raise ContainerError(
                f"chunk {task.chunk_index}: stream of {len(task.stream)} "
                f"bytes cannot code {task.valid} tokens (corrupt container)")
        heapq.heappush(self._queue, (priority, self._seq, task))
        self._seq += 1

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active.any()

    # -------------------------------------------------------------- slots
    def _ensure_state(self):
        if self._state is None:
            if hasattr(self.predictor, "set_decode_len"):
                self.predictor.set_decode_len(self.C + self._ctx_budget)
            self._state = self.predictor.begin_decode(self.B)

    def _refill(self) -> None:
        """Assign queued chunk tasks to free slots; reset their cache
        lanes to a fresh context in ONE jitted call (mask input)."""
        free = np.nonzero(~self._active)[0]
        if not free.size or not self._queue:
            return
        # timeline-only span (DESIGN.md §13): placed after the idle early-
        # out so it marks productive refills, not every step's free-slot
        # check — the recording leg's overhead budget is 10%
        sp = obs.span("service.refill", self.registry, mirror=False) \
            if obs.timeline.active() is not None else obs.trace.NULL
        with sp:
            self._refill_slots(free)

    def _refill_slots(self, free) -> None:
        mask = np.zeros(self.B, bool)
        bos = getattr(self.predictor, "bos_id")
        restores: list[tuple[int, object]] = []
        for b in free:
            if not self._queue:
                break
            _, _, task = heapq.heappop(self._queue)
            self._tasks[b] = task
            self._active[b] = True
            self._is_dec[b] = task.kind != COMPRESS
            self._t[b] = 0
            self._valid[b] = task.valid
            self._prev[b] = bos
            self._nesc[b] = 0
            self._ctx[b] = None
            self._ctxlen[b] = self._cpos[b] = 0
            self._cachekey[b] = None
            ctx = getattr(task, "ctx", None)
            if ctx is not None and ctx.size:
                ctx = np.asarray(ctx, np.int32).ravel()
                L = len(ctx)
                self._ctx[b] = ctx
                self._ctxlen[b] = L
                can_cache = (self.prefix_cache is not None
                             and hasattr(self.predictor, "restore_slot"))
                if can_cache and getattr(task, "cacheable", False):
                    with obs.span("prefix_cache.lookup", self.registry,
                                  mirror=False) \
                            if obs.timeline.active() is not None \
                            else obs.trace.NULL:
                        matched, snap = self.prefix_cache.lookup(ctx)
                    if matched:
                        # resume from the stored post-prefill state: the
                        # snapshot's cache consumed [BOS, ctx[:matched-1]]
                        # and ctx[matched-1] is the next decode input
                        restores.append((b, snap))
                        self._cpos[b] = matched
                        self._prev[b] = ctx[matched - 1]
                    if matched < L:
                        self._cachekey[b] = ctx
            if task.kind == COMPRESS:
                self._tok_buf[b, :] = 0
                self._tok_buf[b, :task.valid] = task.tokens
                self._dec.detach(b)
            else:
                self._dec.attach(b, task.stream)
            mask[b] = True
            self._c_refills.inc()
        if mask.any() and self._state is not None:
            if hasattr(self.predictor, "reset_slots"):
                self._state = self.predictor.reset_slots(self._state, mask)
            elif (mask & self._used).any():
                # a stateful predictor without per-slot reset would hand a
                # refilled lane the previous chunk's context — corrupt
                # streams with no error. Refuse rather than degrade.
                raise ValueError(
                    "stateful predictor lacks reset_slots(state, mask); "
                    "slot refill needs a per-lane cache reset (see "
                    "serve/engine.ModelPredictor) — or use the grouped "
                    "decoder")
        if self._state is not None:
            for b, snap in restores:    # after reset: restore overwrites
                lane = np.zeros(self.B, bool)
                lane[b] = True
                self._state = self.predictor.restore_slot(self._state, snap,
                                                          lane)
        self._used |= mask

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """One fixed-shape model step + one coder step over all active
        slots. Returns False when there was nothing to do."""
        tel = self.registry.enabled
        # a live timeline recorder lifts the 1-in-N span sampling: phase
        # attribution needs every step on the timeline (≥90% coverage),
        # and the recording leg has its own ≤10% overhead budget
        rec = obs.timeline.active()
        sp = obs.span("service.step", self.registry,
                      mirror=rec is None) \
            if rec is not None or (tel and self.span_every
                                   and self._c_steps.value
                                   % self.span_every == 0) else obs.trace.NULL
        with sp:
            self._ensure_state()
            self._refill()
            m = self._active
            if not m.any():
                return False
            # model phase attribution: only worth a span while a timeline
            # is recording (serve/steps.py predictors carry their own
            # model.* spans; plain predictors would otherwise attribute
            # model time to the scheduler)
            msp = obs.span("model.decode_step", self.registry,
                           mirror=False) \
                if rec is not None else obs.trace.NULL
            with msp:
                logits, self._state = self.predictor.decode_step(
                    self._state, self._prev)
                logits = np.asarray(logits)
            pm = m & (self._cpos < self._ctxlen)     # prefilling context
            am = m & ~pm                             # coding this step
            dm = am & self._is_dec
            cm = am & ~self._is_dec
            tq = self._t % self.C
            truth = self._tok_buf[self._lanes, tq]
            if self.topk:
                # fused device top-k -> quantized CDF (kernels/ac_cdf.py on
                # TPU): no host pmf cumsum per step; same integers
                ids, cdfs = topk_cdf_jit(logits, self.topk, self.precision)
                ids = np.asarray(ids)
                cdfs = np.asarray(cdfs, np.int64)                # (B, K+2)
                syms = np.zeros(self.B, np.int64)
                if dm.any():
                    slots = self._dec.get(cdfs, self.precision, dm)
                    if tel:   # coder-computed interval freqs, one write
                        self._fbuf[self._lanes, tq] = self._dec.last_freq
                    esc = dm & (slots == self.topk)
                    syms = np.take_along_axis(
                        ids, np.minimum(slots, self.topk - 1)[:, None],
                        axis=-1)[:, 0].astype(np.int64)
                    if esc.any():
                        u = self._dec.get_uniform(self._esc_bits, esc)
                        syms = np.where(esc, u, syms)
                        self._c_escapes.inc(int(esc.sum()))
                        if tel:
                            self._nesc[esc] += 1
                if cm.any():
                    match = ids == truth[:, None]
                    has = match.any(axis=-1)
                    slot_e = np.where(has, match.argmax(axis=-1), self.topk)
                    starts = np.take_along_axis(cdfs, slot_e[:, None],
                                                axis=1)[:, 0]
                    ends = np.take_along_axis(cdfs, slot_e[:, None] + 1,
                                              axis=1)[:, 0]
                    self._enc.put(starts, ends - starts, self.precision, cm)
                    em = cm & ~has
                    if em.any():
                        self._enc.put_uniform(truth, self._esc_bits, em)
                        self._c_escapes.inc(int(em.sum()))
                        if tel:
                            self._nesc[em] += 1
            else:
                cdfs = np.asarray(full_cdf_jit(logits, self.precision),
                                  np.int64)                       # (B, V+1)
                syms = np.zeros(self.B, np.int64)
                if dm.any():
                    syms = self._dec.get(cdfs, self.precision, dm)
                    if tel:
                        self._fbuf[self._lanes, tq] = self._dec.last_freq
                if cm.any():
                    self._enc.put_symbols(truth.astype(np.int64), cdfs,
                                          self.precision, cm)
            # write decoded tokens; advance every coding lane. Prefill
            # lanes feed their next context token instead — their logits
            # this step are discarded (context conditioning only).
            nxt = np.where(dm, syms, truth).astype(np.int32)
            for b in np.nonzero(pm)[0]:
                nxt[b] = self._ctx[b][self._cpos[b]]
            self._tok_buf[dm, self._t[dm]] = nxt[dm]
            self._prev = np.where(m, nxt, self._prev).astype(np.int32)
            self._t[am] += 1
            self._cpos[pm] += 1
            self._c_steps.inc()
            self._c_lanes.inc(self.B)
            self._c_tokens.inc(int(am.sum()))
            if pm.any():
                self._c_prefill.inc(int(pm.sum()))
                for b in np.nonzero(pm & (self._cpos >=
                                          self._ctxlen))[0]:
                    # prefix fully consumed this step: the lane's cache now
                    # equals begin_decode(prefix=ctx) — snapshot it at the
                    # boundary so later jobs skip this prefill entirely
                    key = self._cachekey[int(b)]
                    if key is not None and self.prefix_cache is not None \
                            and hasattr(self.predictor, "snapshot_slot"):
                        self.prefix_cache.insert(
                            key, self.predictor.snapshot_slot(self._state,
                                                              int(b)))
                    self._cachekey[int(b)] = None
            for b in np.nonzero(m & (self._t >= self._valid))[0]:
                b = int(b)
                fin = self._tasks[b]
                with obs.span("service.finish_slot", self.registry,
                              tags={"job": fin.job.job_id,
                                    "chunk": fin.chunk_index},
                              mirror=False) \
                        if rec is not None else obs.trace.NULL:
                    self._finish_slot(b)
        if tel and self.log_every \
                and self._c_steps.value % self.log_every == 0:
            obs.log("scheduler.progress", steps=self._c_steps.value,
                    occupancy=round(self.stats.occupancy, 4),
                    chunks=self._c_chunks.value,
                    queued=len(self._queue),
                    failures=self._c_failures.value)
        return True

    def _finish_slot(self, b: int) -> None:
        task = self._tasks[b]
        codec = None
        try:
            coded = 0.0
            tel = self.registry.enabled
            if task.kind == COMPRESS:
                if tel:     # price the recorded steps before flush clears
                    coded = self._enc.slot_cost_bits(b)
                result = self._enc.flush_slot(b)
                nbytes = len(result)
                if task.fallback is not None and self.router is not None \
                        and getattr(task, "llm_bits_est", -1.0) >= 0:
                    # probe-vs-realized calibration for the adaptive skip
                    # margin — before the flip overwrites the LLM length
                    self.router.observe(task.llm_bits_est, 8.0 * nbytes,
                                        len(task.fallback))
                if task.fallback is not None:
                    # routed chunk: the probe kept the LLM path, but the
                    # realized fallback stream still wins if smaller —
                    # flip post-hoc (lane count stays coding geometry;
                    # lane composition is free, DESIGN.md §11)
                    if len(task.fallback) < nbytes:
                        result = task.fallback
                        nbytes = len(result)
                        codec = task.fallback_codec
                        coded = 8.0 * nbytes
                        self._c_route_fb.inc()
                        self._c_route_flips.inc()
                    else:
                        self._c_route_llm.inc()
            else:
                if not self._dec.exhausted(b):
                    raise ContainerError(
                        f"chunk {task.chunk_index}: rANS stream not "
                        f"exhausted after {task.valid} tokens (corrupt "
                        f"stream, wrong model, or a slot count different "
                        f"from the encoder's batch — see the container's "
                        f"recorded encode batch)")
                self._dec.detach(b)
                result = self._tok_buf[b, :task.valid].copy()
                nbytes = len(task.stream)
                if tel:     # deferred log2 over the chunk's coder freqs
                    f = np.maximum(self._fbuf[b, :task.valid], 1)
                    coded = (task.valid * self.precision
                             - float(np.log2(f).sum())
                             + int(self._nesc[b]) * self._esc_bits)
            diag = None
            if tel:
                ctx_name = ""
                rk, rp = getattr(task, "recipe", (0, 0))
                if rk and not codec:    # flipped chunks are context-free
                    ctx_name = f"carry({rp})" if rk == 1 else f"shared[{rp}]"
                diag = ChunkDiagnostics(
                    chunk_index=task.chunk_index, n_tokens=task.valid,
                    stream_bytes=nbytes, coded_bits=float(coded),
                    n_escapes=int(self._nesc[b]),
                    codec=codec or "rans", context=ctx_name)
                self._h_bpt.observe(diag.bits_per_token)
            task.complete(result, diag, codec=codec)
        except Exception as e:
            self._c_failures.inc()
            obs.log_exception("scheduler.chunk_failed", e,
                              job=task.job.job_id, chunk=task.chunk_index,
                              kind=task.kind)
            task.fail(e)
        self._tasks[b] = None
        self._active[b] = False
        self._is_dec[b] = False
        self._ctx[b] = None
        self._ctxlen[b] = self._cpos[b] = 0
        self._cachekey[b] = None
        self._c_chunks.inc()

    def run(self) -> SchedulerStats:
        """Drain queue + slots to completion."""
        while self.step():
            pass
        return self.stats
