"""Slot-based continuous-batching scheduler (DESIGN.md §8).

One fixed-shape jitted decode program serves mixed compress/decompress
traffic: the B slots each hold one chunk-stream; every ``step()`` runs
exactly one model ``decode_step`` over all B lanes plus one vectorized
rANS coder step over the active lanes. The grouped decoder
(``LLMCompressor._decode_group``) runs every step to ``valid.max()`` of
its group, so one long chunk holds the other slots idle; here a finished
slot is refilled from the priority queue on the next step, and the model
program never recompiles (B is constant, the masks are runtime inputs).

Both directions share each step's CDF tables, computed once per step
from the same logits:

* decompress slots pull their next token from the rANS decoder
  (per-slot streams attached/detached on refill);
* compress slots run teacher-forced "exact" scoring (DESIGN.md §6):
  the ground-truth token is fed back, its (start, freq) interval
  recorded in the per-slot LIFO encoder, and the slot's stream is
  flushed the moment the chunk completes (out-of-order completion —
  the v4 index footer puts the chunks back in order).

Bit-exactness across batch compositions: each lane's logits are a
function of that lane's cache and input only (attention/SSM/MoE-dropless
are lane-independent by construction — the same property the lock-step
decoder already relies on), and per-slot cache positions make a refilled
lane's computation identical to a fresh-cache decode. So a container
compressed by the service decodes through ``LLMCompressor`` and vice
versa, regardless of what traffic shared the batch.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.core import rans
from repro.core.cdf import DEFAULT_PRECISION, full_cdf_jit, topk_cdf_jit
from repro.core.compressor import ContainerError
from .session import COMPRESS, ChunkTask


@dataclass
class SchedulerStats:
    model_steps: int = 0          # fixed-shape decode_step invocations
    lane_steps: int = 0           # model_steps × B (capacity offered)
    token_steps: int = 0          # active-lane tokens actually coded
    chunks_completed: int = 0
    refills: int = 0

    @property
    def occupancy(self) -> float:
        """Fraction of offered lane-steps that coded a real token.
        0.0 when ``run()`` completed without executing a step (e.g. every
        job rejected at submit) — never a ZeroDivisionError."""
        if self.lane_steps == 0:
            return 0.0
        return self.token_steps / self.lane_steps


class SlotScheduler:
    """Continuous-batching executor over ``n_slots`` model lanes.

    The scheduler is codec-fixed to rANS (codec id 1): the interleaved
    coder is what makes one vectorized coder step per position possible.
    Legacy AC containers take the grouped path in the service API.
    """

    def __init__(self, predictor, *, n_slots: int, chunk_size: int,
                 topk: int = 0, precision: int = DEFAULT_PRECISION):
        if not 0 < precision <= rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} outside rANS range "
                             f"(1..{rans.MAX_PRECISION})")
        # The seq-sharded TP decode path collapses per-lane cache positions
        # with jnp.max — lock-step only; running it under slot refill would
        # corrupt streams silently. Refuse up front (same predicate the
        # model's decode dispatch uses, so the two cannot drift); such
        # predictors must use the grouped decoder.
        cfg = getattr(predictor, "cfg", None)
        if cfg is not None:
            from repro.models.transformer import decode_requires_lockstep
            if decode_requires_lockstep(cfg, getattr(predictor, "mesh",
                                                     None)):
                raise ValueError(
                    "continuous batching needs per-lane cache positions; "
                    "the seq-sharded TP decode path (padded_kv_heads not "
                    "divisible by TP) is lock-step only — use a replicated-"
                    "cache predictor or LLMCompressor's grouped decoder")
        self.predictor = predictor
        self.B = int(n_slots)
        self.C = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self._esc_bits = rans.uniform_bits(predictor.vocab_size)

        B, C = self.B, self.C
        self._queue: list = []          # heap of (priority, seq, task)
        self._seq = 0
        self._tasks: list[ChunkTask | None] = [None] * B
        self._active = np.zeros(B, bool)
        self._is_dec = np.zeros(B, bool)
        self._t = np.zeros(B, np.int64)         # next position per slot
        self._valid = np.zeros(B, np.int64)
        self._prev = np.zeros(B, np.int32)
        self._tok_buf = np.zeros((B, C), np.int32)   # per-slot chunk tokens
        self._dec = rans.BatchedRansDecoder([b""] * B)
        self._enc = rans.SlotRansEncoder(B)
        self._state = None              # model decode state, created lazily
        self._used = np.zeros(B, bool)  # lanes that have held a chunk
        self.stats = SchedulerStats()

    # ------------------------------------------------------------- intake
    def submit(self, task: ChunkTask, priority: int = 0) -> None:
        if task.valid == 0:         # empty chunk: no coded bytes, no slot
            task.complete(b"" if task.kind == COMPRESS
                          else np.zeros(0, np.int32))
            return
        if task.kind != COMPRESS and len(task.stream) < rans._STATE_BYTES:
            # any chunk that coded >= 1 token carries at least the coder
            # state flush; shorter means a corrupt length varint — fail at
            # submit, not mid-step in a shared batch (where the attach
            # would raise a bare ValueError and strand the slot)
            raise ContainerError(
                f"chunk {task.chunk_index}: stream of {len(task.stream)} "
                f"bytes cannot code {task.valid} tokens (corrupt container)")
        heapq.heappush(self._queue, (priority, self._seq, task))
        self._seq += 1

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active.any()

    # -------------------------------------------------------------- slots
    def _ensure_state(self):
        if self._state is None:
            if hasattr(self.predictor, "set_decode_len"):
                self.predictor.set_decode_len(self.C)
            self._state = self.predictor.begin_decode(self.B)

    def _refill(self) -> None:
        """Assign queued chunk tasks to free slots; reset their cache
        lanes to a fresh context in ONE jitted call (mask input)."""
        free = np.nonzero(~self._active)[0]
        if not free.size or not self._queue:
            return
        mask = np.zeros(self.B, bool)
        bos = getattr(self.predictor, "bos_id")
        for b in free:
            if not self._queue:
                break
            _, _, task = heapq.heappop(self._queue)
            self._tasks[b] = task
            self._active[b] = True
            self._is_dec[b] = task.kind != COMPRESS
            self._t[b] = 0
            self._valid[b] = task.valid
            self._prev[b] = bos
            if task.kind == COMPRESS:
                self._tok_buf[b, :] = 0
                self._tok_buf[b, :task.valid] = task.tokens
                self._dec.detach(b)
            else:
                self._dec.attach(b, task.stream)
            mask[b] = True
            self.stats.refills += 1
        if mask.any() and self._state is not None:
            if hasattr(self.predictor, "reset_slots"):
                self._state = self.predictor.reset_slots(self._state, mask)
            elif (mask & self._used).any():
                # a stateful predictor without per-slot reset would hand a
                # refilled lane the previous chunk's context — corrupt
                # streams with no error. Refuse rather than degrade.
                raise ValueError(
                    "stateful predictor lacks reset_slots(state, mask); "
                    "slot refill needs a per-lane cache reset (see "
                    "serve/engine.ModelPredictor) — or use the grouped "
                    "decoder")
        self._used |= mask

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """One fixed-shape model step + one coder step over all active
        slots. Returns False when there was nothing to do."""
        self._ensure_state()
        self._refill()
        m = self._active
        if not m.any():
            return False
        logits, self._state = self.predictor.decode_step(self._state,
                                                         self._prev)
        logits = np.asarray(logits)
        dm = m & self._is_dec
        cm = m & ~self._is_dec
        truth = self._tok_buf[np.arange(self.B), self._t % self.C]
        if self.topk:
            # fused device top-k -> quantized CDF (kernels/ac_cdf.py on
            # TPU): no host pmf cumsum per step; same integers
            ids, cdfs = topk_cdf_jit(logits, self.topk, self.precision)
            ids = np.asarray(ids)
            cdfs = np.asarray(cdfs, np.int64)                # (B, K+2)
            syms = np.zeros(self.B, np.int64)
            if dm.any():
                slots = self._dec.get(cdfs, self.precision, dm)
                esc = dm & (slots == self.topk)
                syms = np.take_along_axis(
                    ids, np.minimum(slots, self.topk - 1)[:, None],
                    axis=-1)[:, 0].astype(np.int64)
                if esc.any():
                    u = self._dec.get_uniform(self._esc_bits, esc)
                    syms = np.where(esc, u, syms)
            if cm.any():
                match = ids == truth[:, None]
                has = match.any(axis=-1)
                slot_e = np.where(has, match.argmax(axis=-1), self.topk)
                starts = np.take_along_axis(cdfs, slot_e[:, None],
                                            axis=1)[:, 0]
                ends = np.take_along_axis(cdfs, slot_e[:, None] + 1,
                                          axis=1)[:, 0]
                self._enc.put(starts, ends - starts, self.precision, cm)
                em = cm & ~has
                if em.any():
                    self._enc.put_uniform(truth, self._esc_bits, em)
        else:
            cdfs = np.asarray(full_cdf_jit(logits, self.precision),
                              np.int64)                       # (B, V+1)
            syms = np.zeros(self.B, np.int64)
            if dm.any():
                syms = self._dec.get(cdfs, self.precision, dm)
            if cm.any():
                self._enc.put_symbols(truth.astype(np.int64), cdfs,
                                      self.precision, cm)
        # write decoded tokens; advance every active lane
        nxt = np.where(dm, syms, truth).astype(np.int32)
        self._tok_buf[dm, self._t[dm]] = nxt[dm]
        self._prev = np.where(m, nxt, self._prev).astype(np.int32)
        self._t[m] += 1
        self.stats.model_steps += 1
        self.stats.lane_steps += self.B
        self.stats.token_steps += int(m.sum())
        for b in np.nonzero(m & (self._t >= self._valid))[0]:
            self._finish_slot(int(b))
        return True

    def _finish_slot(self, b: int) -> None:
        task = self._tasks[b]
        try:
            if task.kind == COMPRESS:
                task.complete(self._enc.flush_slot(b))
            else:
                if not self._dec.exhausted(b):
                    raise ContainerError(
                        f"chunk {task.chunk_index}: rANS stream not "
                        f"exhausted after {task.valid} tokens (corrupt "
                        f"stream, wrong model, or a slot count different "
                        f"from the encoder's batch — see the container's "
                        f"recorded encode batch)")
                self._dec.detach(b)
                task.complete(self._tok_buf[b, :task.valid].copy())
        except Exception as e:
            task.fail(e)
        self._tasks[b] = None
        self._active[b] = False
        self._is_dec[b] = False
        self.stats.chunks_completed += 1

    def run(self) -> SchedulerStats:
        """Drain queue + slots to completion."""
        while self.step():
            pass
        return self.stats
