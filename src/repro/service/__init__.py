"""Continuous-batching compression service (DESIGN.md §8).

The serving layer over the paper's chunked compressor: many independent
compress *and* decompress jobs are multiplexed into the same fixed-shape
(B,) decode steps of one jitted model program. When a chunk-stream
finishes, its slot is refilled from a priority queue instead of waiting
for the rest of its group — the lever that the chunk-independence of the
format (§5.4) makes safe and that the per-slot cache positions
(models/*, serve/engine.reset_slots) make bit-exact.

    service = CompressionService(predictor, slots=16, chunk_size=256,
                                 topk=48)
    h1 = service.submit_compress(tokens_a)
    h2 = service.submit_compress(tokens_b, priority=-1)   # jumps the queue
    h3 = service.submit_decompress(blob_c)
    blob_a, stats = h1.result()       # drives the scheduler as needed
    tokens_c = h3.result()

Containers written by the service are version 4 (seekable index footer +
xxh64 checksums), v5 with routing, or v6 when a job declares context
(``submit_compress(shared_prefix=..., context_window=W)``); it decodes
v2–v6 archives from any writer. Shared-prefix jobs reuse one prefilled
KV prefix through a radix prefix cache (``RadixPrefixCache``).
"""
from .api import CompressionService, ServiceError
from .prefix_cache import RadixPrefixCache
from .scheduler import SchedulerStats, SlotScheduler
from .session import ChunkTask, Job, JobHandle

__all__ = ["CompressionService", "ServiceError", "SlotScheduler",
           "SchedulerStats", "ChunkTask", "Job", "JobHandle",
           "RadixPrefixCache"]
