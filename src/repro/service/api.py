"""Public API of the compression service: submit → await.

``CompressionService`` owns one predictor (one jitted model program at a
fixed slot count) and multiplexes any number of concurrent compress and
decompress jobs through the continuous-batching scheduler. Results come
back through ``JobHandle.result()``, which cooperatively drives the
scheduler until that job completes — submit many handles first, then
await them in any order, and all jobs share every model step.

Containers: writes v4 (seekable index footer + xxh64 checksums; the
out-of-order chunk completion of the scheduler needs the index anyway).
Reads v2/v3/v4; legacy AC-codec containers (and all v2 archives) cannot
ride the interleaved-rANS slot machine, so they are decoded eagerly at
submit time through the grouped path — same result, no await needed.
AC archives above the rANS precision cap can't construct a matching
service at all (the cap guards the service's own rANS coding) — decode
those through ``LLMCompressor`` directly, as the ``llmc`` CLI does.
"""
from __future__ import annotations

import numpy as np

from repro.core import rans
from repro.core.cdf import DEFAULT_PRECISION
from repro.core.compressor import (CODEC_AC, CODEC_RANS, VERSION_V4,
                                   CompressionStats, ContainerError,
                                   LLMCompressor, check_container_config,
                                   parse_container, write_container)
from repro.obs import MetricsRegistry
from .scheduler import SlotScheduler
from .session import COMPRESS, DECOMPRESS, ChunkTask, Job, JobHandle


class ServiceError(RuntimeError):
    """Internal service failure (scheduler stall, double completion)."""


class ServiceStats:
    """``service.stats`` — both the old attribute API and the new
    structured snapshot.

    Attribute reads (``svc.stats.occupancy``, ``svc.stats.model_steps``)
    delegate to the scheduler's counter-backed ``SchedulerStats`` view,
    so pre-PR-7 callers are unchanged; *calling* it
    (``svc.stats()``) returns the full structured snapshot dict —
    the ``CompressionService.stats()`` surface from ISSUE 7."""

    __slots__ = ("_service",)

    def __init__(self, service: "CompressionService"):
        self._service = service

    def __getattr__(self, name):
        return getattr(self._service.scheduler.stats, name)

    def __call__(self) -> dict:
        return self._service.snapshot()

    def __repr__(self) -> str:
        return f"ServiceStats({self._service.scheduler.stats!r})"


class CompressionService:
    """Continuous-batching compression/decompression server over one
    predictor. See repro.service.__init__ for usage."""

    def __init__(self, predictor, *, slots: int = 8, chunk_size: int = 256,
                 topk: int = 0, precision: int = DEFAULT_PRECISION,
                 container_version: int = VERSION_V4,
                 registry: MetricsRegistry | None = None):
        if topk and topk >= predictor.vocab_size:
            topk = 0
        if (1 << precision) <= (topk + 1 if topk else predictor.vocab_size):
            raise ValueError("precision too small for alphabet")
        if precision > rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} exceeds rANS coder "
                             f"limit {rans.MAX_PRECISION}")
        self.predictor = predictor
        self.slots = int(slots)
        self.chunk_size = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self.container_version = int(container_version)
        # private per-service registry by default: stats() must describe
        # THIS service's traffic, not every service in the process. Pass
        # obs.registry() to aggregate into the process-global view.
        self.registry = registry if registry is not None \
            else MetricsRegistry(name="service")
        self.scheduler = SlotScheduler(predictor, n_slots=self.slots,
                                       chunk_size=self.chunk_size,
                                       topk=self.topk,
                                       precision=self.precision,
                                       registry=self.registry)
        self._next_job = 0
        self._legacy: LLMCompressor | None = None
        self._stats = ServiceStats(self)

    # ------------------------------------------------------------- submit
    def submit_compress(self, tokens, *, priority: int = 0) -> JobHandle:
        """Queue a token stream for compression into a v4 container."""
        tokens = np.asarray(tokens, np.int32).ravel()
        n = int(tokens.size)
        C = self.chunk_size
        n_chunks = -(-n // C)            # 0 tokens => 0 chunks

        def assemble(streams: list[bytes]):
            blob = write_container(
                streams, version=self.container_version, chunk_size=C,
                n_tokens=n, vocab=self.predictor.vocab_size,
                topk=self.topk, precision=self.precision,
                codec_id=CODEC_RANS, encode_batch=self.slots)
            payload = sum(len(s) for s in streams)
            return blob, CompressionStats(
                n_tokens=n, payload_bytes=payload,
                header_bytes=len(blob) - payload)

        job = Job(self._new_job_id(), COMPRESS, priority, n_chunks, n,
                  assemble, codec="rans", registry=self.registry)
        self.registry.counter("service.jobs_submitted").inc()
        self.registry.counter("service.compress_jobs").inc()
        if n_chunks == 0:
            # empty input: a valid zero-chunk container, no scheduler
            # involvement (there is no chunk completion to wait for)
            job.resolve(assemble([]))
            return JobHandle(job, self)
        for i in range(n_chunks):
            lo, hi = i * C, min((i + 1) * C, n)
            self.scheduler.submit(
                ChunkTask(job, i, COMPRESS, max(0, hi - lo),
                          tokens=tokens[lo:hi]),
                priority)
        return JobHandle(job, self)

    def submit_decompress(self, blob: bytes, *, priority: int = 0) -> JobHandle:
        """Queue a container for decompression. The container is parsed
        and integrity-checked up front (raises ContainerError on corrupt
        or configuration-mismatched blobs — bad input fails at submit,
        not mid-flight in a shared batch)."""
        info, streams = parse_container(blob)
        check_container_config(info, vocab=self.predictor.vocab_size,
                               chunk_size=self.chunk_size, topk=self.topk,
                               precision=self.precision)
        if info.codec == CODEC_RANS:
            # reject before anything is queued, so a corrupt container
            # cannot leave a partial job's chunks orphaned in the queue
            for i, (s, e) in enumerate(zip(streams, info.entries)):
                if e.n_tokens > 0 and len(s) < rans._STATE_BYTES:
                    raise ContainerError(
                        f"chunk {i}: stream of {len(s)} bytes cannot code "
                        f"{e.n_tokens} tokens (corrupt container)")
        job = Job(self._new_job_id(), DECOMPRESS, priority, info.n_chunks,
                  info.n_tokens,
                  lambda chunks: np.concatenate(chunks)[:info.n_tokens]
                  if chunks else np.zeros(0, np.int32),
                  codec="rans" if info.codec == CODEC_RANS else "ac",
                  registry=self.registry)
        self.registry.counter("service.jobs_submitted").inc()
        self.registry.counter("service.decompress_jobs").inc()
        if info.n_chunks == 0:
            job.resolve(np.zeros(0, np.int32))   # valid empty container
            return JobHandle(job, self)
        if info.codec == CODEC_AC:
            # legacy codec: grouped lock-step decode, resolved eagerly
            job.resolve(self._legacy_compressor().decompress(blob))
            return JobHandle(job, self)
        for i, (stream, entry) in enumerate(zip(streams, info.entries)):
            self.scheduler.submit(
                ChunkTask(job, i, DECOMPRESS, entry.n_tokens,
                          stream=stream),
                priority)
        return JobHandle(job, self)

    # -------------------------------------------------------------- drive
    def poll(self) -> bool:
        """Advance the scheduler by one fixed-shape step; False if idle."""
        return self.scheduler.step()

    def run(self) -> None:
        """Drain every queued job to completion."""
        self.scheduler.run()

    def _run_until(self, job: Job) -> None:
        while not job.done:
            if not self.scheduler.step():
                raise ServiceError(
                    f"scheduler idle but job {job.job_id} incomplete "
                    f"({len(job._results)}/{job.n_chunks} chunks)")

    @property
    def stats(self) -> ServiceStats:
        """Attribute-compatible stats view; call it (``svc.stats()``) for
        the structured snapshot."""
        return self._stats

    def snapshot(self) -> dict:
        """Structured telemetry snapshot of this service: scheduler
        counters + occupancy, job counters, chunk bits/token summary,
        draft-acceptance rate (None until a speculative decode ran), and
        the raw registry dump (JSON-serializable)."""
        reg = self.registry
        sched = self.scheduler.stats.snapshot()
        h = reg.get("chunk.bits_per_token")
        bpt = None
        if h is not None and h.count:
            bpt = {"count": h.count, "mean": h.mean,
                   "p50": h.quantile(0.5), "p99": h.quantile(0.99)}
        offered = reg.value("spec.drafted_tokens")
        acc = reg.value("spec.drafted_accepted")
        return {
            "scheduler": sched,
            "occupancy": sched["occupancy"],
            "jobs": {
                "submitted": reg.value("service.jobs_submitted"),
                "failed": reg.value("service.jobs_failed"),
                "compress": reg.value("service.compress_jobs"),
                "decompress": reg.value("service.decompress_jobs"),
            },
            "chunk_bits_per_token": bpt,
            "draft_acceptance": (acc / offered) if offered else None,
            "metrics": reg.snapshot(),
        }

    # ------------------------------------------------------------ helpers
    def _new_job_id(self) -> int:
        self._next_job += 1
        return self._next_job

    def _legacy_compressor(self) -> LLMCompressor:
        if self._legacy is None:
            self._legacy = LLMCompressor(
                self.predictor, chunk_size=self.chunk_size, topk=self.topk,
                precision=self.precision, decode_batch=self.slots,
                registry=self.registry)
        return self._legacy
