"""Public API of the compression service: submit → await.

``CompressionService`` owns one predictor (one jitted model program at a
fixed slot count) and multiplexes any number of concurrent compress and
decompress jobs through the continuous-batching scheduler. Results come
back through ``JobHandle.result()``, which cooperatively drives the
scheduler until that job completes — submit many handles first, then
await them in any order, and all jobs share every model step.

Containers: writes v4 (seekable index footer + xxh64 checksums; the
out-of-order chunk completion of the scheduler needs the index anyway),
or v5 when adaptive routing is on (``route != "llm"`` — per-chunk codec
tags, DESIGN.md §11). Routing happens at submit: every chunk's realized
best-fallback stream is built up front, the probe marks poorly-modelled
chunks, and those complete *immediately* — they never occupy a model
slot, so unpredictable traffic stops costing model steps. Chunks that do
enter the batch still flip to their fallback at completion if the
fallback stream turned out smaller (``SlotScheduler._finish_slot``).

Context (v6, DESIGN.md §12): ``submit_compress(shared_prefix=...,
context_window=W)`` upgrades the job's container to v6 with per-chunk
context recipes. Shared prefixes prefill once per slot wave and are
snapshotted into a radix prefix cache (``service.prefix_cache``), so
jobs sharing a system prompt/template reuse one prefilled KV prefix —
``prefix_cache.hits``/``misses``/``evictions`` count the reuse.

Reads v2–v6; legacy AC-codec containers (and all v2 archives) cannot
ride the interleaved-rANS slot machine, so they are decoded eagerly at
submit time through the grouped path — same result, no await needed;
v6 archives with carried/shared recipes take the same eager grouped
path (carry chains need in-order predecessors, not out-of-order slots).
Fallback-tagged v5/v6 chunks similarly decode eagerly at submit (they
need no model); only the LLM-tagged chunks are queued.
AC archives above the rANS precision cap can't construct a matching
service at all (the cap guards the service's own rANS coding) — decode
those through ``LLMCompressor`` directly, as the ``llmc`` CLI does.
"""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.core import rans
from repro.core.cdf import DEFAULT_PRECISION
from repro.core.compressor import (CODEC_AC, CODEC_RANS,
                                   FALLBACK_CODEC_IDS, RECIPE_NONE,
                                   RECIPE_SHARED, VERSION_V4, VERSION_V5,
                                   VERSION_V6, CompressionStats,
                                   ContainerError, LLMCompressor,
                                   assign_context_recipes,
                                   check_container_config, context_budget,
                                   chunk_valid_lengths, parse_container,
                                   recipe_context, write_container)
from repro.core.router import (ROUTE_AUTO, ROUTE_LLM, CodecRouter,
                               RouterConfig, route_chunks)
from repro.obs import MetricsRegistry
from .prefix_cache import RadixPrefixCache
from .scheduler import SlotScheduler
from .session import COMPRESS, DECOMPRESS, ChunkTask, Job, JobHandle


class ServiceError(RuntimeError):
    """Internal service failure (scheduler stall, double completion)."""


class ServiceStats:
    """``service.stats`` — both the old attribute API and the new
    structured snapshot.

    Attribute reads (``svc.stats.occupancy``, ``svc.stats.model_steps``)
    delegate to the scheduler's counter-backed ``SchedulerStats`` view,
    so pre-PR-7 callers are unchanged; *calling* it
    (``svc.stats()``) returns the full structured snapshot dict —
    the ``CompressionService.stats()`` surface from ISSUE 7."""

    __slots__ = ("_service",)

    def __init__(self, service: "CompressionService"):
        self._service = service

    def __getattr__(self, name):
        return getattr(self._service.scheduler.stats, name)

    def __call__(self) -> dict:
        return self._service.snapshot()

    def __repr__(self) -> str:
        return f"ServiceStats({self._service.scheduler.stats!r})"


class CompressionService:
    """Continuous-batching compression/decompression server over one
    predictor. See repro.service.__init__ for usage."""

    def __init__(self, predictor, *, slots: int = 8, chunk_size: int = 256,
                 topk: int = 0, precision: int = DEFAULT_PRECISION,
                 container_version: int | None = None,
                 route: str = ROUTE_LLM,
                 router: CodecRouter | RouterConfig | None = None,
                 registry: MetricsRegistry | None = None,
                 prefix_cache_tokens: int = 1 << 16,
                 trace: "str | obs.TimelineRecorder | None" = None):
        if topk and topk >= predictor.vocab_size:
            topk = 0
        if (1 << precision) <= (topk + 1 if topk else predictor.vocab_size):
            raise ValueError("precision too small for alphabet")
        if precision > rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} exceeds rANS coder "
                             f"limit {rans.MAX_PRECISION}")
        if route not in (ROUTE_LLM, ROUTE_AUTO) \
                and route not in FALLBACK_CODEC_IDS:
            raise ValueError(
                f"unknown route {route!r} (choose 'llm', 'auto', or a "
                f"fallback codec from {sorted(FALLBACK_CODEC_IDS)})")
        if container_version is None:
            container_version = VERSION_V4 if route == ROUTE_LLM \
                else VERSION_V5
        if route != ROUTE_LLM and container_version < VERSION_V5:
            raise ValueError(
                f"route={route!r} requires a v5+ container (per-chunk "
                f"codec tags); cannot write v{container_version}")
        self.route = route
        if isinstance(router, CodecRouter):
            self.router = router
        elif isinstance(router, RouterConfig):
            self.router = CodecRouter(router)
        elif route in FALLBACK_CODEC_IDS:
            self.router = CodecRouter(RouterConfig(fallbacks=(route,)))
        else:
            self.router = CodecRouter()
        self.predictor = predictor
        self.slots = int(slots)
        self.chunk_size = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self.container_version = int(container_version)
        # private per-service registry by default: stats() must describe
        # THIS service's traffic, not every service in the process. Pass
        # obs.registry() to aggregate into the process-global view.
        self.registry = registry if registry is not None \
            else MetricsRegistry(name="service")
        # shared-prefix KV reuse across jobs (v6): only touched when a
        # submit declares a cacheable context — model-free traffic and
        # plain v4/v5 jobs never reach it
        self.prefix_cache = RadixPrefixCache(
            capacity_tokens=int(prefix_cache_tokens),
            registry=self.registry)
        self.scheduler = SlotScheduler(predictor, n_slots=self.slots,
                                       chunk_size=self.chunk_size,
                                       topk=self.topk,
                                       precision=self.precision,
                                       registry=self.registry,
                                       prefix_cache=self.prefix_cache,
                                       router=self.router)
        self._next_job = 0
        self._legacy: LLMCompressor | None = None
        self._stats = ServiceStats(self)
        # performance attribution (DESIGN.md §13): trace= installs a
        # process-wide TimelineRecorder for this service's lifetime —
        # every span lands on the timeline, JobHandle.diagnostics gains a
        # per-job PhaseReport, and write_timeline() exports Chrome-trace
        # JSON. trace may be a path (saved by write_timeline/close), a
        # recorder instance, or None (no recording, no overhead).
        self.trace_path = None
        self.trace_recorder: obs.TimelineRecorder | None = None
        if trace is not None:
            if isinstance(trace, obs.TimelineRecorder):
                self.trace_recorder = trace
            else:
                self.trace_path = trace
                self.trace_recorder = obs.TimelineRecorder()
            obs.timeline.install(self.trace_recorder)

    # ------------------------------------------------------------- submit
    def submit_compress(self, tokens, *, priority: int = 0,
                        shared_prefix=None,
                        context_window: int = 0) -> JobHandle:
        """Queue a token stream for compression into a v4 container
        (v5 with per-chunk codec tags when routing is enabled).

        Context (v6): ``shared_prefix`` conditions every stripe-head
        chunk on the given token prefix (the radix prefix cache makes
        jobs sharing it pay its prefill once), ``context_window=W``
        carries each previous chunk's W-token tail into the next chunk
        of the stripe. Either option upgrades this job's container to
        v6 — the recipes ride in the index footer so any decoder can
        rematerialize the same context."""
        tokens = np.asarray(tokens, np.int32).ravel()
        n = int(tokens.size)
        C = self.chunk_size
        n_chunks = -(-n // C)            # 0 tokens => 0 chunks

        sp = None
        if shared_prefix is not None:
            sp = np.asarray(shared_prefix, np.int32).ravel()
            if sp.size == 0:
                sp = None
        ctx_on = sp is not None or context_window > 0
        version = max(self.container_version, VERSION_V6) if ctx_on \
            else self.container_version
        recipes = None
        chunks2d = valids = None
        if n_chunks:
            padded = np.zeros(n_chunks * C, np.int32)
            padded[:n] = tokens
            chunks2d = padded.reshape(n_chunks, C)
            valids = chunk_valid_lengths(n, C)
        ctx_budget = 0
        if ctx_on and n_chunks:
            # one stripe per slot: carry chains decode round-robin across
            # the recorded lane count, so carry never serializes decode
            recipes = assign_context_recipes(
                n_chunks, context_window=int(context_window),
                stripes=min(self.slots, n_chunks), shared=sp is not None)
            # job-wide decode-length geometry, recorded in the v6 footer:
            # every chunk of the job — context-free heads included — runs
            # the model program at chunk_size + ctx_budget positions
            ctx_budget = context_budget(
                recipes, valids, [("shared", sp)] if sp is not None else [])

        decisions = fb = None
        if self.route != ROUTE_LLM and n_chunks:
            decisions, fb = route_chunks(
                self.router, self.predictor, chunks2d,
                valids, "rans", auto=self.route == ROUTE_AUTO)

        sp_list = [("shared", sp)] if sp is not None else []

        def assemble(streams: list[bytes]):
            tags = None
            if version >= VERSION_V5:
                # late-bound through the job: fallback codec names were
                # recorded per chunk as completions arrived
                tags = [FALLBACK_CODEC_IDS.get(job._codecs.get(i),
                                               CODEC_RANS)
                        for i in range(n_chunks)]
            rec = None
            if version >= VERSION_V6 and recipes is not None:
                # fallback chunks are context-free by format law: zero the
                # recipe wherever the router (or a flip) won the chunk
                rec = [(RECIPE_NONE, 0) if i in job._codecs else recipes[i]
                       for i in range(n_chunks)]
            blob = write_container(
                streams, version=version, chunk_size=C,
                n_tokens=n, vocab=self.predictor.vocab_size,
                topk=self.topk, precision=self.precision,
                codec_id=CODEC_RANS, encode_batch=self.slots,
                codec_tags=tags, recipes=rec,
                shared_prefixes=sp_list if rec is not None else None,
                ctx_budget=ctx_budget)
            payload = sum(len(s) for s in streams)
            return blob, CompressionStats(
                n_tokens=n, payload_bytes=payload,
                header_bytes=len(blob) - payload)

        job = Job(self._new_job_id(), COMPRESS, priority, n_chunks, n,
                  assemble, codec="rans", registry=self.registry)
        self.registry.counter("service.jobs_submitted").inc()
        self.registry.counter("service.compress_jobs").inc()
        if n_chunks == 0:
            # empty input: a valid zero-chunk container, no scheduler
            # involvement (there is no chunk completion to wait for)
            job.resolve(assemble([]))
            return JobHandle(job, self)
        for i in range(n_chunks):
            lo, hi = i * C, min((i + 1) * C, n)
            valid = max(0, hi - lo)
            if decisions is not None and decisions[i].codec != "rans":
                # the probe (or a forced route) diverted this chunk: it
                # completes right now and never takes a model slot
                name, stream = fb[i]
                self.registry.counter(obs.ROUTER_CHUNKS_FALLBACK).inc()
                if decisions[i].llm_bits_est >= 0:
                    self.registry.counter(obs.ROUTER_PROBE_SKIPS).inc()
                diag = None
                if self.registry.enabled:
                    diag = obs.ChunkDiagnostics(
                        chunk_index=i, n_tokens=valid,
                        stream_bytes=len(stream),
                        coded_bits=8.0 * len(stream), codec=name)
                job._chunk_done(i, stream, diag, codec=name)
                continue
            task = ChunkTask(job, i, COMPRESS, valid, tokens=tokens[lo:hi],
                             ctx_budget=ctx_budget)
            if decisions is not None:
                task.fallback, task.fallback_codec = fb[i][1], fb[i][0]
                task.llm_bits_est = decisions[i].llm_bits_est
            if recipes is not None and recipes[i][0] != RECIPE_NONE:
                task.recipe = recipes[i]
                # same materialization the v6 decoder will use, so the
                # encode-side context cannot drift from the format's
                task.ctx = recipe_context(recipes, chunks2d, valids, i,
                                          sp_list)
                task.cacheable = recipes[i][0] == RECIPE_SHARED
            self.scheduler.submit(task, priority)
        return JobHandle(job, self)

    def submit_decompress(self, blob: bytes, *, priority: int = 0) -> JobHandle:
        """Queue a container for decompression. The container is parsed
        and integrity-checked up front (raises ContainerError on corrupt
        or configuration-mismatched blobs — bad input fails at submit,
        not mid-flight in a shared batch)."""
        info, streams = parse_container(blob)
        check_container_config(info, vocab=self.predictor.vocab_size,
                               chunk_size=self.chunk_size, topk=self.topk,
                               precision=self.precision)
        if info.codec == CODEC_RANS:
            # reject before anything is queued, so a corrupt container
            # cannot leave a partial job's chunks orphaned in the queue
            for i, (s, e) in enumerate(zip(streams, info.entries)):
                if e.is_llm and e.n_tokens > 0 \
                        and len(s) < rans._STATE_BYTES:
                    raise ContainerError(
                        f"chunk {i}: stream of {len(s)} bytes cannot code "
                        f"{e.n_tokens} tokens (corrupt container)")
        # fallback-tagged v5 chunks need no model: decode them NOW, before
        # anything is queued — a corrupt fallback stream therefore fails
        # the whole submit (ContainerError) without orphaning queued work
        fb_tokens: dict[int, np.ndarray] = {}
        for i, (stream, entry) in enumerate(zip(streams, info.entries)):
            if entry.is_llm or entry.n_tokens == 0:
                continue
            try:
                fb_tokens[i] = CodecRouter.decode_fallback(
                    entry.codec_name, stream, entry.n_tokens, info.vocab)
            except ValueError as e:
                raise ContainerError(f"corrupt container: chunk {i}: {e}")
        job = Job(self._new_job_id(), DECOMPRESS, priority, info.n_chunks,
                  info.n_tokens,
                  lambda chunks: np.concatenate(chunks)[:info.n_tokens]
                  if chunks else np.zeros(0, np.int32),
                  codec="rans" if info.codec == CODEC_RANS else "ac",
                  registry=self.registry)
        self.registry.counter("service.jobs_submitted").inc()
        self.registry.counter("service.decompress_jobs").inc()
        if info.n_chunks == 0:
            job.resolve(np.zeros(0, np.int32))   # valid empty container
            return JobHandle(job, self)
        carried = any(e.recipe_kind != RECIPE_NONE for e in info.entries)
        if info.codec == CODEC_AC or carried:
            # legacy codec, or v6 carried context: grouped lock-step
            # decode, resolved eagerly. Carried chunks need their
            # predecessors' tokens before they can decode — that ordering
            # is the grouped decoder's chain scheduling, not the slot
            # machine's out-of-order refill. (An all-fallback v6 archive
            # has every recipe zeroed by format law, so it never lands
            # here and stays model-free below.)
            job.resolve(self._legacy_compressor().decompress(blob))
            return JobHandle(job, self)
        for i, (stream, entry) in enumerate(zip(streams, info.entries)):
            if i in fb_tokens:
                self.registry.counter(
                    "decompress.fallback_chunks",
                    "fallback-tagged chunks decoded without the "
                    "model").inc()
                job._chunk_done(i, fb_tokens[i],
                                codec=entry.codec_name)
                continue
            self.scheduler.submit(
                ChunkTask(job, i, DECOMPRESS, entry.n_tokens,
                          stream=stream,
                          ctx_budget=getattr(info, "ctx_budget", 0)),
                priority)
        return JobHandle(job, self)

    # -------------------------------------------------------------- drive
    def poll(self) -> bool:
        """Advance the scheduler by one fixed-shape step; False if idle."""
        return self.scheduler.step()

    def run(self) -> None:
        """Drain every queued job to completion."""
        self.scheduler.run()

    def _run_until(self, job: Job) -> None:
        while not job.done:
            if not self.scheduler.step():
                raise ServiceError(
                    f"scheduler idle but job {job.job_id} incomplete "
                    f"({len(job._results)}/{job.n_chunks} chunks)")

    @property
    def stats(self) -> ServiceStats:
        """Attribute-compatible stats view; call it (``svc.stats()``) for
        the structured snapshot."""
        return self._stats

    def snapshot(self) -> dict:
        """Structured telemetry snapshot of this service: scheduler
        counters + occupancy, job counters, chunk bits/token summary,
        draft-acceptance rate (None until a speculative decode ran), and
        the raw registry dump (JSON-serializable)."""
        reg = self.registry
        sched = self.scheduler.stats.snapshot()
        h = reg.get("chunk.bits_per_token")
        bpt = None
        if h is not None and h.count:
            bpt = {"count": h.count, "mean": h.mean,
                   "p50": h.quantile(0.5), "p95": h.quantile(0.95),
                   "p99": h.quantile(0.99)}
        offered = reg.value("spec.drafted_tokens")
        acc = reg.value("spec.drafted_accepted")
        return {
            "scheduler": sched,
            "occupancy": sched["occupancy"],
            "jobs": {
                "submitted": reg.value("service.jobs_submitted"),
                "failed": reg.value("service.jobs_failed"),
                "compress": reg.value("service.compress_jobs"),
                "decompress": reg.value("service.decompress_jobs"),
            },
            "chunk_bits_per_token": bpt,
            "draft_acceptance": (acc / offered) if offered else None,
            "prefix_cache": {
                "hits": reg.value("prefix_cache.hits"),
                "misses": reg.value("prefix_cache.misses"),
                "evictions": reg.value("prefix_cache.evictions"),
                "tokens_reused": reg.value("prefix_cache.tokens_reused"),
                "entries": len(self.prefix_cache),
                "size_tokens": self.prefix_cache.size_tokens,
            },
            "metrics": reg.snapshot(),
            "phases": {k: round(v, 6) for k, v in
                       obs.timeline.phases_from_registry(reg).items()},
        }

    # -------------------------------------------------------- attribution
    def write_timeline(self, path=None) -> "str | None":
        """Export the service's recorded timeline as Chrome-trace JSON
        (loads in chrome://tracing / ui.perfetto.dev). ``path`` defaults
        to the ``trace=`` path given at construction; returns the path
        written, or None when the service records no timeline."""
        rec = self.trace_recorder
        path = path if path is not None else self.trace_path
        if rec is None or path is None:
            return None
        rec.save(path)
        return str(path)

    def close(self) -> None:
        """Uninstall this service's timeline recorder (and save to the
        ``trace=`` path, if one was given). Idempotent; a service without
        tracing closes as a no-op."""
        rec = self.trace_recorder
        if rec is None:
            return
        self.write_timeline()
        if obs.timeline.active() is rec:
            obs.timeline.uninstall()
        self.trace_recorder = None

    # ------------------------------------------------------------ helpers
    def _new_job_id(self) -> int:
        self._next_job += 1
        return self._next_job

    def _legacy_compressor(self) -> LLMCompressor:
        if self._legacy is None:
            self._legacy = LLMCompressor(
                self.predictor, chunk_size=self.chunk_size, topk=self.topk,
                precision=self.precision, decode_batch=self.slots,
                registry=self.registry)
        return self._legacy
