"""Radix-tree shared-prefix cache for the compression service (v6).

Jobs that share a system prompt / template prefix should pay its prefill
once, not once per chunk: the scheduler prefills the first slot that
needs a given prefix, snapshots that lane's post-prefill KV state
(``predictor.snapshot_slot``), and stores it here keyed by the prefix
*tokens*. Every later slot that needs the same prefix restores the
snapshot (``predictor.restore_slot``) instead of re-running prefill —
the sglang-style radix-attention idea (SNIPPETS.md) applied to the
decode-side entropy coder.

The tree is path-compressed: each edge carries a token-array label, and
a node holds a value when a stored prefix ends exactly there. ``lookup``
returns the **deepest stored ancestor** of the query, so a job whose
prefix extends a cached one still reuses the cached part and only
prefills the tail (partial hit).

Eviction is LRU by *stored prefix tokens* against ``capacity_tokens`` —
the sglang accounting: what the cache protects is prefill compute, which
is linear in prefix length. Evicting a value leaves the skeleton nodes
in place (host-side token labels only; the device snapshot is what is
released).

Correctness note: a snapshot is only ever restored for a query whose
tokens extend the snapshot's exact insertion path, so a restore can
never substitute a different context — a hash collision cannot occur
because the key IS the token sequence.

Counters (in the owning registry): ``prefix_cache.hits``,
``prefix_cache.misses``, ``prefix_cache.evictions``,
``prefix_cache.tokens_reused`` (prefill steps avoided).
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.obs import MetricsRegistry


class _Node:
    __slots__ = ("edges", "value", "depth", "tick")

    def __init__(self, depth: int = 0):
        # first token of the edge label -> (label tokens, child node)
        self.edges: dict[int, tuple[np.ndarray, "_Node"]] = {}
        self.value: Any = None          # stored snapshot (None = skeleton)
        self.depth = depth              # tokens from root to this node
        self.tick = 0                   # LRU clock at last touch


class RadixPrefixCache:
    """Longest-stored-prefix lookup over token sequences, LRU-bounded."""

    def __init__(self, capacity_tokens: int = 1 << 16,
                 registry: Optional[MetricsRegistry] = None):
        if capacity_tokens <= 0:
            raise ValueError("capacity_tokens must be positive")
        self.capacity = int(capacity_tokens)
        self._root = _Node()
        self._entries: list[_Node] = []     # nodes currently holding values
        self._clock = 0
        self._size = 0                      # sum of stored prefix depths
        reg = registry if registry is not None \
            else MetricsRegistry(name="prefix_cache")
        self._c_hits = reg.counter(
            "prefix_cache.hits", "lookups that reused a stored KV prefix")
        self._c_misses = reg.counter(
            "prefix_cache.misses", "lookups with no stored ancestor")
        self._c_evict = reg.counter(
            "prefix_cache.evictions", "stored prefixes dropped by LRU")
        self._c_reused = reg.counter(
            "prefix_cache.tokens_reused",
            "prefill token-steps avoided via cache hits")

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def size_tokens(self) -> int:
        return self._size

    def lookup(self, tokens: np.ndarray) -> tuple[int, Any]:
        """Longest stored prefix of ``tokens``: returns ``(matched, value)``
        where the stored prefix is exactly ``tokens[:matched]``; (0, None)
        on a miss. Counts a hit only when a value is reused."""
        tokens = np.asarray(tokens, np.int32).ravel()
        self._clock += 1
        node, pos = self._root, 0
        best_node = None
        while pos < len(tokens):
            edge = node.edges.get(int(tokens[pos]))
            if edge is None:
                break
            label, child = edge
            n = len(label)
            if pos + n > len(tokens) or \
                    not np.array_equal(label, tokens[pos:pos + n]):
                break               # partial edge match: no node down there
            node, pos = child, pos + n
            if node.value is not None:
                best_node = node
        if best_node is None:
            self._c_misses.inc()
            return 0, None
        best_node.tick = self._clock
        self._c_hits.inc()
        self._c_reused.inc(best_node.depth)
        return best_node.depth, best_node.value

    # ------------------------------------------------------------- updates
    def insert(self, tokens: np.ndarray, value: Any) -> None:
        """Store ``value`` (a per-lane KV snapshot) for exactly
        ``tokens``. Replaces any previous value at that key; evicts LRU
        entries if the stored-token budget is exceeded."""
        tokens = np.asarray(tokens, np.int32).ravel()
        if tokens.size == 0:
            raise ValueError("cannot cache an empty prefix")
        self._clock += 1
        node, pos = self._root, 0
        while pos < len(tokens):
            first = int(tokens[pos])
            edge = node.edges.get(first)
            if edge is None:
                child = _Node(depth=len(tokens))
                node.edges[first] = (tokens[pos:].copy(), child)
                node = child
                pos = len(tokens)
                break
            label, child = edge
            n = int(min(len(label), len(tokens) - pos))
            common = 0
            while common < n and label[common] == tokens[pos + common]:
                common += 1
            if common == len(label):        # full edge consumed, descend
                node, pos = child, pos + common
                continue
            # split the edge at the divergence point
            mid = _Node(depth=pos + common)
            mid.edges[int(label[common])] = (label[common:], child)
            node.edges[first] = (label[:common].copy(), mid)
            node, pos = mid, pos + common
        if node.value is None:
            self._entries.append(node)
            self._size += len(tokens)
        node.value = value
        node.depth = len(tokens)
        node.tick = self._clock
        while self._size > self.capacity and len(self._entries) > 1:
            self._evict_lru(keep=node)

    def _evict_lru(self, keep: Optional[_Node] = None) -> None:
        victims = [e for e in self._entries if e is not keep]
        if not victims:
            return
        v = min(victims, key=lambda e: e.tick)
        self._entries.remove(v)
        self._size -= v.depth
        v.value = None                  # skeleton stays; snapshot released
        self._c_evict.inc()

    def clear(self) -> None:
        """Drop every stored snapshot (e.g. when the owning decode state
        is rebuilt with a different cache geometry — stale snapshots would
        shape-mismatch on restore)."""
        self._root = _Node()
        self._entries = []
        self._size = 0
