"""Job and session bookkeeping for the compression service.

A *job* is one client request (compress a token stream / decompress a
container). The session layer splits jobs into independent per-chunk
work items (``ChunkTask``), hands them to the scheduler, and reassembles
completed chunks — which arrive **out of order** — into the job's final
result. Chunk independence is the format's own guarantee (paper §5.4,
DESIGN.md §2): nothing here needs cross-chunk state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

COMPRESS = "compress"
DECOMPRESS = "decompress"


@dataclass
class ChunkTask:
    """One chunk's worth of work — the scheduler's unit of slot refill.

    Exactly one of ``tokens`` (compress: the chunk's token ids, unpadded)
    or ``stream`` (decompress: the chunk's coded bytes) is set. ``valid``
    is the chunk's true token count (< chunk_size only for a job's final
    chunk)."""
    job: "Job"
    chunk_index: int
    kind: str
    valid: int
    tokens: Optional[np.ndarray] = None
    stream: Optional[bytes] = None

    def complete(self, result) -> None:
        self.job._chunk_done(self.chunk_index, result)

    def fail(self, err: Exception) -> None:
        self.job._fail(err)


@dataclass
class Job:
    """One submitted request, decomposed into ``n_chunks`` ChunkTasks."""
    job_id: int
    kind: str
    priority: int
    n_chunks: int
    n_tokens: int
    # called with the in-order list of per-chunk results once all chunks
    # are done; returns the job's final result (container bytes / tokens)
    assemble: Callable[[list], Any]
    _results: dict = field(default_factory=dict)
    _result: Any = None
    _error: Optional[Exception] = None
    _done: bool = False

    def _chunk_done(self, chunk_index: int, result) -> None:
        if self._done:
            return
        if chunk_index in self._results:
            raise RuntimeError(
                f"job {self.job_id}: chunk {chunk_index} completed twice")
        self._results[chunk_index] = result
        if len(self._results) == self.n_chunks:
            try:
                ordered = [self._results[i] for i in range(self.n_chunks)]
                self._result = self.assemble(ordered)
            except Exception as e:          # surface through the handle
                self._error = e
            self._done = True

    def _fail(self, err: Exception) -> None:
        self._error = err
        self._done = True

    def resolve(self, result) -> None:
        """Complete the whole job immediately (no scheduler involvement —
        e.g. legacy-codec containers decoded through the grouped path)."""
        self._result = result
        self._done = True

    @property
    def done(self) -> bool:
        return self._done


class JobHandle:
    """Client-side future for a submitted job. ``result()`` drives the
    service's scheduler until this job completes (cooperative, single
    process — the service owns the model program)."""

    def __init__(self, job: Job, service):
        self._job = job
        self._service = service

    @property
    def job_id(self) -> int:
        return self._job.job_id

    def done(self) -> bool:
        return self._job.done

    def result(self):
        """Block (drive the scheduler) until the job finishes; returns the
        decompressed tokens or (container bytes, stats), or re-raises the
        job's failure."""
        self._service._run_until(self._job)
        if self._job._error is not None:
            raise self._job._error
        return self._job._result
