"""Job and session bookkeeping for the compression service.

A *job* is one client request (compress a token stream / decompress a
container). The session layer splits jobs into independent per-chunk
work items (``ChunkTask``), hands them to the scheduler, and reassembles
completed chunks — which arrive **out of order** — into the job's final
result. Chunk independence is the format's own guarantee (paper §5.4,
DESIGN.md §2): nothing here needs cross-chunk state.

Telemetry rides along, out-of-band: the scheduler attaches an optional
``obs.ChunkDiagnostics`` to each chunk completion, and
``JobHandle.diagnostics`` assembles them into an ``obs.JobDiagnostics``
(bits/token, cross-entropy, escape rate per chunk) once the job is done.
Diagnostics never enter the container bytes; ``handle.write_sidecar()``
puts them in a ``<path>.diag.json`` file next to it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro import obs

COMPRESS = "compress"
DECOMPRESS = "decompress"


@dataclass
class ChunkTask:
    """One chunk's worth of work — the scheduler's unit of slot refill.

    Exactly one of ``tokens`` (compress: the chunk's token ids, unpadded)
    or ``stream`` (decompress: the chunk's coded bytes) is set. ``valid``
    is the chunk's true token count (< chunk_size only for a job's final
    chunk).

    Routed compress chunks (DESIGN.md §11) carry their realized
    best-fallback stream in ``fallback``: the scheduler compares it
    against the slot encoder's flushed bytes at completion and keeps the
    smaller — the chunk still took a model slot (the probe kept it), but
    the container never pays more than the fallback would.

    Context (v6, DESIGN.md §12): ``ctx`` is the chunk's declared context
    prefix — the scheduler prefills it through the slot's lane before any
    token is coded, and ``recipe`` is the (kind, param) pair the v6
    container records so a decoder can rematerialize the same context.
    ``cacheable`` marks ``ctx`` as a shared prefix worth storing in the
    service's radix prefix cache (carry windows are chunk-unique — caching
    them would only churn the LRU). ``ctx_budget`` is the job-wide
    decode-length budget (the v6 footer's ``ctx_budget``): cache length
    is coding geometry, so every chunk of a job — context-free ones
    included — must run the model program at chunk_size + ctx_budget
    positions, and the scheduler refuses to mix geometries mid-flight.
    ``llm_bits_est`` is the router probe's estimate, fed back to
    ``CodecRouter.observe`` at completion."""
    job: "Job"
    chunk_index: int
    kind: str
    valid: int
    tokens: Optional[np.ndarray] = None
    stream: Optional[bytes] = None
    fallback: Optional[bytes] = None
    fallback_codec: str = ""
    ctx: Optional[np.ndarray] = None
    recipe: tuple = (0, 0)
    cacheable: bool = False
    ctx_budget: int = 0
    llm_bits_est: float = -1.0

    def complete(self, result,
                 diag: Optional[obs.ChunkDiagnostics] = None,
                 codec: Optional[str] = None) -> None:
        self.job._chunk_done(self.chunk_index, result, diag, codec)

    def fail(self, err: Exception) -> None:
        self.job._fail(err)


@dataclass
class Job:
    """One submitted request, decomposed into ``n_chunks`` ChunkTasks."""
    job_id: int
    kind: str
    priority: int
    n_chunks: int
    n_tokens: int
    # called with the in-order list of per-chunk results once all chunks
    # are done; returns the job's final result (container bytes / tokens)
    assemble: Callable[[list], Any]
    codec: str = ""                     # codec label for diagnostics
    registry: Optional[obs.MetricsRegistry] = None
    _results: dict = field(default_factory=dict)
    _diags: dict = field(default_factory=dict)
    # chunk_index -> fallback codec *name* for chunks the router diverted
    # (absent => the container's entropy codec). The compress assemble
    # closure turns these into v5 per-chunk codec tags.
    _codecs: dict = field(default_factory=dict)
    _result: Any = None
    _error: Optional[Exception] = None
    _done: bool = False
    # submit→done wall interval, on the same perf_counter clock the span
    # timeline records with — so a job's PhaseReport window is exact
    t_submit: float = field(default_factory=time.perf_counter)
    t_done: float = 0.0

    def _chunk_done(self, chunk_index: int, result,
                    diag: Optional[obs.ChunkDiagnostics] = None,
                    codec: Optional[str] = None) -> None:
        if self._done:
            return
        if chunk_index in self._results:
            raise RuntimeError(
                f"job {self.job_id}: chunk {chunk_index} completed twice")
        self._results[chunk_index] = result
        if codec:
            self._codecs[chunk_index] = codec
        if diag is not None:
            self._diags[chunk_index] = diag
        if len(self._results) == self.n_chunks:
            try:
                ordered = [self._results[i] for i in range(self.n_chunks)]
                self._result = self.assemble(ordered)
            except Exception as e:          # surface through the handle
                obs.log_exception("service.assemble_failed", e,
                                  job=self.job_id, kind=self.kind)
                self._count_failure()
                self._error = e
            self._done = True
            self.t_done = time.perf_counter()

    def _fail(self, err: Exception) -> None:
        if self._error is None:         # count each job's failure once
            self._count_failure()
        self._error = err
        self._done = True
        self.t_done = time.perf_counter()

    def _count_failure(self) -> None:
        if self.registry is not None:
            self.registry.counter(
                "service.jobs_failed",
                "jobs resolved with an error (await re-raises)").inc()

    def resolve(self, result) -> None:
        """Complete the whole job immediately (no scheduler involvement —
        e.g. legacy-codec containers decoded through the grouped path)."""
        self._result = result
        self._done = True
        self.t_done = time.perf_counter()

    @property
    def done(self) -> bool:
        return self._done


class JobHandle:
    """Client-side future for a submitted job. ``result()`` drives the
    service's scheduler until this job completes (cooperative, single
    process — the service owns the model program)."""

    def __init__(self, job: Job, service):
        self._job = job
        self._service = service

    @property
    def job_id(self) -> int:
        return self._job.job_id

    def done(self) -> bool:
        return self._job.done

    def result(self):
        """Block (drive the scheduler) until the job finishes; returns the
        decompressed tokens or (container bytes, stats), or re-raises the
        job's failure."""
        self._service._run_until(self._job)
        if self._job._error is not None:
            raise self._job._error
        return self._job._result

    @property
    def diagnostics(self) -> obs.JobDiagnostics:
        """The job's per-chunk compression diagnostics, assembled after
        ``result()``. Chunks are in order; empty-at-submit chunks and
        telemetry-disabled runs contribute no entries."""
        job = self._job
        self._service._run_until(job)
        container_bytes = 0
        if job.kind == COMPRESS and isinstance(job._result, tuple):
            container_bytes = len(job._result[0])
        d = obs.JobDiagnostics(
            job_id=job.job_id, kind=job.kind, codec=job.codec,
            n_tokens=job.n_tokens, container_bytes=container_bytes,
            chunks=[job._diags[i] for i in sorted(job._diags)])
        if job.t_done:
            d.wall_s = max(0.0, job.t_done - job.t_submit)
        rep = self.phase_report()
        if rep is not None:
            d.phases = rep.to_dict()
        return d

    def phase_report(self):
        """Per-phase wall-time attribution of this job's submit→done
        interval (``obs.PhaseReport``), from the service's timeline
        recorder — None when the service wasn't constructed with
        ``trace=`` (DESIGN.md §13)."""
        job = self._job
        self._service._run_until(job)
        rec = getattr(self._service, "trace_recorder", None)
        if rec is None or not job.t_done:
            return None
        return obs.PhaseReport.from_events(
            rec.events(), t0=job.t_submit - rec.t_start,
            t1=job.t_done - rec.t_start, dropped=rec.dropped)

    def write_sidecar(self, container_path):
        """Write ``diagnostics`` as JSON next to ``container_path``
        (``<name>.diag.json``); returns the sidecar path."""
        return obs.write_sidecar(container_path, self.diagnostics)
