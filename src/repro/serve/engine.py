"""Serving engine: batched prefill (scoring) + lock-step decode.

This is the inference side of the paper's system. `ModelPredictor`
implements core.compressor.PredictorAdapter over any model-zoo config:

  * score_chunks — one jitted teacher-forced forward over (B, C) chunks
    (prefill-shaped; on the production mesh this is the pjit `score_step`).
  * decode loop — jitted single-token step with a donated cache.

The BOS convention: the model input for chunk tokens x_0..x_{C-1} is
[BOS, x_0, .., x_{C-2}], so logits[t] parameterizes P(x_t | x_<t) with a
fresh context per chunk — exactly the paper's chunked setup (§5.4).

For MoE models both paths run dropless dispatch (see models/moe.py) so
scoring and decoding produce bit-identical distributions — the lossless
requirement.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.models import api as model_api


class ModelPredictor:
    """PredictorAdapter over the model zoo (single-host execution)."""

    def __init__(self, params, cfg: ModelConfig, *, bos_id: int | None = None,
                 extra_batch: dict | None = None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.vocab_size = cfg.vocab_size
        self.bos_id = bos_id if bos_id is not None else cfg.vocab_size - 1
        self.extra_batch = extra_batch or {}
        self.mesh = mesh
        fam_kw = {"dropless": True} if cfg.family == "moe" else {}
        if cfg.family == "moe" and mesh is not None:
            fam_kw["mesh"] = mesh

        # jax.named_scope labels below mirror the host-span names (minus
        # the dots XProf dislikes) so a captured device trace interleaves
        # with the obs.trace host timeline under one vocabulary.
        @jax.jit
        def _score(params, tokens, extra):
            with jax.named_scope("model_score"):
                inp = jnp.concatenate(
                    [jnp.full((tokens.shape[0], 1), self.bos_id,
                              tokens.dtype),
                     tokens[:, :-1]], axis=1)
                batch = {"tokens": inp, **extra}
                logits = model_api.forward(params, cfg, batch, **fam_kw)
                return logits[..., :cfg.vocab_size]

        @jax.jit
        def _decode(params, cache, prev, extra):
            with jax.named_scope("model_decode_step"):
                logits, cache = model_api.decode_step(params, cfg, cache,
                                                      prev, **fam_kw)
                return logits[..., :cfg.vocab_size], cache

        @jax.jit
        def _score_ctx(params, tokens, prefix, extra):
            with jax.named_scope("model_score_prefix"):
                inp = jnp.concatenate(
                    [jnp.full((tokens.shape[0], 1), self.bos_id,
                              tokens.dtype),
                     prefix, tokens[:, :-1]], axis=1)
                batch = {"tokens": inp, **extra}
                logits = model_api.forward(params, cfg, batch, **fam_kw)
                return logits[:, prefix.shape[1]:, :cfg.vocab_size]

        @jax.jit
        def _prefill(params, cache, prefix, extra):
            """Consume [BOS, prefix[:, :-1]] through the decode-step
            program in one dispatch. Each scanned step IS the lock-step
            decoder's own jitted computation (same program, same reduction
            order — the _verify argument), so the resulting cache is
            bit-identical to P sequential decode_step calls. The caller
            then feeds prefix[:, -1] as the first decode input."""
            del extra
            inp = jnp.concatenate(
                [jnp.full((prefix.shape[0], 1), self.bos_id, prefix.dtype),
                 prefix[:, :-1]], axis=1)

            def step(c, tok):
                with jax.named_scope("model_prefill_step"):
                    _, c2 = model_api.decode_step(params, cfg, c, tok,
                                                  **fam_kw)
                    return c2, None

            cache, _ = jax.lax.scan(step, cache, jnp.swapaxes(inp, 0, 1))
            return cache

        @jax.jit
        def _verify(params, cache, seq, extra):
            """Score T = seq.shape[1] positions in ONE dispatch by scanning
            the decode-step program, emitting the post-step cache after
            every input. Because each step IS the lock-step decoder's own
            jitted computation (same program, same reduction order), the
            logits are bit-identical to T sequential decode_step calls —
            the property speculative decompression stands on (DESIGN.md
            §9). Memory: the stacked snapshots cost (T+1)x the cache — the
            price of masked per-lane rollback in one gather."""
            del extra

            def step(c, tok):
                with jax.named_scope("model_verify_step"):
                    lg, c2 = model_api.decode_step(params, cfg, c, tok,
                                                   **fam_kw)
                    return c2, (lg[..., :cfg.vocab_size], c2)

            _, (logits, snaps) = jax.lax.scan(step, cache,
                                              jnp.swapaxes(seq, 0, 1))
            # snapshot 0 = the entering cache (0 inputs consumed), so a
            # rollback index is simply "#inputs this lane keeps"
            snaps = jax.tree_util.tree_map(
                lambda s0, st: jnp.concatenate([s0[None], st], axis=0),
                cache, snaps)
            return jnp.swapaxes(logits, 0, 1), snaps

        @jax.jit
        def _rollback(snaps, acc):
            """Per-lane masked cache restore: lane b resumes from the
            snapshot taken after it consumed acc[b] of the verify inputs
            (reset_slots-style — a runtime gather, no recompilation).
            Cache leaves are (L, B, ...) batch-axis-1 except 'pos' (B,);
            encdec cross-attn conditioning (xk/xv) is constant across
            steps, so any snapshot of it is the value itself."""
            def leaf(path, x):
                name = path[-1].key if hasattr(path[-1], "key") else ""
                if name in ("xk", "xv"):
                    return x[0]
                ba = 1 if name == "pos" else 2     # batch axis in (T+1, ...)
                xm = jnp.moveaxis(x, ba, 1)        # (T+1, B, rest...)
                out = jax.vmap(lambda col, a: col[a],
                               in_axes=(1, 0))(xm, acc)      # (B, rest...)
                return jnp.moveaxis(out, 0, ba - 1)
            return jax.tree_util.tree_map_with_path(leaf, snaps)

        @jax.jit
        def _snapshot(cache, lane):
            """Copy one cache lane out as a standalone snapshot (the radix
            prefix cache's stored value). Leaves are (L, B, ...) batch-
            axis-1 except 'pos' (B,); encdec cross-attn conditioning
            (xk/xv) is per-job, not per-slot context, so it stays whole
            and restore leaves the target's own value in place."""
            def leaf(path, x):
                name = path[-1].key if hasattr(path[-1], "key") else ""
                if name in ("xk", "xv"):
                    return x
                if name == "pos":
                    return x[lane]
                return jnp.take(x, lane, axis=1)
            return jax.tree_util.tree_map_with_path(leaf, cache)

        @jax.jit
        def _restore(cache, snap, mask):
            """Broadcast a single-lane snapshot into every cache lane
            selected by mask (B,) bool — the prefix-cache-hit path: the
            slot resumes from the stored post-prefill state instead of
            re-running prefill. Runtime mask, no recompilation."""
            def leaf(path, x, s):
                name = path[-1].key if hasattr(path[-1], "key") else ""
                if name in ("xk", "xv"):
                    return x
                if name == "pos":
                    return jnp.where(mask, s, x).astype(x.dtype)
                shape = [1] * x.ndim
                shape[1] = mask.shape[0]
                return jnp.where(mask.reshape(shape),
                                 jnp.expand_dims(s, 1), x)
            return jax.tree_util.tree_map_with_path(leaf, cache, snap)

        @jax.jit
        def _reset(cache, mask):
            """Zero the cache lanes selected by mask (B,) bool — per-slot
            fresh context for the continuous-batching scheduler. 'pos'
            lanes return to 0; recurrent state (SSM conv/state) MUST be
            zeroed (it is the context); attention K/V lanes are zeroed
            for hygiene (the per-lane causal mask already hides them);
            encdec cross-attn caches (xk/xv) are per-job conditioning and
            survive the reset."""
            def leaf(path, x):
                name = path[-1].key if hasattr(path[-1], "key") else ""
                if name in ("xk", "xv"):
                    return x
                if name == "pos":
                    return jnp.where(mask, 0, x).astype(x.dtype)
                # every other cache leaf is (L, B, ...) — batch on axis 1
                shape = [1] * x.ndim
                shape[1] = mask.shape[0]
                return jnp.where(mask.reshape(shape), jnp.zeros((), x.dtype),
                                 x)
            return jax.tree_util.tree_map_with_path(leaf, cache)

        self._score = _score
        self._score_ctx = _score_ctx
        self._prefill = _prefill
        self._decode = _decode
        self._verify = _verify
        self._rollback = _rollback
        self._snapshot = _snapshot
        self._restore = _restore
        self._reset = _reset

    # --------------------------------------------------- PredictorAdapter
    def score_chunks(self, tokens: np.ndarray,
                     prefix: np.ndarray | None = None) -> np.ndarray:
        """Teacher-forced logits for (B, C) chunks. With ``prefix``
        (B, P) or (P,), position t is scored given [prefix, x_<t] instead
        of a fresh context — the v6 carried/shared-context scorer."""
        with obs.span("model.score"):
            tokens = jnp.asarray(tokens, jnp.int32)
            if prefix is None:
                return np.asarray(
                    self._score(self.params, tokens, self.extra_batch))
            prefix = jnp.asarray(prefix, jnp.int32)
            if prefix.ndim == 1:
                prefix = jnp.broadcast_to(
                    prefix[None], (tokens.shape[0], prefix.shape[0]))
            return np.asarray(self._score_ctx(self.params, tokens, prefix,
                                              self.extra_batch))

    def begin_decode(self, batch: int, prefix: np.ndarray | None = None):
        """Fresh decode cache for ``batch`` lanes. With ``prefix`` (B, P)
        or (P,), the cache has consumed [BOS, prefix[:, :-1]] in one
        scanned dispatch (bit-identical to sequential decode_step calls);
        the caller feeds prefix[:, -1] as the first decode_step input."""
        max_len = getattr(self, "_decode_max_len", 1024)
        cache = model_api.init_cache(self.cfg, batch, max_len)
        if self.cfg.family == "encdec" and "frames" in self.extra_batch:
            from repro.models.encdec import precompute_cross_kv
            frames = self.extra_batch["frames"]
            if frames.shape[0] != batch:
                frames = jnp.broadcast_to(
                    frames[:1], (batch,) + frames.shape[1:])
            cache["xk"], cache["xv"] = precompute_cross_kv(
                self.params, self.cfg, frames)
        if prefix is not None:
            prefix = jnp.asarray(prefix, jnp.int32)
            if prefix.ndim == 1:
                prefix = jnp.broadcast_to(prefix[None],
                                          (batch, prefix.shape[0]))
            with obs.span("model.prefill"):
                cache = self._prefill(self.params, cache, prefix,
                                      self.extra_batch)
        return cache

    def set_decode_len(self, n: int):
        self._decode_max_len = int(n)

    def decode_step(self, state, prev_tokens: np.ndarray):
        with obs.span("model.decode_step"):
            logits, state = self._decode(self.params, state,
                                         jnp.asarray(prev_tokens, jnp.int32),
                                         self.extra_batch)
            return np.asarray(logits), state

    def verify_steps(self, state, seq: np.ndarray):
        """Speculative-decode verify program: score seq (B, T) — column 0
        is each lane's previous token, columns 1..T-1 its drafted
        continuation — in one jitted dispatch. Returns (logits (B, T, V)
        bit-identical to T lock-step decode_step calls, snapshots) where
        ``snapshots`` is the opaque stacked-cache value ``rollback``
        consumes."""
        with obs.span("model.verify"):
            logits, snaps = self._verify(self.params, state,
                                         jnp.asarray(seq, jnp.int32),
                                         self.extra_batch)
            return np.asarray(logits), snaps

    def rollback(self, snapshots, accepted: np.ndarray):
        """Restore each lane's cache to the state after it consumed
        ``accepted[b]`` verify inputs (0 = the pre-verify cache) — the
        speculative decoder's masked per-lane rewind. One jitted gather."""
        with obs.span("model.rollback"):
            return self._rollback(snapshots,
                                  jnp.asarray(accepted, jnp.int32))

    def snapshot_slot(self, state, lane: int):
        """Copy cache lane ``lane`` out as a standalone snapshot — the
        value a radix prefix cache stores for a prefilled shared prefix.
        One jitted gather; the live cache is untouched."""
        with obs.span("model.snapshot_slot"):
            return self._snapshot(state, jnp.asarray(lane, jnp.int32))

    def restore_slot(self, state, snapshot, mask: np.ndarray):
        """Broadcast ``snapshot`` (from snapshot_slot) into every cache
        lane selected by ``mask`` (B,) bool — the prefix-cache-hit path
        that replaces re-prefilling those lanes. One jitted select."""
        with obs.span("model.restore_slot"):
            return self._restore(state, snapshot, jnp.asarray(mask, bool))

    def reset_slots(self, state, mask: np.ndarray):
        """Reset the cache lanes selected by ``mask`` (B,) bool to a fresh
        context (pos 0, zero recurrent state) without touching the other
        lanes — the slot-refill primitive of the continuous-batching
        scheduler (repro.service). One jitted call, no recompilation:
        the mask is a runtime input."""
        with obs.span("model.reset_slots"):
            return self._reset(state, jnp.asarray(mask, bool))

    # ----------------------------------------------------------- sampling
    def generate(self, n_tokens: int, batch: int = 1, *, temperature=1.0,
                 top_k: int = 0, seed: int = 0, prompt=None,
                 vocab_limit: int = 0):
        """Autoregressive sampling — used to create 'LLM-generated' corpora
        for the paper's experiments. vocab_limit > 0 restricts sampling to
        ids < vocab_limit (e.g. 256 for raw bytes, excluding PAD/BOS)."""
        key = jax.random.PRNGKey(seed)
        plen = 0 if prompt is None else np.asarray(prompt).shape[-1]
        self.set_decode_len(max(n_tokens, 16) + plen)
        cache = self.begin_decode(batch)
        prev = np.full((batch,), self.bos_id, np.int32)
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32)
            if prompt.ndim == 1:  # shared prompt
                prompt = np.tile(prompt, (batch, 1))
            for t in range(prompt.shape[1]):
                _, cache = self.decode_step(cache, prev)
                prev = prompt[:, t]
        out = np.zeros((batch, n_tokens), np.int32)
        for t in range(n_tokens):
            logits, cache = self.decode_step(cache, prev)
            key, sub = jax.random.split(key)
            lg = jnp.asarray(logits) / max(temperature, 1e-4)
            if vocab_limit:
                lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab_limit,
                               lg, -1e30)
            if top_k:
                vals, idx = jax.lax.top_k(lg, top_k)
                choice = jax.random.categorical(sub, vals, axis=-1)
                tok = jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]
            else:
                tok = jax.random.categorical(sub, lg, axis=-1)
            prev = np.asarray(tok, np.int32)
            out[:, t] = prev
        return out
