"""Production serve steps for the compression system.

score_step  — prefill-shaped: tokens (B,S) -> (topk ids (B,S,K),
              quantized pmf (B,S,K+1)). The vocab-sized logits are never
              materialized for the whole sequence: the LM head + softmax +
              top-K + CDF quantization run per position-block (lax.map), so
              peak logits memory is B × s_blk × V.

serve_step  — decode-shaped: (params, cache, prev (B,)) -> (ids (B,K),
              qpmf (B,K+1), cache). One new token against a seq_len cache;
              this is the decompression inner loop and the `decode_*` /
              `long_*` dry-run cells.

Both emit (ids, quantized pmf) — integers for the host arithmetic coder —
rather than logits, which is the TPU/host interface of the system
(DESIGN.md §3).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.cdf import topk_quantized
from repro.models import api as model_api
from repro.models.transformer import lm_logits
from repro.sharding.specs import (batch_pspecs, cache_pspecs, param_pspecs)


class _TracedStep:
    """Host-span wrapper around a jitted step: every dispatch opens an
    ``obs.span`` (model.* — phase attribution, DESIGN.md §13) that also
    mirrors into ``jax.profiler.TraceAnnotation``. The jit surface
    (``.lower()`` for dryrun/HLO analysis, ``.trace`` etc.) passes
    through untouched."""

    __slots__ = ("_fn", "span_name")

    def __init__(self, fn, span_name: str):
        self._fn = fn
        self.span_name = span_name

    def __call__(self, *args, **kw):
        with obs.span(self.span_name):
            return self._fn(*args, **kw)

    def __getattr__(self, attr):
        return getattr(self._fn, attr)


def _tok_batch_axes(mesh, b: int):
    """Batch mesh axes for the topk shard_map — only when divisible."""
    from repro.launch.mesh import batch_axes
    ba = batch_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    return ba if ba and b % n == 0 else ()


def _fam_kw(cfg: ModelConfig, mesh):
    kw = {}
    if cfg.family == "moe":
        kw["dropless"] = True
        if mesh is not None and "model" in mesh.axis_names and \
                mesh.shape["model"] > 1:
            kw["mesh"] = mesh
    return kw


def make_score_step(cfg: ModelConfig, mesh=None, *, topk: int = 64,
                    precision: int = 16, attn_impl: str = "masked",
                    s_block: int = 2048, global_batch: int = 1,
                    q_chunk: int = 512, sharded_topk: bool = True):
    """sharded_topk=True uses the hierarchical shard_map top-K
    (§Perf iteration I4): plain lax.top_k over vocab-sharded logits makes
    XLA all-gather full fp32 logits — measured 600+ GiB on prefill_32k."""
    fam_kw = _fam_kw(cfg, mesh)
    if cfg.family == "moe":
        fam_kw["dispatch_group"] = 2048
    use_sharded = (sharded_topk and mesh is not None
                   and "model" in mesh.axis_names
                   and cfg.padded_vocab % mesh.shape["model"] == 0)

    def score_step(params, batch):
        from repro.models.layers import mesh_context
        layout = "serve" if cfg.family != "moe" else "train"
        with mesh_context(mesh, layout=layout):
            return _score_body(params, batch)

    def _score_body(params, batch):
        hidden = model_api.forward(params, cfg, batch, attn_impl=attn_impl,
                                   q_chunk=q_chunk, return_hidden=True,
                                   **fam_kw)
        B, S, D = hidden.shape
        sb = min(s_block, S)
        pad = (-S) % sb
        if pad:  # e.g. VLM: text positions = seq_len - n_img_tokens
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        Sp = hidden.shape[1]
        blocks = jnp.moveaxis(hidden.reshape(B, Sp // sb, sb, D), 1, 0)

        def blk(h):
            logits = lm_logits(cfg, params, h)
            if use_sharded:
                from repro.core.cdf import topk_quantized_sharded
                return topk_quantized_sharded(
                    logits, topk, precision, mesh,
                    batch_axes=_tok_batch_axes(mesh, logits.shape[0]))
            return topk_quantized(logits, topk, precision)

        ids, qpmf = jax.lax.map(blk, blocks)
        ids = jnp.moveaxis(ids, 0, 1).reshape(B, Sp, topk)[:, :S]
        qpmf = jnp.moveaxis(qpmf, 0, 1).reshape(B, Sp, topk + 1)[:, :S]
        return ids, qpmf

    if mesh is None:
        return _TracedStep(jax.jit(score_step), "model.score_step")
    bspecs = batch_pspecs(cfg, mesh, global_batch=global_batch)
    sh = lambda s: NamedSharding(mesh, s)
    score_layout = "serve" if cfg.family != "moe" else "train"
    pspecs = jax.tree_util.tree_map(
        sh, param_pspecs(cfg, mesh, layout=score_layout))
    out_b = bspecs["tokens"][0]
    return _TracedStep(jax.jit(
        score_step,
        in_shardings=(pspecs, {k: sh(v) for k, v in bspecs.items()}),
        out_shardings=(sh(P(out_b, None, None)), sh(P(out_b, None, None))),
    ), "model.score_step")


def make_prefill_step(cfg: ModelConfig, mesh=None, *, batch: int,
                      donate: bool = True):
    """Prefix prefill for the carried/shared-context decoder (v6):
    (params, cache, prefix (B, P)) -> cache that has consumed
    [BOS, prefix[:, :-1]] — the caller feeds prefix[:, -1] as the first
    serve_step input. The scan body IS the decode-step program (same
    reduction order), so the cache is bit-identical to P sequential
    serve_step calls — the lossless requirement for context reuse. One
    dispatch per prefix length; the radix prefix cache in the service
    layer amortizes it across jobs sharing the prefix."""
    fam_kw = _fam_kw(cfg, mesh)

    def prefill_step(params, cache, prefix):
        from repro.models.layers import mesh_context
        with mesh_context(mesh, layout="serve"):
            inp = jnp.concatenate(
                [jnp.full((prefix.shape[0], 1),
                          cfg.vocab_size - 1, prefix.dtype),
                 prefix[:, :-1]], axis=1)

            def step(c, tok):
                _, c2 = model_api.decode_step(params, cfg, c, tok, **fam_kw)
                return c2, None

            cache, _ = jax.lax.scan(step, cache, jnp.swapaxes(inp, 0, 1))
            return cache

    if mesh is None:
        return _TracedStep(
            jax.jit(prefill_step, donate_argnums=(1,) if donate else ()),
            "model.prefill_step")
    sh = lambda s: NamedSharding(mesh, s)
    pspecs = jax.tree_util.tree_map(
        sh, param_pspecs(cfg, mesh, layout="serve"))
    cspecs = jax.tree_util.tree_map(sh, cache_pspecs(cfg, mesh, batch=batch))
    bspec = batch_pspecs(cfg, mesh, global_batch=batch)["tokens"][0]
    return _TracedStep(jax.jit(
        prefill_step,
        in_shardings=(pspecs, cspecs, sh(P(bspec, None))),
        out_shardings=cspecs,
        donate_argnums=(1,) if donate else (),
    ), "model.prefill_step")


def make_serve_step(cfg: ModelConfig, mesh=None, *, batch: int,
                    topk: int = 64, precision: int = 16,
                    donate: bool = True, sharded_topk: bool = True):
    fam_kw = _fam_kw(cfg, mesh)
    use_sharded = (sharded_topk and mesh is not None
                   and "model" in mesh.axis_names
                   and cfg.padded_vocab % mesh.shape["model"] == 0)

    def serve_step(params, cache, prev):
        from repro.models.layers import mesh_context
        with mesh_context(mesh, layout="serve"):
            logits, cache = model_api.decode_step(params, cfg, cache, prev,
                                                  **fam_kw)
            if use_sharded:
                from repro.core.cdf import topk_quantized_sharded
                ids, qpmf = topk_quantized_sharded(
                    logits, topk, precision, mesh,
                    batch_axes=_tok_batch_axes(mesh, logits.shape[0]))
            else:
                ids, qpmf = topk_quantized(logits, topk, precision)
            return ids, qpmf, cache

    if mesh is None:
        return _TracedStep(
            jax.jit(serve_step, donate_argnums=(1,) if donate else ()),
            "model.serve_step")
    sh = lambda s: NamedSharding(mesh, s)
    pspecs = jax.tree_util.tree_map(
        sh, param_pspecs(cfg, mesh, layout="serve"))
    cspecs = jax.tree_util.tree_map(sh, cache_pspecs(cfg, mesh, batch=batch))
    bspec = batch_pspecs(cfg, mesh, global_batch=batch)["tokens"][0]
    return _TracedStep(jax.jit(
        serve_step,
        in_shardings=(pspecs, cspecs, sh(P(bspec))),
        out_shardings=(sh(P(bspec, None)), sh(P(bspec, None)), cspecs),
        donate_argnums=(1,) if donate else (),
    ), "model.serve_step")
