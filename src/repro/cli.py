"""``llmc`` — command-line front end for the LLM compression system.

    llmc compress   IN OUT [--codec rans|ac] [--chunk N] [--topk K]
                           [--slots B] [--predictor NAME] [--v3]
                           [--route auto|llm|zstd|lzma|raw] [--sidecar]
                           [--context-window W] [--shared-prefix FILE]
                           [--trace OUT.json]
    llmc decompress IN OUT [--predictor NAME] [--sidecar]
    llmc range      IN OUT --chunks LO:HI [--predictor NAME]
    llmc info       IN
    llmc stats      [--tokens N] [--format json|prom|text]
                    [--predictor NAME]

``compress``/``decompress`` route through the continuous-batching
service (repro.service) and write/read v4 seekable containers by
default; ``--route auto`` turns on adaptive per-chunk codec routing
(DESIGN.md §11) and writes a v5 mixed-codec container whose index
records each chunk's codec tag — decode follows the recorded tags, it
never guesses. ``--context-window``/``--shared-prefix`` write a v6
container whose chunks are coded under declared context recipes
(DESIGN.md §12) — the ratio lever of the paper's long-context regime.
``range`` random-access-decodes a chunk interval from a v4+ archive
(mixed-codec v5 and carried-context v6 included); ``info`` prints
header + index (for v5 the per-chunk codec tags, for v6 also the
context recipes and shared-prefix dictionary) without loading any
model. All-fallback archives decompress and range-decode model-free:
no predictor is ever constructed.

``stats`` (DESIGN.md §10) runs a small round-trip workload through a
``CompressionService`` and prints its telemetry snapshot — occupancy,
bits/token histogram, escape counts, job counters — as JSON (default),
Prometheus text exposition (``--format prom``), or a human summary
(``--format text``). ``--sidecar`` on compress/decompress writes the
job's per-chunk diagnostics next to the container as
``<container>.diag.json``.

Predictors come from the benchmark prep cache (trained byte-level LMs,
benchmarks/prep.py), so the model-dependent commands must run from a
repo checkout; ``info`` works anywhere. Registered as a console script
in pyproject.toml (``pip install -e . && llmc info archive.llmc``).
"""
from __future__ import annotations

import argparse
import sys
import time


def _predictor(name: str):
    sys.path[:0] = ["src", "."]
    try:
        from benchmarks.prep import predictor
    except ImportError as e:
        raise SystemExit(
            f"llmc: cannot load predictor {name!r} ({e}); the model-"
            f"dependent commands need a repo checkout with benchmarks/"
        )
    return predictor(name)


def _cmd_info(args) -> int:
    from repro.core import read_header, read_index
    from repro.core.compressor import VERSION_V4, VERSION_V5, VERSION_V6
    blob = open(args.input, "rb").read()
    info = read_header(blob)
    print(f"{args.input}: LLMC v{info.version} codec={info.codec_name} "
          f"chunk_size={info.chunk_size} n_tokens={info.n_tokens} "
          f"n_chunks={info.n_chunks} vocab={info.vocab} topk={info.topk} "
          f"precision={info.precision} ({len(blob)} bytes)")
    if info.version >= VERSION_V4:
        info = read_index(blob, info)
        tagged = info.version >= VERSION_V5
        ctxed = info.version >= VERSION_V6
        cols = "offset, bytes, tokens, xxh64" + (", codec" if tagged else "") \
            + (", context" if ctxed else "")
        budget = f" ctx_budget={info.ctx_budget};" if ctxed else ""
        print(f"index: footer verified; encode_batch={info.encode_batch};"
              f"{budget} per-chunk ({cols}):")
        for i, e in enumerate(info.entries):
            tag = f"  {e.codec_name}" if tagged else ""
            rec = f"  {e.recipe_name}" if ctxed else ""
            print(f"  chunk {i:4d}: {e.offset:8d} {e.length:6d} "
                  f"{e.n_tokens:5d} {e.checksum:016x}{tag}{rec}")
        if tagged:
            counts = {}
            for e in info.entries:
                counts[e.codec_name] = counts.get(e.codec_name, 0) + 1
            mix = "  ".join(f"{n}×{c}" for c, n in sorted(counts.items()))
            print(f"codecs: {mix}" if mix else "codecs: (empty)")
        if ctxed:
            rcounts = {}
            for e in info.entries:
                name = e.recipe_name.split("(")[0].split("[")[0]
                rcounts[name] = rcounts.get(name, 0) + 1
            mix = "  ".join(f"{n}×{r}" for r, n in sorted(rcounts.items()))
            print(f"contexts: {mix}" if mix else "contexts: (empty)")
            if info.shared_prefixes:
                for j, (name, toks) in enumerate(info.shared_prefixes):
                    print(f"shared prefix [{j}]: {name!r} "
                          f"({len(toks)} tokens)")
            else:
                print("shared prefixes: none")
    else:
        print("index: none (v2/v3 container — no random access)")
    return 0


def _service(args, pred):
    from repro.core.cdf import DEFAULT_PRECISION
    from repro.service import CompressionService
    return CompressionService(pred, slots=args.slots, chunk_size=args.chunk,
                              topk=args.topk,
                              precision=getattr(args, "precision",
                                                DEFAULT_PRECISION),
                              route=getattr(args, "route", "llm"),
                              trace=getattr(args, "trace", None) or None)


def _print_phases(rep) -> None:
    """One-line per-job phase breakdown (DESIGN.md §13)."""
    if rep is None:
        return
    parts = "  ".join(f"{k}={v * 1e3:.1f}ms"
                      for k, v in sorted(rep.phases.items()) if v > 0)
    print(f"phases ({rep.total_s * 1e3:.0f}ms wall, coverage "
          f"{rep.coverage:.0%}): {parts}")


def _cmd_compress(args) -> int:
    from repro.core import LLMCompressor
    from repro.data.tokenizer import encode
    args.slots = args.slots or 16
    pred = _predictor(args.predictor)
    data = open(args.input, "rb").read()
    toks = encode(data)
    sp = None
    if args.shared_prefix:
        sp = encode(open(args.shared_prefix, "rb").read())
    t0 = time.time()
    handle = None
    svc = None
    rec = None
    if args.codec == "ac" or args.v3:
        if args.route != "llm":
            # routing needs v5 codec tags; v3 can't carry them and the
            # ac estimator path never routes — fail with a clear message
            raise SystemExit("llmc: --route requires the default service "
                             "path (rans codec, no --v3)")
        if args.context_window or sp is not None:
            raise SystemExit("llmc: context options need the default "
                             "service path (rans codec, no --v3) — they "
                             "write a v6 container")
        # legacy codec / wire-minimal container: grouped path
        from repro import obs
        if args.trace:
            rec = obs.TimelineRecorder()
            obs.timeline.install(rec)
        comp = LLMCompressor(pred, chunk_size=args.chunk, topk=args.topk,
                             decode_batch=args.slots, codec=args.codec,
                             container_version=3 if args.v3 else 4)
        try:
            blob, stats = comp.compress(toks)
        finally:
            if rec is not None and obs.timeline.active() is rec:
                obs.timeline.uninstall()
    else:
        svc = _service(args, pred)
        handle = svc.submit_compress(
            toks, shared_prefix=sp, context_window=args.context_window)
        blob, stats = handle.result()
    open(args.output, "wb").write(blob)
    if args.trace:
        from repro import obs
        if svc is not None:
            rep = handle.phase_report()
            path = svc.write_timeline()
            svc.close()
        else:
            rec.save(args.trace)
            path = args.trace
            rep = obs.PhaseReport.from_recorder(rec)
        print(f"timeline -> {path} (Chrome-trace JSON; load in "
              f"chrome://tracing or ui.perfetto.dev)")
        _print_phases(rep)
    if args.sidecar:
        from repro import obs
        if handle is not None:
            path = handle.write_sidecar(args.output)
        else:   # grouped path: per-chunk diagnostics ride on stats.chunks
            path = obs.write_sidecar(args.output, obs.JobDiagnostics(
                kind="compress", codec=args.codec, n_tokens=stats.n_tokens,
                container_bytes=len(blob), chunks=stats.chunks))
        print(f"diagnostics -> {path}")
    print(f"{len(data)}B -> {len(blob)}B "
          f"({len(data) / max(1, len(blob)):.2f}x, "
          f"{stats.n_tokens} tokens, {time.time() - t0:.1f}s)")
    return 0


def _cmd_decompress(args) -> int:
    from repro.core import (LLMCompressor, container_is_model_free,
                            decompress_model_free, read_header)
    from repro.data.tokenizer import decode
    blob = open(args.input, "rb").read()
    info = read_header(blob)        # fail fast + learn the geometry
    if info.version >= 4:
        from repro.core import read_index
        info = read_index(blob, info)
        if container_is_model_free(info):
            # every chunk is fallback-coded: decode without constructing
            # a predictor (no model load, no prefix cache, no service)
            t0 = time.time()
            toks = decompress_model_free(blob)
            open(args.output, "wb").write(decode(toks))
            print(f"{len(blob)}B -> decoded {toks.size} tokens "
                  f"(model-free, {time.time() - t0:.1f}s)")
            return 0
    pred = _predictor(args.predictor)
    args.chunk, args.topk = info.chunk_size, info.topk
    args.precision = info.precision
    args.slots = args.slots or info.encode_batch or 16
    t0 = time.time()
    handle = None
    if info.codec_name == "ac":
        # legacy codec: the service is rANS-only (and its rANS precision
        # cap would reject legal high-precision AC archives) — grouped
        # decode directly, same result
        comp = LLMCompressor(pred, chunk_size=args.chunk, topk=args.topk,
                             precision=args.precision, codec="ac",
                             decode_batch=args.slots)
        toks = comp.decompress(blob)
    elif args.draft:
        # speculative grouped decode: draft/verify/accept (DESIGN.md §9),
        # identical tokens, fewer model dispatches on predictable text
        comp = LLMCompressor(pred, chunk_size=args.chunk, topk=args.topk,
                             precision=args.precision,
                             decode_batch=args.slots, draft_k=args.draft)
        toks = comp.decompress(blob)
    else:
        handle = _service(args, pred).submit_decompress(blob)
        toks = handle.result()
    if args.sidecar:
        if handle is not None:
            print(f"diagnostics -> {handle.write_sidecar(args.input)}")
        else:
            print("llmc: note: --sidecar needs the service decode path "
                  "(rans codec, no --draft); skipped", file=sys.stderr)
    open(args.output, "wb").write(decode(toks))
    print(f"{len(blob)}B -> decoded {toks.size} tokens "
          f"({time.time() - t0:.1f}s)")
    return 0


def _cmd_range(args) -> int:
    from repro.core import (ContainerError, LLMCompressor,
                            decompress_range_model_free, read_index)
    from repro.data.tokenizer import decode
    blob = open(args.input, "rb").read()
    info = read_index(blob)
    try:
        lo, hi = (int(x) for x in args.chunks.split(":"))
    except ValueError:
        raise SystemExit(f"llmc: --chunks expects LO:HI integers, "
                         f"got {args.chunks!r}")
    if 0 <= lo < hi <= len(info.entries) \
            and all(not e.is_llm for e in info.entries[lo:hi]):
        # every requested chunk is fallback-coded (recipes are none by
        # format law), so the range decodes without a model
        t0 = time.time()
        try:
            toks = decompress_range_model_free(blob, lo, hi)
        except ContainerError as e:
            raise SystemExit(f"llmc: {e}")
        open(args.output, "wb").write(decode(toks))
        print(f"chunks [{lo}, {hi}) -> {toks.size} tokens "
              f"(model-free, {time.time() - t0:.1f}s)")
        return 0
    if args.slots and info.encode_batch and args.slots != info.encode_batch:
        print(f"llmc: note: range decode runs at the container's recorded "
              f"encode batch ({info.encode_batch}); --slots {args.slots} "
              f"ignored", file=sys.stderr)
    pred = _predictor(args.predictor)
    comp = LLMCompressor(pred, chunk_size=info.chunk_size, topk=info.topk,
                         precision=info.precision,
                         decode_batch=args.slots or info.encode_batch or 16)
    t0 = time.time()
    try:
        toks = comp.decompress_range(blob, lo, hi)
    except ContainerError as e:
        # empty/reversed/out-of-bounds ranges and corrupt containers all
        # arrive here with a precise message — never a bare IndexError
        raise SystemExit(f"llmc: {e}")
    open(args.output, "wb").write(decode(toks))
    print(f"chunks [{lo}, {hi}) -> {toks.size} tokens "
          f"({time.time() - t0:.1f}s)")
    return 0


def _cmd_stats(args) -> int:
    """Exercise a CompressionService on a small round-trip workload and
    print its telemetry snapshot (DESIGN.md §10)."""
    import numpy as np
    pred = _predictor(args.predictor)
    args.chunk = args.chunk or 64
    args.topk = args.topk or 0
    args.slots = args.slots or 8
    svc = _service(args, pred)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, max(2, pred.vocab_size - 1), args.tokens,
                        dtype=np.int32)
    blob, _ = svc.submit_compress(toks).result()
    rt = svc.submit_decompress(blob).result()
    if not np.array_equal(rt, toks):
        raise SystemExit("llmc: stats round-trip mismatch (BUG)")
    snap = svc.snapshot()
    if args.format == "prom":
        sys.stdout.write(svc.registry.to_prometheus())
    elif args.format == "text":
        sched = snap["scheduler"]
        bpt = snap["chunk_bits_per_token"] or {}
        print(f"workload: {args.tokens} tokens round-tripped "
              f"({len(blob)} container bytes)")
        print(f"occupancy {snap['occupancy']:.3f}  model_steps "
              f"{sched['model_steps']}  chunks {sched['chunks_completed']}"
              f"  refills {sched['refills']}  failures "
              f"{sched['chunk_failures']}")
        if bpt:
            print(f"bits/token: mean {bpt['mean']:.2f}  p50 {bpt['p50']:g}"
                  f"  p95 {bpt['p95']:g}  p99 {bpt['p99']:g}  "
                  f"({bpt['count']} chunks)")
        acc = snap["draft_acceptance"]
        print(f"draft acceptance: "
              f"{'n/a (no speculative decode)' if acc is None else acc}")
        print(f"jobs: {snap['jobs']}")
        phases = {k: v for k, v in (snap.get("phases") or {}).items()
                  if v > 0}
        if phases:
            print("phase seconds: " + "  ".join(
                f"{k}={v:.3f}" for k, v in sorted(phases.items())))
    else:
        import json
        print(json.dumps(snap, indent=1, default=str))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="llmc", description="LLM next-token-prediction compressor")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, model=True):
        p.add_argument("input")
        if p.prog.split()[-1] != "info":
            p.add_argument("output")
        if model:
            p.add_argument("--predictor", default="pred-base")
            # default: 16 for compress; for decompress/range, the v4
            # container's recorded encode batch (bit-exactness needs the
            # decoder to run the model program at the encoder's batch)
            p.add_argument("--slots", type=int, default=None)

    p = sub.add_parser("compress", help="file -> .llmc container")
    common(p)
    p.add_argument("--codec", choices=("rans", "ac"), default="rans")
    p.add_argument("--chunk", type=int, default=128)
    p.add_argument("--topk", type=int, default=48)
    p.add_argument("--v3", action="store_true",
                   help="write the wire-minimal v3 container "
                        "(no index/checksums)")
    p.add_argument("--route", choices=("llm", "auto", "zstd", "lzma", "raw"),
                   default="llm",
                   help="per-chunk codec routing (DESIGN.md §11): 'auto' "
                        "probes model fit per chunk and writes a v5 "
                        "mixed-codec container; a codec name forces that "
                        "fallback for every chunk; 'llm' (default) keeps "
                        "the pure entropy-coded v4 path")
    p.add_argument("--sidecar", action="store_true",
                   help="write per-chunk diagnostics (bits/token, "
                        "escapes) to OUT.diag.json")
    p.add_argument("--context-window", type=int, default=0, metavar="W",
                   help="carry each chunk's W-token tail into the next "
                        "chunk of its stripe (writes a v6 container with "
                        "per-chunk context recipes, DESIGN.md §12)")
    p.add_argument("--shared-prefix", default="", metavar="FILE",
                   help="condition stripe-head chunks on FILE's tokens "
                        "as a named shared prefix (v6; jobs sharing the "
                        "prefix reuse one prefilled KV state)")
    p.add_argument("--trace", default="", metavar="OUT.json",
                   help="record a span timeline of the run and export it "
                        "as Chrome-trace JSON (chrome://tracing / "
                        "ui.perfetto.dev), plus a per-job phase cost "
                        "breakdown (DESIGN.md §13)")
    p.set_defaults(fn=_cmd_compress)

    p = sub.add_parser("decompress", help=".llmc container -> file")
    common(p)
    p.add_argument("--draft", type=int, default=0, metavar="K",
                   help="speculative decode: self-draft K tokens per "
                        "verify forward (0 = lock-step)")
    p.add_argument("--sidecar", action="store_true",
                   help="write per-chunk diagnostics to IN.diag.json")
    p.set_defaults(fn=_cmd_decompress)

    p = sub.add_parser("range", help="random-access decode (v4+ seekable "
                                     "containers, mixed-codec v5 included)")
    common(p)
    p.add_argument("--chunks", required=True, metavar="LO:HI")
    p.set_defaults(fn=_cmd_range)

    p = sub.add_parser("info", help="print header + index (no model)")
    common(p, model=False)
    p.set_defaults(fn=_cmd_info)

    p = sub.add_parser(
        "stats", help="run a sample workload, print service telemetry")
    p.add_argument("--predictor", default="pred-base")
    p.add_argument("--tokens", type=int, default=2048,
                   help="workload size in tokens (default 2048)")
    p.add_argument("--chunk", type=int, default=64)
    p.add_argument("--topk", type=int, default=0)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--format", choices=("json", "prom", "text"),
                   default="json",
                   help="snapshot format: structured JSON (default), "
                        "Prometheus text exposition, or human summary")
    p.set_defaults(fn=_cmd_stats)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
