"""zamba2-7b [hybrid] — 81L d_model=3584 32H (MHA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified]. 81 SSD layers; ONE shared attention+MLP
block applied after every 13 SSM layers (6 applications; 3 trailing SSM
layers). d_head=112 (3584/32) — not MXU-128 aligned; in roofline notes."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14336, vocab_size=32000, rope_theta=1e4,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    hybrid_ssm_per_block=13,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
