"""whisper-large-v3 [audio] — 32L(+32L enc) d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866 — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified].
20 heads pad to 32 for TP=16; decoder self-attn uses RoPE (adaptation from
learned positions so the assigned 32k decode shape is well-defined)."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
    d_ff=5120, vocab_size=51866, rope_theta=1e4,
    n_enc_layers=32, max_source_len=1500,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
