"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
Backbone only; the anyres patch frontend is a stub — input_specs() provides
precomputed patch embeddings (n_img_tokens per image)."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab_size=64000, rope_theta=1e6,
    n_img_tokens=576,            # one anyres base tile (24x24 patches)
)
SMOKE_CONFIG = tiny_variant(CONFIG)
