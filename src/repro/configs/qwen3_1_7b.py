"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144, vocab_size=151936, qk_norm=True, rope_theta=1e6,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
