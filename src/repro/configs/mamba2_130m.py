"""mamba2-130m [ssm] — 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=64,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    tie_embeddings=True,   # mamba2-130m ties embed/lm_head
)
SMOKE_CONFIG = tiny_variant(CONFIG)
