"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA (window 4096)
[arXiv:2401.16818; unverified]. d_head=120 (3840/32) — not MXU-128
aligned; recorded in the roofline notes."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
    d_ff=10240, vocab_size=32000, sliding_window=4096, rope_theta=1e4,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
