"""Model/run configuration.

One `ModelConfig` covers all six assigned families (dense / moe / ssm /
hybrid / encdec / vlm); family-specific fields are zero/None when unused.
`ShapeConfig` describes the four assigned input-shape cells.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # default d_model // n_heads
    # attention details
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA window (h2o-danube)
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    # hybrid (zamba2): groups of `hybrid_ssm_per_block` ssm layers, each
    # followed by ONE application of a single shared attention block.
    hybrid_ssm_per_block: int = 0
    # encdec (whisper): n_layers is the decoder depth; encoder depth below.
    n_enc_layers: int = 0
    max_source_len: int = 1500
    # vlm (llava-next): anyres tiling stub — patch embeddings are inputs.
    n_img_tokens: int = 0
    # numerics / padding for the production mesh (TP degree 16)
    dtype: str = "bfloat16"
    kv_cache_dtype: Optional[str] = None   # None => model dtype; "int8"
    head_pad_multiple: int = 16
    vocab_pad_multiple: int = 256
    # runtime
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False   # ref (XLA) path by default; kernels validated separately
    norm_eps: float = 1e-6

    # ------------------------------------------------------------ derived
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        m = self.head_pad_multiple
        return math.ceil(self.n_heads / m) * m if self.n_heads % m else self.n_heads

    @property
    def padded_kv_heads(self) -> int:
        """KV heads after padding. GQA group size must stay integral: if the
        padded Q heads are not a multiple of the (possibly padded) KV count,
        pad KV up to the largest divisor pattern (MHA pads to padded_heads)."""
        if self.n_kv_heads == self.n_heads:       # MHA — pad together
            return self.padded_heads
        kv = self.n_kv_heads
        while self.padded_heads % kv:
            kv += 1
        return kv

    @property
    def q_per_kv(self) -> int:
        return self.padded_heads // self.padded_kv_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return math.ceil(self.vocab_size / m) * m

    # ssm derived
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def is_autoregressive(self) -> bool:
        return True  # every assigned family has an autoregressive decoder

    def n_params(self) -> int:
        """Analytic parameter count (logical, unpadded) for MODEL_FLOPS."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, K = self.head_dim, self.n_heads, self.n_kv_heads
        attn = D * hd * (H + 2 * K) + H * hd * D
        mlp = 3 * D * F
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * self.d_ff + D * self.n_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, N, Hs = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            ssm = D * (2 * di + 2 * N + Hs) + di * D + self.ssm_conv * (di + 2 * N) + 2 * Hs
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = {"dense": attn + mlp, "moe": attn + mlp, "vlm": attn + mlp,
                     "ssm": ssm, "encdec": attn + mlp,
                     "hybrid": ssm}[self.family]
        total = self.n_layers * per_layer + emb
        if self.family == "hybrid":
            n_blocks = self.n_layers // max(1, self.hybrid_ssm_per_block)
            total += attn + mlp  # one shared attention+mlp block
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp) + self.n_layers * attn  # cross-attn
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        D, F = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * D * F
        active_moe = self.top_k * 3 * D * F
        return self.n_params() - self.n_layers * (dense_moe - active_moe)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def tiny_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_head=16, d_ff=128, vocab_size=257,
        head_pad_multiple=1, vocab_pad_multiple=1,
        dtype="float32", remat=False,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff=32)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16, ssm_expand=2)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, hybrid_ssm_per_block=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, max_source_len=32)
    if cfg.family == "vlm":
        kw.update(n_img_tokens=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    kw.update(overrides)
    return cfg.with_(**kw)
