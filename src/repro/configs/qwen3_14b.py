"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
40 q-heads pad to 48 for TP=16 (DESIGN.md §4)."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1e6,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
