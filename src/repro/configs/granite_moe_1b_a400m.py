"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
(per expert) vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=512, vocab_size=49155, rope_theta=1e4,
    n_experts=32, top_k=8,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
