"""Small byte-level predictor LMs for the MEASURED compression experiments
(the paper's 1B-14B Llama/Qwen models scaled to this CPU container; the
architecture family is the same llama-style dense decoder).

Vocab 258 = 256 bytes + BOS + PAD. Three sizes give the paper's model-size
sweep (§5.5).
"""
from repro.configs.base import ModelConfig

def _mk(name, L, D, H, F):
    return ModelConfig(
        name=name, family="dense", n_layers=L, d_model=D, n_heads=H,
        n_kv_heads=max(1, H // 2), d_head=D // H, d_ff=F, vocab_size=258,
        head_pad_multiple=1, vocab_pad_multiple=1, dtype="float32",
        remat=False, rope_theta=1e4,
    )

PRED_TINY = _mk("pred-tiny", 2, 64, 4, 192)       # ~0.1M
PRED_SMALL = _mk("pred-small", 4, 128, 8, 384)    # ~0.9M
PRED_BASE = _mk("pred-base", 6, 256, 8, 768)      # ~5M
PRED_LARGE = _mk("pred-large", 8, 384, 12, 1152)  # ~16M
