"""Architecture registry: the 10 assigned architectures (+ the small
predictor configs used for measured compression experiments).

Every entry matches the assignment block verbatim; see each <id>.py module
for the single-config file and DESIGN.md §5 for applicability notes.
"""
from __future__ import annotations

from importlib import import_module

ARCH_IDS = [
    "llava_next_34b",
    "mamba2_130m",
    "qwen3_moe_235b_a22b",
    "granite_moe_1b_a400m",
    "qwen3_14b",
    "deepseek_7b",
    "h2o_danube_3_4b",
    "qwen3_1_7b",
    "zamba2_7b",
    "whisper_large_v3",
]

# assigned ids use dashes
def canon(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str):
    mod = import_module(f"repro.configs.{canon(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str):
    mod = import_module(f"repro.configs.{canon(arch_id)}")
    return mod.SMOKE_CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
