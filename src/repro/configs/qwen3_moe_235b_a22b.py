"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
qk_norm per qwen3; d_head=128 (independent of d_model/n_heads)."""
from repro.configs.base import ModelConfig, tiny_variant

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
)
SMOKE_CONFIG = tiny_variant(CONFIG)
