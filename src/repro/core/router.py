"""Adaptive per-chunk codec routing (DESIGN.md §11).

The paper's 20× compression holds only on text the predictor itself
could have generated; on human or cross-model text the LLM path degrades
sharply — below gzip in the adversarial cases (Llamazip's training-set
detection and "The Statistical Signature of LLMs" are exactly this
signal, PAPERS.md). The router closes that loop: per chunk, estimate
model fit from early cross-entropy and fall back to a dictionary codec
(zstd/lzma) or raw store when the LLM path would lose, so routed
compression never loses to the best fallback on any input. The chosen
codec is recorded per chunk in the v5 container's index footer
(core/compressor.py), so decode never guesses — the recorded tag is the
routing decision, bit-exact by construction.

Division of labour:

* this module owns the *policy*: the probe heuristic, fallback-codec
  selection, and the token<->byte packing fallback streams use. It deals
  in codec **names**; container codec *ids* belong to the container
  layer (``compressor.CODEC_NAMES``), which keeps this module free of
  wire-format knowledge (and free of import cycles).
* ``core/baselines.py`` owns the fallback byte codecs themselves
  (``compress_bytes``/``decompress_bytes``).
* ``core/compressor.py`` and ``service/`` own the mechanism: where the
  probe runs, which chunks enter the model batch, and the final
  realized-size comparison after an LLM encode.

Routing is encode-side only and advisory until written: a sloppy probe
can cost ratio, never correctness — the decoder reconstructs each chunk
with the codec named by its tag, and the entropy-coded chunks still
carry the exact-CDF guarantee of the LLM path.

Fallback stream layout (the per-chunk bytes a fallback codec tag
selects):  ``u8 token_width (1|2|4) || codec payload``, where the
payload is ``compress_bytes(codec, tokens packed little-endian at
token_width bytes each)``. The width is chosen per chunk from the
chunk's max token id, so byte-tokenized data (vocab 258, tokens < 256
in practice) packs at 1 byte/token and raw store of random bytes costs
~8 bits/token, not 16.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .baselines import available_byte_codecs, compress_bytes, decompress_bytes

#: routes that run the LLM entropy path for every chunk
ROUTE_LLM = "llm"
#: probe-and-compare adaptive routing
ROUTE_AUTO = "auto"


def pack_tokens(tokens: np.ndarray) -> tuple[int, bytes]:
    """Pack a token vector into little-endian fixed-width bytes; returns
    ``(width, packed)``. Width is the smallest of 1/2/4 bytes that holds
    the chunk's max token id."""
    tokens = np.asarray(tokens)
    hi = int(tokens.max(initial=0))
    if hi < (1 << 8):
        width, dt = 1, np.uint8
    elif hi < (1 << 16):
        width, dt = 2, np.dtype("<u2")
    else:
        width, dt = 4, np.dtype("<u4")
    return width, tokens.astype(dt).tobytes()


def unpack_tokens(packed: bytes, width: int, n_tokens: int,
                  vocab: int) -> np.ndarray:
    """Inverse of ``pack_tokens``. Validates length and token range —
    a crafted stream must fail loudly, never decode out-of-vocab ids."""
    if width not in (1, 2, 4):
        raise ValueError(f"corrupt fallback stream: token width {width}")
    if len(packed) != width * n_tokens:
        raise ValueError(
            f"corrupt fallback stream: {len(packed)} payload bytes for "
            f"{n_tokens} tokens at width {width}")
    dt = {1: np.uint8, 2: np.dtype("<u2"), 4: np.dtype("<u4")}[width]
    toks = np.frombuffer(packed, dtype=dt).astype(np.int32)
    if toks.size and int(toks.max()) >= vocab:
        raise ValueError(
            f"corrupt fallback stream: token id {int(toks.max())} "
            f">= vocab {vocab}")
    return toks


@dataclass
class RouterConfig:
    """Routing policy knobs.

    * ``fallbacks`` — candidate fallback codec names in preference order;
      None means every available codec (zstd when the optional
      ``zstandard`` package is importable, always lzma and raw).
    * ``probe_tokens`` — positions of early cross-entropy the probe
      scores before deciding whether a chunk enters the model batch.
    * ``skip_margin`` — the LLM path is skipped only when its estimated
      bits exceed ``margin ×`` the fallback's realized bits, where
      ``margin`` starts at ``skip_margin`` and — with
      ``adaptive_margin`` — is updated per traffic class from
      probe-vs-realized history (see ``CodecRouter.observe``). > 1 is
      conservative: a borderline chunk still gets the LLM encode plus
      the final realized-size comparison, so probe noise costs model
      time, not ratio.
    * ``adaptive_margin`` / ``margin_floor`` / ``margin_ceil`` /
      ``margin_alpha`` — the calibration loop: when realized LLM bits
      run hotter than the probe estimated (the probe flatters the
      model — adversarial traffic whose tail degrades after the probed
      prefix), the effective margin shrinks toward ``margin_floor`` so
      such chunks skip sooner; when realized bits run cooler
      (predictable traffic the early-CE probe under-credits), it grows
      toward ``margin_ceil``. ``margin_alpha`` is the EMA step. The
      floor is a safety clamp: the margin never drops below it, so a
      burst of bad luck cannot lock the router out of the LLM path.
    """
    fallbacks: tuple | None = None
    probe_tokens: int = 32
    skip_margin: float = 1.25
    adaptive_margin: bool = True
    margin_floor: float = 1.05
    margin_ceil: float = 2.0
    margin_alpha: float = 0.25


@dataclass
class RouteDecision:
    """One chunk's routing record (diagnostics; the wire carries only
    the final codec tag)."""
    codec: str                  # final codec name
    fallback_bytes: int         # realized best-fallback stream size
    llm_bits_est: float = -1.0  # probe estimate (-1: no probe ran)
    flipped: bool = False       # LLM encode ran but fallback won


class CodecRouter:
    """Per-chunk codec selection policy.

    Decisions are per-chunk and order-independent, but the router keeps
    one piece of *calibration* state: a per-traffic-class EMA of the
    realized-vs-estimated LLM bit ratio, fed by ``observe`` after each
    LLM encode and consumed by ``margin_for``. Calibration only tunes
    the probe's skip threshold — it can cost model time, never
    correctness (the final realized-size flip still runs on every
    LLM-encoded chunk, and decode follows the recorded tags)."""

    def __init__(self, config: RouterConfig | None = None):
        self.config = config or RouterConfig()
        # traffic class -> EMA of (realized llm bits / probe estimate)
        self._calibration: dict[str, float] = {}

    @staticmethod
    def traffic_class(est_bits: float, fallback_bytes: int) -> str:
        """Coarse traffic class from the probe's own signals: how the
        estimated LLM cost compares to the realized fallback. Classes
        keep calibration from mixing regimes — the probe's bias on
        model-friendly text says nothing about its bias on adversarial
        bytes."""
        fb_bits = 8.0 * max(1, fallback_bytes)
        r = est_bits / fb_bits
        if r < 0.75:
            return "predictable"
        if r < 1.5:
            return "borderline"
        return "adversarial"

    def margin_for(self, cls: str) -> float:
        """Effective skip margin for a traffic class: the configured
        ``skip_margin`` divided by the class's realized/estimated ratio
        (estimates running hot shrink the margin — skip sooner),
        clamped to [margin_floor, margin_ceil]."""
        cfg = self.config
        rho = self._calibration.get(cls)
        if not cfg.adaptive_margin or rho is None:
            return cfg.skip_margin
        return float(np.clip(cfg.skip_margin / rho, cfg.margin_floor,
                             cfg.margin_ceil))

    def observe(self, est_bits: float, llm_bits: float,
                fallback_bytes: int) -> None:
        """Feed one probe-vs-realized observation (an LLM-encoded
        chunk's probe estimate and realized code length) into the
        class's calibration EMA. Chunks that skipped the model have no
        realized LLM size and are never observed — the estimate is the
        only thing being calibrated."""
        if est_bits <= 0 or llm_bits <= 0:
            return
        cls = self.traffic_class(est_bits, fallback_bytes)
        rho = llm_bits / est_bits
        old = self._calibration.get(cls)
        a = self.config.margin_alpha
        self._calibration[cls] = rho if old is None \
            else (1.0 - a) * old + a * rho

    def fallback_candidates(self) -> list[str]:
        """Usable fallback codec names, honouring the configured
        preference list and current zstd availability."""
        avail = available_byte_codecs()
        want = self.config.fallbacks
        if want is None:
            return avail
        names = [n for n in want if n in avail]
        if not names:
            raise ValueError(
                f"no configured fallback codec is available "
                f"(wanted {list(want)}, available {avail})")
        return names

    def best_fallback(self, tokens: np.ndarray) -> tuple[str, bytes]:
        """Realized best fallback stream for a chunk's tokens: every
        candidate codec actually runs and the smallest stream wins (raw
        store is always a candidate, so the result can never exceed
        packed size + 1 width byte)."""
        width, packed = pack_tokens(tokens)
        best_name, best = None, None
        for name in {*self.fallback_candidates(), "raw"}:
            blob = compress_bytes(name, packed)
            if best is None or len(blob) < len(best) \
                    or (len(blob) == len(best) and name < best_name):
                best_name, best = name, blob
        return best_name, bytes([width]) + best

    def skip_llm(self, est_bits: float, fallback_stream: bytes) -> bool:
        """True when the probe estimate says the LLM path would lose by
        more than the (class-calibrated) safety margin — the chunk then
        skips the model entirely (the service never gives it a slot)."""
        margin = self.margin_for(
            self.traffic_class(est_bits, len(fallback_stream)))
        return est_bits > margin * 8.0 * len(fallback_stream)

    @staticmethod
    def decode_fallback(codec_name: str, stream: bytes, n_tokens: int,
                        vocab: int) -> np.ndarray:
        """Decode one fallback chunk stream back to tokens. Raises
        ValueError on any structural problem (the container layer wraps
        this into ContainerError)."""
        if len(stream) < 2:
            raise ValueError(
                f"corrupt fallback stream: {len(stream)} bytes cannot "
                f"code {n_tokens} tokens")
        try:
            packed = decompress_bytes(codec_name, stream[1:])
        except ValueError:
            raise
        except Exception as e:     # zstd/lzma backend errors
            raise ValueError(f"corrupt {codec_name} fallback stream: {e}")
        return unpack_tokens(packed, stream[0], n_tokens, vocab)


def route_chunks(router: CodecRouter, predictor, chunks: np.ndarray,
                 valid: np.ndarray, llm_codec: str,
                 auto: bool) -> tuple[list[RouteDecision], list]:
    """Shared encode-side routing pass (the grouped compressor and the
    service scheduler both call this, so their policies cannot drift).

    Realizes the best fallback stream for every chunk, then — in auto
    mode — runs ONE prefill probe over the first ``probe_tokens``
    positions of all chunks and marks each chunk either ``llm_codec``
    (enter the model batch; the realized-size comparison still happens
    after encode) or its fallback codec name (skip the model entirely).
    Returns ``(decisions, fallback_streams)`` with ``fallback_streams[i]
    = (codec_name, stream)``."""
    from repro.obs import trace as _trace
    n_chunks = chunks.shape[0] if len(chunks) else 0
    with _trace.span("router.fallback"):
        fb = [router.best_fallback(chunks[i, :int(valid[i])])
              for i in range(n_chunks)]
    if not auto:
        return [RouteDecision(name, len(s)) for name, s in fb], fb
    if not n_chunks:
        return [], fb
    with _trace.span("router.probe"):
        P = min(router.config.probe_tokens, chunks.shape[1])
        logits = np.asarray(predictor.score_chunks(chunks[:, :P]))
        est = estimate_chunk_bits(logits, chunks, valid, P)
    return [RouteDecision(name if router.skip_llm(float(est[i]), s)
                          else llm_codec, len(s), float(est[i]))
            for i, (name, s) in enumerate(fb)], fb


def estimate_chunk_bits(logits: np.ndarray, tokens: np.ndarray,
                        valid: np.ndarray,
                        probe: int) -> np.ndarray:
    """Early-cross-entropy probe: given teacher-forced logits for the
    first ``probe`` positions of each chunk (``logits[:, t]`` predicts
    ``tokens[:, t]``), return the per-chunk *whole-chunk* LLM bit
    estimate — mean scored bits/token extrapolated to ``valid`` tokens.

    The probe is advisory (the decision is recorded in the container,
    decode never re-runs it), so prefill-scored logits are fine here
    even though the exact encode scores through the decode program."""
    logits = np.asarray(logits, np.float64)
    tokens = np.asarray(tokens, np.int64)
    valid = np.asarray(valid, np.int64)
    P = min(probe, logits.shape[1])
    lp = logits[:, :P]
    lp = lp - lp.max(axis=-1, keepdims=True)
    lse = np.log(np.exp(lp).sum(axis=-1))
    tok_lp = np.take_along_axis(lp, tokens[:, :P, None], axis=-1)[..., 0]
    scored = np.minimum(valid, P)
    m = np.arange(P)[None, :] < scored[:, None]
    bits = ((lse - tok_lp) * m).sum(axis=1) / np.log(2.0)
    per_tok = bits / np.maximum(scored, 1)
    return per_tok * valid
