"""Core of the paper's contribution: LLM next-token prediction as the
probability model for lossless entropy coding (arithmetic or rANS)."""
from .ac import ArithmeticDecoder, ArithmeticEncoder, uniform_cdf
from .cdf import (coding_cost_bits, logits_to_cdf, pmf_to_cdf,
                  quantize_pmf, topk_quantized)
from .checksum import xxh64
from .compressor import (ChunkEntry, CompressionStats, ContainerError,
                         ContainerInfo, LLMCompressor, PredictorAdapter,
                         parse_container, read_header, read_index,
                         write_container)
from .draft import ConstantDraft, DraftProposer, OracleDraft, SuffixDraft
from .rans import BatchedRansDecoder, BatchedRansEncoder, SlotRansEncoder

__all__ = [
    "ArithmeticDecoder", "ArithmeticEncoder", "uniform_cdf",
    "BatchedRansDecoder", "BatchedRansEncoder", "SlotRansEncoder",
    "coding_cost_bits", "logits_to_cdf", "pmf_to_cdf", "quantize_pmf",
    "topk_quantized", "xxh64",
    "ChunkEntry", "CompressionStats", "ContainerError", "ContainerInfo",
    "LLMCompressor", "PredictorAdapter",
    "ConstantDraft", "DraftProposer", "OracleDraft", "SuffixDraft",
    "parse_container", "read_header", "read_index", "write_container",
]
