"""Core of the paper's contribution: LLM next-token prediction as the
probability model for lossless entropy coding (arithmetic or rANS)."""
from .ac import ArithmeticDecoder, ArithmeticEncoder, uniform_cdf
from .baselines import (BYTE_CODECS, available_byte_codecs, compress_bytes,
                        decompress_bytes)
from .cdf import (coding_cost_bits, logits_to_cdf, pmf_to_cdf,
                  quantize_pmf, topk_quantized)
from .checksum import xxh64
from .compressor import (CODEC_IDS, CODEC_NAMES, FALLBACK_CODEC_IDS,
                         RECIPE_CARRY, RECIPE_NONE, RECIPE_SHARED,
                         VERSION_V3, VERSION_V4, VERSION_V5, VERSION_V6,
                         ChunkEntry, CompressionStats, ContainerError,
                         ContainerInfo, LLMCompressor, PredictorAdapter,
                         assign_context_recipes, container_is_model_free,
                         context_budget,
                         decompress_model_free, decompress_range_model_free,
                         parse_container, read_header, read_index,
                         recipe_context, write_container)
from .draft import ConstantDraft, DraftProposer, OracleDraft, SuffixDraft
from .rans import BatchedRansDecoder, BatchedRansEncoder, SlotRansEncoder
from .router import (ROUTE_AUTO, ROUTE_LLM, CodecRouter, RouteDecision,
                     RouterConfig, pack_tokens, unpack_tokens)

__all__ = [
    "ArithmeticDecoder", "ArithmeticEncoder", "uniform_cdf",
    "BatchedRansDecoder", "BatchedRansEncoder", "SlotRansEncoder",
    "BYTE_CODECS", "available_byte_codecs", "compress_bytes",
    "decompress_bytes",
    "coding_cost_bits", "logits_to_cdf", "pmf_to_cdf", "quantize_pmf",
    "topk_quantized", "xxh64",
    "CODEC_IDS", "CODEC_NAMES", "FALLBACK_CODEC_IDS",
    "RECIPE_CARRY", "RECIPE_NONE", "RECIPE_SHARED",
    "VERSION_V3", "VERSION_V4", "VERSION_V5", "VERSION_V6",
    "ChunkEntry", "CompressionStats", "ContainerError", "ContainerInfo",
    "LLMCompressor", "PredictorAdapter",
    "assign_context_recipes", "container_is_model_free",
    "context_budget",
    "decompress_model_free", "decompress_range_model_free",
    "recipe_context",
    "ConstantDraft", "DraftProposer", "OracleDraft", "SuffixDraft",
    "ROUTE_AUTO", "ROUTE_LLM", "CodecRouter", "RouteDecision",
    "RouterConfig", "pack_tokens", "unpack_tokens",
    "parse_container", "read_header", "read_index", "write_container",
]
