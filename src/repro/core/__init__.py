"""Core of the paper's contribution: LLM next-token prediction as the
probability model for lossless entropy coding (arithmetic or rANS)."""
from .ac import ArithmeticDecoder, ArithmeticEncoder, uniform_cdf
from .cdf import (coding_cost_bits, logits_to_cdf, pmf_to_cdf,
                  quantize_pmf, topk_quantized)
from .compressor import CompressionStats, LLMCompressor, PredictorAdapter
from .rans import BatchedRansDecoder, BatchedRansEncoder

__all__ = [
    "ArithmeticDecoder", "ArithmeticEncoder", "uniform_cdf",
    "BatchedRansDecoder", "BatchedRansEncoder",
    "coding_cost_bits", "logits_to_cdf", "pmf_to_cdf", "quantize_pmf",
    "topk_quantized", "CompressionStats", "LLMCompressor", "PredictorAdapter",
]
