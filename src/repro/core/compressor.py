"""The paper's LLM-based lossless compressor (§4), as a framework component.

Design
------
The text is tokenized, split into fixed-size **chunks** (paper §5.4), and
each chunk is coded *independently* given a fresh context. Independence is
what makes the workload batchable:

* **compress** — one teacher-forced scoring pass over a (B, C) batch of
  chunks (a prefill-shaped pjit computation) yields P(x_t | x_<t) for every
  position; each actual token is then entropy-coded with its quantized CDF.
  Model cost: one forward pass per C tokens.

* **decompress** — B chunks are decoded in lock-step: one `decode_step`
  (serve-shaped computation, KV/SSM cache) per position for the whole
  batch; the entropy decoder picks each stream's next token from the
  model CDF, which is then fed back as the next input.

Losslessness requires the *same* quantized CDFs on both sides. Both sides
run the same jitted function on the same weights with integer quantization,
so the CDFs are bit-identical (this is exactly why the paper compresses
instead of re-generating, §4.4 — we make the determinism explicit).

Beyond-paper: top-K + escape coding (see core/cdf.py) bounds host-coder
work per token at K+1 instead of |V|, at a measured ~0 ratio cost for
well-predicted text (escapes coded uniformly remain lossless).

Entropy backends (DESIGN.md §7)
-------------------------------
Two host coders share the container:

* ``codec="rans"`` (id 1, default) — batched interleaved rANS
  (core/rans.py): all B chunk-streams advance through ONE vectorized
  coder step per token position. This is the production path; host cost
  per token is a few numpy ufuncs amortized over the batch.
* ``codec="ac"`` (id 0) — the reference Witten–Neal–Cleary arithmetic
  coder (core/ac.py): per-stream Python loops, kept as the legacy /
  cross-check backend and for decoding v2 archives.

Container format (little-endian), version 3:
  magic 'LLMC' | u8 version | u8 flags | u16 chunk_size | u32 n_tokens
  u32 vocab | u16 topk (0 => full vocab) | u8 precision | u8 codec
  then per chunk: varint byte-length + codec stream.
Version 2 (seed format) lacks the codec byte and is always AC; the
decoder still accepts it — the codec actually used for decode comes from
the container, not from this object's configuration.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Callable, Protocol

import numpy as np

from . import ac, rans
from .cdf import (DEFAULT_PRECISION, build_topk_cdfs, logits_to_cdf,
                  pmf_to_cdf, topk_quantized_jit)

MAGIC = b"LLMC"
VERSION = 3
_V2_HEADER = "<BBHIIHB"          # seed header (no codec byte)
_V3_HEADER = "<BBHIIHBB"

CODEC_AC = 0
CODEC_RANS = 1
CODEC_IDS = {"ac": CODEC_AC, "rans": CODEC_RANS}
CODEC_NAMES = {v: k for k, v in CODEC_IDS.items()}


class PredictorAdapter(Protocol):
    """What the compressor needs from a model. See serve/engine.py for the
    production implementation over the model zoo."""

    vocab_size: int
    bos_id: int

    def score_chunks(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (B, C) int32 -> logits (B, C, V): logits[:, t] predicts
        tokens[:, t] (i.e. the model input is [BOS, x_0 .. x_{C-2}])."""
        ...

    def begin_decode(self, batch: int):
        """-> opaque decode state positioned to predict token 0 of each chunk."""
        ...

    def decode_step(self, state, prev_tokens: np.ndarray):
        """(state, prev (B,) int32) -> (logits (B, V), new state)."""
        ...


def _write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _read_varint(buf: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    val = 0
    while True:
        b = buf[pos]
        pos += 1
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, pos
        shift += 7


@dataclass
class CompressionStats:
    n_tokens: int = 0
    payload_bytes: int = 0
    header_bytes: int = 0
    n_escapes: int = 0
    ideal_bits: float = 0.0  # -sum log2 p from the un-quantized model

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.header_bytes


class LLMCompressor:
    """Chunked LLM-predictor + entropy-coding lossless compressor."""

    def __init__(self, predictor: PredictorAdapter, *,
                 chunk_size: int = 256,
                 topk: int = 0,
                 precision: int = DEFAULT_PRECISION,
                 decode_batch: int = 64,
                 codec: str = "rans"):
        if topk and topk >= predictor.vocab_size:
            topk = 0
        if codec not in CODEC_IDS:
            raise ValueError(f"unknown codec {codec!r} "
                             f"(choose from {sorted(CODEC_IDS)})")
        self.predictor = predictor
        self.chunk_size = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self.decode_batch = int(decode_batch)
        self.codec = codec
        if (1 << precision) <= (topk + 1 if topk else predictor.vocab_size):
            raise ValueError("precision too small for alphabet")
        # only the rANS backend caps precision (AC handles up to 30 bits);
        # decoding a foreign-codec container never hits the encoder limit
        if codec == "rans" and precision > rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} exceeds rANS coder "
                             f"limit {rans.MAX_PRECISION}")
        # escape symbols: AC codes exactly over V; rANS over 2**esc_bits >= V
        self._esc_bits = rans.uniform_bits(predictor.vocab_size)

    # ------------------------------------------------------------- compress
    def compress(self, tokens: np.ndarray, *,
                 exact: bool = True) -> tuple[bytes, CompressionStats]:
        """Compress a token stream.

        exact=True (default) scores with the *decode program* (the same
        jitted step the decompressor runs), guaranteeing bit-identical CDFs
        on both sides — the lossless requirement. exact=False scores with
        the teacher-forced prefill pass: ~C× fewer model invocations and
        identical in exact arithmetic, but float reduction-order
        differences between the prefill and decode programs can flip a
        quantization bucket on rare tokens, so it is reserved for ratio
        estimation / benchmarking (see DESIGN.md §6).
        """
        tokens = np.asarray(tokens, dtype=np.int32).ravel()
        n = tokens.size
        C = self.chunk_size
        n_chunks = max(1, -(-n // C))
        padded = np.zeros(n_chunks * C, dtype=np.int32)
        padded[:n] = tokens
        chunks = padded.reshape(n_chunks, C)

        stats = CompressionStats(n_tokens=n)
        streams: list[bytes] = []
        B = self.decode_batch
        for i in range(0, n_chunks, B):
            batch = chunks[i:i + B]
            if exact:
                logits = self._score_incremental(batch)
            else:
                logits = np.asarray(self.predictor.score_chunks(batch))
            streams.extend(self._encode_batch(batch, logits,
                                              i, n, stats))
        out = bytearray()
        flags = 1 if self.topk else 0
        out += MAGIC
        out += struct.pack(_V3_HEADER, VERSION, flags, C, n,
                           self.predictor.vocab_size, self.topk,
                           self.precision, CODEC_IDS[self.codec])
        stats.header_bytes = len(out) + 0
        body = bytearray()
        for s in streams:
            _write_varint(body, len(s))
            body += s
        stats.header_bytes += len(body) - sum(len(s) for s in streams)
        stats.payload_bytes = sum(len(s) for s in streams)
        return bytes(out + body), stats

    def _score_incremental(self, batch: np.ndarray) -> np.ndarray:
        """Teacher-forced scoring through the decode program: one call to
        the decompressor's own jitted step per position, ground-truth token
        fed back. Bit-exact with decompression by construction."""
        B, C = batch.shape
        if hasattr(self.predictor, "set_decode_len"):
            self.predictor.set_decode_len(C)
        state = self.predictor.begin_decode(B)
        prev = np.full((B,), self.predictor.bos_id, dtype=np.int32)
        logits = np.zeros((B, C, self.predictor.vocab_size), np.float32)
        for t in range(C):
            lg, state = self.predictor.decode_step(state, prev)
            logits[:, t] = lg
            prev = batch[:, t]
        return logits

    # -------------------------------------------------------------- encode
    def _valid_lengths(self, B, chunk_offset, n_total) -> np.ndarray:
        C = self.chunk_size
        return np.array([min(C, max(0, n_total - (chunk_offset + b) * C))
                         for b in range(B)], dtype=np.int64)

    def _encode_batch(self, batch, logits, chunk_offset, n_total, stats):
        self._accumulate_ideal_bits(batch, logits, chunk_offset, n_total,
                                    stats)
        if self.codec == "rans":
            return self._encode_batch_rans(batch, logits, chunk_offset,
                                           n_total, stats)
        return self._encode_batch_ac(batch, logits, chunk_offset,
                                     n_total, stats)

    def _accumulate_ideal_bits(self, batch, logits, chunk_offset, n_total,
                               stats):
        lp = logits.astype(np.float64)
        lp -= lp.max(axis=-1, keepdims=True)
        lse = np.log(np.exp(lp).sum(axis=-1))
        tok_lp = np.take_along_axis(lp, batch[..., None].astype(np.int64),
                                    axis=-1)[..., 0]
        valid = self._valid_lengths(batch.shape[0], chunk_offset, n_total)
        m = np.arange(batch.shape[1])[None, :] < valid[:, None]
        stats.ideal_bits += float(((lse - tok_lp) * m).sum() / np.log(2.0))

    def _encode_batch_rans(self, batch, logits, chunk_offset, n_total,
                           stats):
        """All B chunk-streams advance through one vectorized coder step
        per token position: vectorized top-K slot lookup, masked escape
        steps, and a single LIFO flush in finish()."""
        B, C = batch.shape
        valid = self._valid_lengths(B, chunk_offset, n_total)
        enc = rans.BatchedRansEncoder(B)
        pos = np.arange(C)[None, :] < valid[:, None]          # (B, C) active
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)            # (B,C,K),(B,C,K+2)
            match = ids == batch[..., None]
            has = match.any(axis=-1)
            slots = np.where(has, match.argmax(axis=-1), self.topk)
            starts = np.take_along_axis(cdfs, slots[..., None],
                                        axis=-1)[..., 0]
            ends = np.take_along_axis(cdfs, slots[..., None] + 1,
                                      axis=-1)[..., 0]
            stats.n_escapes += int((~has & pos).sum())
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                enc.put(starts[:, t], ends[:, t] - starts[:, t],
                        self.precision, m)
                em = m & ~has[:, t]
                if em.any():
                    enc.put_uniform(batch[:, t], self._esc_bits, em)
        else:
            # per-position CDFs: a (B, C, V+1) int64 tensor would be tens
            # of GB at production vocab sizes, so quantize one (B, V+1)
            # slab per step — same shape the decode path uses
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                cdfs = logits_to_cdf(logits[:, t], self.precision)
                enc.put_symbols(batch[:, t].astype(np.int64), cdfs,
                                self.precision, m)
        return enc.finish()

    def _encode_batch_ac(self, batch, logits, chunk_offset, n_total, stats):
        """Legacy per-stream arithmetic-coding loops (reference codec)."""
        V = self.predictor.vocab_size
        streams = []
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)
        valid = self._valid_lengths(batch.shape[0], chunk_offset, n_total)
        for b in range(batch.shape[0]):
            enc = ac.ArithmeticEncoder()
            for t in range(int(valid[b])):
                sym = int(batch[b, t])
                if self.topk:
                    slot = np.nonzero(ids[b, t] == sym)[0]
                    if slot.size:
                        enc.encode(int(slot[0]), cdfs[b, t])
                    else:  # escape, then uniform over the full vocab
                        stats.n_escapes += 1
                        enc.encode(self.topk, cdfs[b, t])
                        enc.encode(sym, ac.uniform_cdf(V))
                else:
                    cdf = logits_to_cdf(logits[b, t], self.precision)
                    enc.encode(sym, cdf)
            streams.append(enc.finish() if valid[b] else b"")
        return streams

    # ----------------------------------------------------------- decompress
    def decompress(self, blob: bytes) -> np.ndarray:
        if blob[:4] != MAGIC:
            raise ValueError("bad magic")
        version = blob[4]
        if version == 2:
            hdr = _V2_HEADER
            _, flags, C, n, vocab, topk, precision = struct.unpack(
                hdr, blob[4:4 + struct.calcsize(hdr)])
            codec = CODEC_AC          # v2 archives predate the codec byte
        elif version == VERSION:
            hdr = _V3_HEADER
            (_, flags, C, n, vocab, topk, precision,
             codec) = struct.unpack(hdr, blob[4:4 + struct.calcsize(hdr)])
            if codec not in CODEC_NAMES:
                raise ValueError(f"unknown codec id {codec}")
        else:
            raise ValueError(f"unsupported version {version}")
        if vocab != self.predictor.vocab_size or C != self.chunk_size \
                or topk != self.topk or precision != self.precision:
            raise ValueError("compressor configuration mismatch with container")
        pos = 4 + struct.calcsize(hdr)
        n_chunks = max(1, -(-n // C))
        streams = []
        for _ in range(n_chunks):
            ln, pos = _read_varint(blob, pos)
            streams.append(blob[pos:pos + ln])
            pos += ln
        out = np.zeros(n_chunks * C, dtype=np.int32)
        B = self.decode_batch
        for i in range(0, n_chunks, B):
            group = streams[i:i + B]
            dec_tokens = self._decode_group(group, C, n, i, codec)
            out[i * C:(i + len(group)) * C] = dec_tokens.ravel()
        return out[:n]

    def _decode_group(self, streams, C, n_total, chunk_offset, codec: int):
        if codec == CODEC_RANS:
            return self._decode_group_rans(streams, C, n_total, chunk_offset)
        return self._decode_group_ac(streams, C, n_total, chunk_offset)

    def _begin_group(self, B, C):
        if hasattr(self.predictor, "set_decode_len"):
            self.predictor.set_decode_len(C)
        state = self.predictor.begin_decode(B)
        prev = np.full((B,), self.predictor.bos_id, dtype=np.int32)
        return state, prev

    def _decode_group_rans(self, streams, C, n_total, chunk_offset):
        """Lock-step batched decode: one model step + one vectorized coder
        step (plus a masked escape step) per token position."""
        B = len(streams)
        valid = self._valid_lengths(B, chunk_offset, n_total)
        dec = rans.BatchedRansDecoder(streams)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev = self._begin_group(B, C)
        for t in range(int(valid.max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            logits = np.asarray(logits)
            m = valid > t
            if self.topk:
                ids, qpmf = topk_quantized_jit(logits, self.topk,
                                               self.precision)
                ids = np.asarray(ids)
                cdfs = pmf_to_cdf(np.asarray(qpmf))            # (B, K+2)
                slots = dec.get(cdfs, self.precision, m)
                esc = m & (slots == self.topk)
                syms = np.take_along_axis(
                    ids, np.minimum(slots, self.topk - 1)[:, None],
                    axis=-1)[:, 0].astype(np.int64)
                if esc.any():
                    u = dec.get_uniform(self._esc_bits, esc)
                    syms = np.where(esc, u, syms)
            else:
                cdfs = logits_to_cdf(logits, self.precision)   # (B, V+1)
                syms = dec.get(cdfs, self.precision, m)
            nxt = np.where(m, syms, 0).astype(np.int32)
            tokens[:, t] = nxt
            prev = nxt
        return tokens

    def _decode_group_ac(self, streams, C, n_total, chunk_offset):
        """Legacy per-stream arithmetic decode (reference codec + v2)."""
        V = self.predictor.vocab_size
        B = len(streams)
        decoders = [ac.ArithmeticDecoder(s) for s in streams]
        valid = self._valid_lengths(B, chunk_offset, n_total)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev = self._begin_group(B, C)
        for t in range(int(valid.max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            logits = np.asarray(logits)
            if self.topk:
                ids, qpmf = topk_quantized_jit(logits, self.topk,
                                               self.precision)
                ids = np.asarray(ids)
                cdfs = pmf_to_cdf(np.asarray(qpmf))
            nxt = np.zeros((B,), dtype=np.int32)
            for b in range(B):
                if t >= valid[b]:
                    continue
                if self.topk:
                    slot = decoders[b].decode(cdfs[b])
                    if slot == self.topk:  # escape
                        sym = decoders[b].decode(ac.uniform_cdf(V))
                    else:
                        sym = int(ids[b, slot])
                else:
                    cdf = logits_to_cdf(logits[b], self.precision)
                    sym = decoders[b].decode(cdf)
                tokens[b, t] = sym
                nxt[b] = sym
            prev = nxt
        return tokens

    # ------------------------------------------------------------- metrics
    @staticmethod
    def ratio(original_bytes: int, blob: bytes) -> float:
        return original_bytes / max(1, len(blob))
