"""The paper's LLM-based lossless compressor (§4), as a framework component.

Design
------
The text is tokenized, split into fixed-size **chunks** (paper §5.4), and
each chunk is coded *independently* given a fresh context. Independence is
what makes the workload batchable:

* **compress** — one teacher-forced scoring pass over a (B, C) batch of
  chunks (a prefill-shaped pjit computation) yields P(x_t | x_<t) for every
  position; each actual token is then entropy-coded with its quantized CDF.
  Model cost: one forward pass per C tokens.

* **decompress** — B chunks are decoded in lock-step: one `decode_step`
  (serve-shaped computation, KV/SSM cache) per position for the whole
  batch; the entropy decoder picks each stream's next token from the
  model CDF, which is then fed back as the next input.

Losslessness requires the *same* quantized CDFs on both sides. Both sides
run the same jitted function on the same weights with integer quantization,
so the CDFs are bit-identical (this is exactly why the paper compresses
instead of re-generating, §4.4 — we make the determinism explicit).

Beyond-paper: top-K + escape coding (see core/cdf.py) bounds host-coder
work per token at K+1 instead of |V|, at a measured ~0 ratio cost for
well-predicted text (escapes coded uniformly remain lossless).

Entropy backends (DESIGN.md §7)
-------------------------------
Two host coders share the container:

* ``codec="rans"`` (id 1, default) — batched interleaved rANS
  (core/rans.py): all B chunk-streams advance through ONE vectorized
  coder step per token position. This is the production path; host cost
  per token is a few numpy ufuncs amortized over the batch.
* ``codec="ac"`` (id 0) — the reference Witten–Neal–Cleary arithmetic
  coder (core/ac.py): per-stream Python loops, kept as the legacy /
  cross-check backend and for decoding v2 archives.

Container format (little-endian)
--------------------------------
Shared header (v3 and v4; v2 lacks the codec byte):
  magic 'LLMC' | u8 version | u8 flags | u16 chunk_size | u32 n_tokens
  u32 vocab | u16 topk (0 => full vocab) | u8 precision | u8 codec
Body (all versions): per chunk, varint byte-length + codec stream.

Version 4 appends a **seekable footer** after the body (DESIGN.md §8):
one index entry per chunk —
  u64 stream offset (from container start) | u32 stream length
  u32 valid token count | u64 xxh64(stream)
— followed by u32 encode batch (the lane count the encoder's model
program ran at; 0 = unrecorded), u64 xxh64(header || entries || encode
batch), u32 n_chunks, u32 footer length, and the end magic 'LC4F'. The
encode batch is recorded because on real models the logits are only
bit-reproducible at the *same* batch shape (XLA reduction order varies
with B), so it is the decode batch/slot count required for bit-exact
decode — advisory for batch-invariant predictors, load-bearing for
production models. The index enables random-access decode
of chunk ranges (``decompress_range``) and out-of-order chunk completion
from the service scheduler; the checksums turn silent corruption into
``ContainerError`` before the entropy coder runs on garbage.

Version 5 (DESIGN.md §11) is v4 plus **adaptive codec routing**: each
index entry carries a u8 codec tag —
  u64 offset | u32 stream length | u32 valid tokens | u8 codec | u64 xxh64
— end magic 'LC5F'. The header codec byte still names the container's
LLM *entropy* codec (ac/rans); a per-chunk tag either repeats it (the
chunk is LLM-coded) or names a fallback byte codec (zstd=2, lzma=3,
raw=4 — core/baselines.py) the router chose because the model fit was
poor. The tags live inside the hash-covered footer, so a flipped tag is
detected like any other index corruption, and decode reconstructs each
chunk with exactly the recorded backend — the router runs at encode
only, never guesses at decode. LLM-tagged chunks are grouped at the
recorded encode batch for decode; lanes are independent, so *which*
chunks share a group is free while the lane count stays load-bearing.

Version 6 (DESIGN.md §12) makes conditioning **context** first-class:
each index entry additionally carries a hash-covered context recipe —
  u64 offset | u32 length | u32 valid tokens | u8 codec
  u8 recipe kind | u16 recipe param | u64 xxh64
(28-byte entries, end magic 'LC6F') — and the footer holds a
shared-prefix dictionary section between the entries and the encode
batch (also hash-covered). The recipe declares what the model had
consumed before the chunk's first token:

  * ``none`` (0, param 0) — fresh context, exactly the v2–v5 contract;
  * ``carry(W)`` (1, param W >= 1) — the last ``min(W, C)`` tokens of
    the *previous* chunk (so a carry chunk can never be chunk 0);
  * ``shared[i]`` (2) — entry ``i`` of the shared-prefix dictionary
    (u16 count; per prefix: u8 name length | name | u16 token count |
    u32 tokens).

A lane's model input is always the self-contained sequence
[BOS, context…, chunk tokens…]; lanes are independent, so recipe +
recorded lane count make ranged decode bit-exact by construction —
a ranged chunk's carry chain is decoded forward from its chain start
to materialize the declared context, and *composition* of lanes stays
free exactly as in v5. Fallback-tagged chunks must carry recipe
``none`` (they decode without the model, and an all-fallback archive
must stay fully model-free).

The codec, version and geometry used for decode come from the container,
never from this object's configuration. Version compatibility: v2
read-only (AC implied), v3/v4/v5/v6 read/write. A bare
``LLMCompressor`` writes v3 — the wire-minimal format every ratio
benchmark measures (the v4 index costs a fixed 24 B/chunk, which
amortizes over production payloads but distorts micro-scale ratios);
the service layer (repro.service) and the ``llmc`` CLI write v4, where
seekability and integrity checking earn their bytes, v5 whenever
routing is enabled (``route != "llm"``), and v6 whenever a context
recipe is in play (``context_window``/``shared_prefix``).
"""
from __future__ import annotations

import inspect
import struct
from dataclasses import dataclass, field
from typing import Callable, Protocol

import numpy as np

from repro import obs
from . import ac, rans
from .cdf import (DEFAULT_PRECISION, build_topk_cdfs, full_cdf_jit,
                  full_cdf_lookup_jit, logits_to_cdf, pmf_to_cdf,
                  topk_cdf_jit, topk_cdf_lookup_jit, topk_quantized_jit)
from .checksum import xxh64
from .draft import SuffixDraft
from .router import (ROUTE_AUTO, ROUTE_LLM, CodecRouter, RouterConfig,
                     route_chunks)

MAGIC = b"LLMC"
VERSION_V3 = 3
VERSION_V4 = 4
VERSION_V5 = 5
VERSION_V6 = 6
VERSION = VERSION_V6                 # newest supported container version
_V2_HEADER = "<BBHIIHB"              # seed header (no codec byte)
_V3_HEADER = "<BBHIIHBB"             # v3..v6 share this header layout
_V4_ENTRY = "<QIIQ"                  # offset, stream len, valid tokens, xxh64
_V4_ENTRY_SIZE = struct.calcsize(_V4_ENTRY)
_V4_END_MAGIC = b"LC4F"
_V5_ENTRY = "<QIIBQ"                 # v4 entry + u8 per-chunk codec tag
_V5_ENTRY_SIZE = struct.calcsize(_V5_ENTRY)
_V5_END_MAGIC = b"LC5F"
_V6_ENTRY = "<QIIBBHQ"               # v5 entry + u8 recipe kind, u16 param
_V6_ENTRY_SIZE = struct.calcsize(_V6_ENTRY)
_V6_END_MAGIC = b"LC6F"
_V4_TRAILER = 12                     # u32 n_chunks | u32 footer_len | magic
_INDEXED_VERSIONS = (VERSION_V4, VERSION_V5, VERSION_V6)

# v6 per-chunk context recipes (DESIGN.md §12)
RECIPE_NONE = 0      # fresh context — the v2-v5 contract
RECIPE_CARRY = 1     # last min(param, C) tokens of the previous chunk
RECIPE_SHARED = 2    # shared-prefix dictionary entry [param]
RECIPE_NAMES = {RECIPE_NONE: "none", RECIPE_CARRY: "carry",
                RECIPE_SHARED: "shared"}
# shared-prefix dictionary wire limits (u8 name length, u16 counts)
MAX_PREFIX_TOKENS = 0xFFFF
MAX_PREFIX_NAME = 0xFF

# LLM entropy codecs — legal in the header codec byte of any version
CODEC_AC = 0
CODEC_RANS = 1
# fallback byte codecs — legal only in v5 per-chunk tags (the router's
# choices; backends live in core/baselines.py)
CODEC_ZSTD = 2
CODEC_LZMA = 3
CODEC_RAW = 4
CODEC_IDS = {"ac": CODEC_AC, "rans": CODEC_RANS}
FALLBACK_CODEC_IDS = {"zstd": CODEC_ZSTD, "lzma": CODEC_LZMA,
                      "raw": CODEC_RAW}
CODEC_NAMES = {v: k for k, v in {**CODEC_IDS,
                                 **FALLBACK_CODEC_IDS}.items()}
LLM_CODECS = frozenset(CODEC_IDS.values())


class ContainerError(ValueError):
    """Malformed, truncated, corrupt, or configuration-mismatched container.

    Everything the parser can detect raises this (a ValueError subclass),
    never a bare IndexError/struct.error from running off the end of a
    truncated blob."""


class PredictorAdapter(Protocol):
    """What the compressor needs from a model. See serve/engine.py for the
    production implementation over the model zoo."""

    vocab_size: int
    bos_id: int

    def score_chunks(self, tokens: np.ndarray,
                     prefix: np.ndarray | None = None) -> np.ndarray:
        """tokens (B, C) int32 -> logits (B, C, V): logits[:, t] predicts
        tokens[:, t] (i.e. the model input is [BOS, x_0 .. x_{C-2}]).
        With ``prefix`` (B, P) the input is [BOS, prefix, x_0 .. x_{C-2}]
        and only the last C positions are returned — teacher-forced
        scoring under a declared context (v6 recipes)."""
        ...

    def begin_decode(self, batch: int, prefix: np.ndarray | None = None):
        """-> opaque decode state positioned to predict token 0 of each chunk.
        With ``prefix`` (B, P) the state has consumed [BOS, prefix[:, :-1]]
        — the caller feeds ``prefix[:, -1]`` as the first ``decode_step``
        input, whose logits then predict token 0 under the prefix. The
        ``prefix`` keyword is optional for adapters (its absence is
        detected by signature and the compressor falls back to feeding
        the context through ``decode_step`` one token at a time)."""
        ...

    def decode_step(self, state, prev_tokens: np.ndarray):
        """(state, prev (B,) int32) -> (logits (B, V), new state)."""
        ...


def _write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _read_varint(buf: bytes, pos: int, end: int | None = None) -> tuple[int, int]:
    """Bounds-checked varint read from ``buf[pos:end]``."""
    end = len(buf) if end is None else end
    shift = 0
    val = 0
    while True:
        if pos >= end:
            raise ContainerError(
                f"truncated container: varint runs past byte {end}")
        b = buf[pos]
        pos += 1
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, pos
        shift += 7
        if shift > 63:
            raise ContainerError("corrupt container: varint longer than 64 bits")


# ---------------------------------------------------------------- container
@dataclass
class ChunkEntry:
    """One v4/v5 index-footer entry (also synthesized for v2/v3 at
    parse). ``codec`` is the chunk's own codec id: the container's
    entropy codec for every chunk of a v2-v4 archive, and the recorded
    per-chunk routing decision for v5 (possibly a fallback codec)."""
    offset: int          # byte offset of the stream from container start
    length: int          # stream byte length
    n_tokens: int        # valid tokens in this chunk (<= chunk_size)
    checksum: int = 0    # xxh64 of the stream bytes (0 for v2/v3)
    codec: int = -1      # per-chunk codec id (filled in at parse)
    # v6 context recipe (RECIPE_NONE for every earlier version)
    recipe_kind: int = RECIPE_NONE
    recipe_param: int = 0

    @property
    def codec_name(self) -> str:
        return CODEC_NAMES[self.codec]

    @property
    def is_llm(self) -> bool:
        return self.codec in LLM_CODECS

    @property
    def recipe_name(self) -> str:
        if self.recipe_kind == RECIPE_CARRY:
            return f"carry({self.recipe_param})"
        if self.recipe_kind == RECIPE_SHARED:
            return f"shared[{self.recipe_param}]"
        return "none"


@dataclass
class ContainerInfo:
    """Parsed header (+ index when v4) of an .llmc container."""
    version: int
    flags: int
    chunk_size: int
    n_tokens: int
    vocab: int
    topk: int
    precision: int
    codec: int
    header_size: int
    n_chunks: int
    entries: list[ChunkEntry] = field(default_factory=list)
    # v4 only: the model-program lane count the encoder ran at (0 when
    # unrecorded / v2 / v3). Bit-exact decode of non-batch-invariant
    # models requires decoding at this same batch shape.
    encode_batch: int = 0
    # v6 only: shared-prefix dictionary [(name, tokens int32)] that
    # RECIPE_SHARED entries index into.
    shared_prefixes: list[tuple[str, np.ndarray]] = field(
        default_factory=list)
    # v6 only: the context-length budget the encoder's model program ran
    # at. Like encode_batch, this is coding geometry: the decode cache is
    # sized chunk_size + ctx_budget positions, and on real models the
    # cache length changes the jitted program's reduction shapes (and so
    # the logits, bitwise) — every group must decode at the same length
    # every chunk was encoded at, context-free chunks included.
    ctx_budget: int = 0

    @property
    def codec_name(self) -> str:
        return CODEC_NAMES[self.codec]


def chunk_valid_lengths(n_tokens: int, chunk_size: int) -> np.ndarray:
    """Valid token count per chunk for a contiguous n_tokens stream.
    Zero tokens means zero chunks (an empty container has an empty body),
    so the returned array is empty — callers must not assume max()."""
    n_chunks = -(-n_tokens // chunk_size)
    ends = np.minimum(np.arange(1, n_chunks + 1) * chunk_size, n_tokens)
    starts = np.arange(n_chunks) * chunk_size
    return np.maximum(ends - starts, 0).astype(np.int64)


def read_header(blob: bytes) -> ContainerInfo:
    """Parse and validate the container header (any supported version)."""
    if len(blob) < 4 or blob[:4] != MAGIC:
        raise ContainerError("bad magic (not an LLMC container)")
    if len(blob) < 5:
        raise ContainerError("truncated container: missing version byte")
    version = blob[4]
    if version == 2:
        hdr = _V2_HEADER
    elif version == VERSION_V3 or version in _INDEXED_VERSIONS:
        hdr = _V3_HEADER
    else:
        raise ContainerError(f"unsupported container version {version}")
    hsize = 4 + struct.calcsize(hdr)
    if len(blob) < hsize:
        raise ContainerError(
            f"truncated container: {len(blob)} bytes < {hsize}-byte header")
    fields = struct.unpack(hdr, blob[4:hsize])
    if version == 2:
        _, flags, C, n, vocab, topk, precision = fields
        codec = CODEC_AC              # v2 archives predate the codec byte
    else:
        _, flags, C, n, vocab, topk, precision, codec = fields
        # the header byte names the container's LLM *entropy* codec;
        # fallback byte-codec ids (zstd/lzma/raw) are only legal in v5
        # per-chunk tags, never here
        if codec not in LLM_CODECS:
            raise ContainerError(f"unknown codec id {codec} in header "
                                 f"(entropy codec expected)")
    if C == 0:
        raise ContainerError("corrupt header: chunk_size is zero")
    # the *container's* codec decides which limits apply: a 24-bit-precision
    # AC container is legal, the same precision under rANS is not decodable
    if codec == CODEC_RANS and precision > rans.MAX_PRECISION:
        raise ContainerError(
            f"container precision {precision} exceeds rANS coder limit "
            f"{rans.MAX_PRECISION}")
    if precision < 1 or (1 << precision) <= (topk + 1 if topk else vocab):
        raise ContainerError(
            f"corrupt header: precision {precision} too small for "
            f"{'top-' + str(topk) if topk else 'vocab ' + str(vocab)} alphabet")
    n_chunks = -(-n // C)                # 0 tokens => 0 chunks
    return ContainerInfo(version, flags, C, n, vocab, topk, precision,
                         codec, hsize, n_chunks)


def _encode_prefix_dict(prefixes: list[tuple[str, np.ndarray]]) -> bytes:
    """Serialize the v6 shared-prefix dictionary: u16 count, then per
    prefix u8 name length | utf-8 name | u16 token count | u32 tokens."""
    out = bytearray(struct.pack("<H", len(prefixes)))
    for name, toks in prefixes:
        nb = name.encode("utf-8")
        toks = np.asarray(toks, np.int64).ravel()
        out += struct.pack("<B", len(nb)) + nb
        out += struct.pack("<H", toks.size)
        out += toks.astype("<u4").tobytes()
    return bytes(out)


def _parse_prefix_dict(buf: bytes,
                       vocab: int) -> list[tuple[str, np.ndarray]]:
    """Parse + validate the v6 shared-prefix dictionary section. The
    section must be consumed exactly — trailing garbage inside the
    hash-covered span is corruption, not padding."""
    if len(buf) < 2:
        raise ContainerError(
            "corrupt container: shared-prefix dictionary shorter than "
            "its count field")
    (n,) = struct.unpack_from("<H", buf, 0)
    pos = 2
    prefixes: list[tuple[str, np.ndarray]] = []
    for i in range(n):
        if pos + 1 > len(buf):
            raise ContainerError(
                f"corrupt container: shared prefix {i} truncated")
        name_len = buf[pos]
        pos += 1
        if pos + name_len + 2 > len(buf):
            raise ContainerError(
                f"corrupt container: shared prefix {i} truncated")
        try:
            name = buf[pos:pos + name_len].decode("utf-8")
        except UnicodeDecodeError:
            raise ContainerError(
                f"corrupt container: shared prefix {i} name is not utf-8")
        pos += name_len
        (nt,) = struct.unpack_from("<H", buf, pos)
        pos += 2
        if nt == 0:
            raise ContainerError(
                f"corrupt container: shared prefix {i} ({name!r}) is empty")
        if pos + 4 * nt > len(buf):
            raise ContainerError(
                f"corrupt container: shared prefix {i} claims {nt} tokens, "
                f"section ends early")
        toks = np.frombuffer(buf, dtype="<u4", count=nt,
                             offset=pos).astype(np.int32)
        pos += 4 * nt
        if toks.size and int(toks.max()) >= vocab:
            raise ContainerError(
                f"corrupt container: shared prefix {i} ({name!r}) has "
                f"token id {int(toks.max())} >= vocab {vocab}")
        prefixes.append((name, toks))
    if pos != len(buf):
        raise ContainerError(
            f"corrupt container: {len(buf) - pos} stray bytes after the "
            f"shared-prefix dictionary")
    return prefixes


def _check_recipe(i: int, kind: int, param: int, codec_tag: int,
                  n_prefixes: int) -> None:
    """Validate one chunk's context recipe against the format invariants
    (shared by read_index and write_container so they cannot drift)."""
    if kind == RECIPE_NONE:
        if param != 0:
            raise ContainerError(
                f"corrupt index: chunk {i} recipe none with param {param}")
        return
    if kind == RECIPE_CARRY:
        if param < 1:
            raise ContainerError(
                f"corrupt index: chunk {i} carry recipe with window 0")
        if i == 0:
            raise ContainerError(
                "corrupt index: chunk 0 cannot carry context "
                "(no previous chunk)")
    elif kind == RECIPE_SHARED:
        if param >= n_prefixes:
            raise ContainerError(
                f"corrupt index: chunk {i} shared-prefix recipe [{param}] "
                f"but the dictionary has {n_prefixes} entries")
    else:
        raise ContainerError(
            f"corrupt index: chunk {i} has unknown recipe kind {kind}")
    if codec_tag not in LLM_CODECS:
        raise ContainerError(
            f"corrupt index: chunk {i} is fallback-coded "
            f"({CODEC_NAMES.get(codec_tag, codec_tag)}) but declares a "
            f"context recipe — fallback chunks must be context-free")


def read_index(blob: bytes, info: ContainerInfo | None = None) -> ContainerInfo:
    """Parse + verify the v4/v5 index footer; returns info with
    ``entries`` populated. Verifies the footer checksum (which covers the
    header too) but not the per-chunk stream checksums — those are checked
    by ``parse_container``/``decompress_range`` for the chunks actually
    read. v5 entries additionally carry the per-chunk codec tag, validated
    here: a fallback id is fine, an LLM id must match the header's entropy
    codec (a v5 archive never mixes rANS and AC chunks)."""
    info = info or read_header(blob)
    if info.version == VERSION_V4:
        entry_fmt, entry_size, end_magic = \
            _V4_ENTRY, _V4_ENTRY_SIZE, _V4_END_MAGIC
    elif info.version == VERSION_V5:
        entry_fmt, entry_size, end_magic = \
            _V5_ENTRY, _V5_ENTRY_SIZE, _V5_END_MAGIC
    elif info.version == VERSION_V6:
        entry_fmt, entry_size, end_magic = \
            _V6_ENTRY, _V6_ENTRY_SIZE, _V6_END_MAGIC
    else:
        raise ContainerError(
            f"container version {info.version} has no index footer "
            f"(random access requires v4+)")
    if len(blob) < info.header_size + _V4_TRAILER:
        raise ContainerError("truncated container: missing index footer")
    if blob[-4:] != end_magic:
        raise ContainerError(
            f"truncated or corrupt container: "
            f"v{info.version} end magic missing")
    n_chunks_f, footer_len = struct.unpack("<II", blob[-12:-4])
    # v4/v5: entries + u32 encode_batch + u64 hash. v6 additionally holds
    # the variable-length shared-prefix dictionary between the entries
    # and the encode batch, and a u32 ctx_budget after it (all inside the
    # hash-covered span)
    min_len = n_chunks_f * entry_size \
        + (16 if info.version == VERSION_V6 else 12)
    if info.version == VERSION_V6:
        if footer_len < min_len:
            raise ContainerError(
                f"corrupt footer: length field {footer_len} < {min_len} "
                f"for {n_chunks_f} chunks")
        dict_len = footer_len - min_len
    else:
        if footer_len != min_len:
            raise ContainerError(
                f"corrupt footer: length field {footer_len} != {min_len} "
                f"for {n_chunks_f} chunks")
        dict_len = 0
    if n_chunks_f != info.n_chunks:
        raise ContainerError(
            f"corrupt container: footer indexes {n_chunks_f} chunks, header "
            f"implies {info.n_chunks}")
    footer_start = len(blob) - _V4_TRAILER - footer_len
    if footer_start < info.header_size:
        raise ContainerError("truncated container: footer overlaps header")
    entries_end = footer_start + n_chunks_f * entry_size
    data_end = entries_end + dict_len       # dict (v6) sits before the batch
    (encode_batch,) = struct.unpack("<I", blob[data_end:data_end + 4])
    ctx_budget = 0
    if info.version == VERSION_V6:
        (ctx_budget,) = struct.unpack("<I",
                                      blob[data_end + 4:data_end + 8])
        data_end += 4
    (footer_hash,) = struct.unpack("<Q",
                                   blob[data_end + 4:data_end + 12])
    if xxh64(blob[:info.header_size] + blob[footer_start:data_end + 4]) \
            != footer_hash:
        raise ContainerError("corrupt container: footer checksum mismatch "
                             "(header or index damaged)")
    if ctx_budget > MAX_PREFIX_TOKENS:
        raise ContainerError(
            f"corrupt footer: context budget {ctx_budget} exceeds "
            f"{MAX_PREFIX_TOKENS}")
    prefixes = _parse_prefix_dict(
        blob[entries_end:entries_end + dict_len], info.vocab) \
        if info.version == VERSION_V6 else []
    entries = []
    for i in range(n_chunks_f):
        rec = struct.unpack_from(entry_fmt, blob,
                                 footer_start + i * entry_size)
        rk = rp = 0
        if info.version == VERSION_V4:
            off, ln, nt, cks = rec
            ctag = info.codec
        else:
            if info.version == VERSION_V5:
                off, ln, nt, ctag, cks = rec
            else:
                off, ln, nt, ctag, rk, rp, cks = rec
            if ctag not in CODEC_NAMES:
                raise ContainerError(
                    f"corrupt index: chunk {i} has unknown codec id {ctag}")
            if ctag in LLM_CODECS and ctag != info.codec:
                raise ContainerError(
                    f"corrupt index: chunk {i} tagged entropy codec {ctag} "
                    f"but the container codec is {info.codec}")
        _check_recipe(i, rk, rp, ctag, len(prefixes))
        if nt > info.chunk_size:
            raise ContainerError(
                f"corrupt index: chunk {i} claims {nt} tokens "
                f"(chunk_size {info.chunk_size})")
        if off < info.header_size or off + ln > footer_start:
            raise ContainerError(
                f"corrupt index: chunk {i} stream [{off}, {off + ln}) "
                f"outside body [{info.header_size}, {footer_start})")
        entries.append(ChunkEntry(off, ln, nt, cks, ctag, rk, rp))
    if sum(e.n_tokens for e in entries) != info.n_tokens:
        raise ContainerError(
            "corrupt container: index token counts disagree with header "
            f"n_tokens {info.n_tokens}")
    # geometry floor law: the recorded budget must cover every recipe's
    # materialized context (a smaller value could never have been the
    # encoder's program length — the context wouldn't have fit)
    for i, e in enumerate(entries):
        need = 0
        if e.recipe_kind == RECIPE_CARRY:
            need = min(e.recipe_param, entries[i - 1].n_tokens)
        elif e.recipe_kind == RECIPE_SHARED:
            need = int(prefixes[e.recipe_param][1].size)
        if need > ctx_budget:
            raise ContainerError(
                f"corrupt footer: chunk {i} materializes a "
                f"{need}-token context but the recorded context "
                f"budget is {ctx_budget}")
    info.entries = entries
    info.encode_batch = encode_batch
    info.shared_prefixes = prefixes
    info.ctx_budget = ctx_budget
    return info


def parse_container(blob: bytes) -> tuple[ContainerInfo, list[bytes]]:
    """Full parse: header (+ index when v4/v5) + per-chunk streams, with
    all integrity checks. Returns (info-with-entries, streams). Every
    entry's ``codec`` is populated regardless of version, so downstream
    decode logic never special-cases the container version."""
    info = read_header(blob)
    if info.version in _INDEXED_VERSIONS:
        info = read_index(blob, info)
        # read_index validated the trailer's footer length, which for v6
        # includes the variable-size prefix dictionary — recover the body
        # end from it rather than recomputing entry sizes here
        (_, footer_len) = struct.unpack("<II", blob[-12:-4])
        body_end = len(blob) - _V4_TRAILER - footer_len
    else:
        body_end = len(blob)
    pos = info.header_size
    streams: list[bytes] = []
    valid = chunk_valid_lengths(info.n_tokens, info.chunk_size)
    for i in range(info.n_chunks):
        ln, pos = _read_varint(blob, pos, body_end)
        if pos + ln > body_end:
            raise ContainerError(
                f"truncated container: chunk {i} claims {ln} bytes, "
                f"{body_end - pos} remain")
        stream = blob[pos:pos + ln]
        if info.version in _INDEXED_VERSIONS:
            e = info.entries[i]
            if e.offset != pos or e.length != ln:
                raise ContainerError(
                    f"corrupt container: chunk {i} framing ({pos}, {ln}) "
                    f"disagrees with index ({e.offset}, {e.length})")
            if xxh64(stream) != e.checksum:
                raise ContainerError(
                    f"corrupt container: chunk {i} checksum mismatch")
        else:
            info.entries.append(ChunkEntry(pos, ln, int(valid[i]),
                                           codec=info.codec))
        streams.append(stream)
        pos += ln
    return info, streams


def write_container(streams: list[bytes], *, version: int, chunk_size: int,
                    n_tokens: int, vocab: int, topk: int, precision: int,
                    codec_id: int,
                    valid_lengths: np.ndarray | None = None,
                    encode_batch: int = 0,
                    codec_tags: list[int] | None = None,
                    recipes: list[tuple[int, int]] | None = None,
                    shared_prefixes: list[tuple[str, np.ndarray]]
                    | None = None,
                    ctx_budget: int = 0) -> bytes:
    """Assemble a v3..v6 container from per-chunk codec streams (in
    chunk order — the service scheduler completes chunks out of order and
    reorders before calling this). ``encode_batch`` (v4+) records the
    model-program lane count every LLM chunk was encoded at (ragged
    groups are dead-lane padded, never shrunk) — the batch shape a
    decoder must use for bit-exact logits on non-batch-invariant models.
    ``codec_tags`` (v5+) is the per-chunk codec id list the router chose;
    it defaults to the container codec for every chunk. ``recipes`` (v6)
    is the per-chunk (kind, param) context-recipe list, defaulting to
    fresh context everywhere; ``shared_prefixes`` (v6) is the dictionary
    RECIPE_SHARED params index into. ``ctx_budget`` (v6) records the
    context-length budget the encoder's model program ran at — the
    decode-cache geometry counterpart of ``encode_batch`` (it may exceed
    the written recipes' needs when routing flipped the longest-context
    chunk to a fallback, never undercut them). Passing a feature a lower
    version cannot represent is an error."""
    if version not in (VERSION_V3,) + _INDEXED_VERSIONS:
        raise ValueError(f"cannot write container version {version}")
    if codec_tags is not None:
        if len(codec_tags) != len(streams):
            raise ValueError(
                f"{len(codec_tags)} codec tags for {len(streams)} streams")
        if version < VERSION_V5 and any(t != codec_id for t in codec_tags):
            raise ValueError(
                f"per-chunk codec tags require a v5+ container "
                f"(got version {version})")
        for t in codec_tags:
            if t not in CODEC_NAMES:
                raise ValueError(f"unknown codec id {t} in codec_tags")
            if t in LLM_CODECS and t != codec_id:
                raise ValueError(
                    f"chunk tagged entropy codec {t} but the container "
                    f"codec is {codec_id}")
    shared_prefixes = shared_prefixes or []
    if version != VERSION_V6 and (shared_prefixes or (
            recipes is not None
            and any(r != (RECIPE_NONE, 0) for r in recipes))):
        raise ValueError(
            f"context recipes / shared prefixes require a v6 container "
            f"(got version {version})")
    if recipes is not None and len(recipes) != len(streams):
        raise ValueError(
            f"{len(recipes)} recipes for {len(streams)} streams")
    if len(shared_prefixes) > 0xFFFF:
        raise ValueError("too many shared prefixes (u16 count)")
    for name, toks in shared_prefixes:
        toks = np.asarray(toks).ravel()
        if not 1 <= toks.size <= MAX_PREFIX_TOKENS:
            raise ValueError(
                f"shared prefix {name!r} has {toks.size} tokens "
                f"(1..{MAX_PREFIX_TOKENS} allowed)")
        if len(name.encode("utf-8")) > MAX_PREFIX_NAME:
            raise ValueError(f"shared prefix name {name!r} too long")
        if toks.size and not 0 <= int(toks.min()) <= int(toks.max()) < vocab:
            raise ValueError(
                f"shared prefix {name!r} has token ids outside "
                f"[0, {vocab})")
    if version != VERSION_V6 and ctx_budget:
        raise ValueError(
            f"context budget requires a v6 container (got version "
            f"{version})")
    if not 0 <= ctx_budget <= MAX_PREFIX_TOKENS:
        raise ValueError(
            f"context budget {ctx_budget} outside [0, {MAX_PREFIX_TOKENS}]")
    if version == VERSION_V6 and recipes is not None:
        for i, (rk, rp) in enumerate(recipes):
            tag = codec_id if codec_tags is None else codec_tags[i]
            _check_recipe(i, rk, rp, tag, len(shared_prefixes))
            if rk == RECIPE_CARRY and rp > 0xFFFF:
                raise ValueError(
                    f"chunk {i} carry window {rp} exceeds u16")
        vl = valid_lengths if valid_lengths is not None \
            else chunk_valid_lengths(n_tokens, chunk_size)
        need = context_budget(
            recipes, np.asarray(vl),
            [(nm, np.asarray(t).ravel()) for nm, t in shared_prefixes])
        if need > ctx_budget:
            raise ValueError(
                f"recipes materialize a {need}-token context but "
                f"ctx_budget is {ctx_budget}")
    flags = 1 if topk else 0
    out = bytearray()
    out += MAGIC
    out += struct.pack(_V3_HEADER, version, flags, chunk_size, n_tokens,
                       vocab, topk, precision, codec_id)
    header = bytes(out)
    if valid_lengths is None:
        valid_lengths = chunk_valid_lengths(n_tokens, chunk_size)
    indexed = version in _INDEXED_VERSIONS
    entries = bytearray()
    for i, (s, nv) in enumerate(zip(streams, valid_lengths)):
        _write_varint(out, len(s))
        if version == VERSION_V4:   # v3 skips the index + per-stream hash
            entries += struct.pack(_V4_ENTRY, len(out), len(s), int(nv),
                                   xxh64(s))
        elif version == VERSION_V5:
            tag = codec_id if codec_tags is None else codec_tags[i]
            entries += struct.pack(_V5_ENTRY, len(out), len(s), int(nv),
                                   tag, xxh64(s))
        elif version == VERSION_V6:
            tag = codec_id if codec_tags is None else codec_tags[i]
            rk, rp = (RECIPE_NONE, 0) if recipes is None else recipes[i]
            entries += struct.pack(_V6_ENTRY, len(out), len(s), int(nv),
                                   tag, rk, rp, xxh64(s))
        out += s
    if indexed:
        tail = bytes(entries)
        if version == VERSION_V6:
            tail += _encode_prefix_dict(shared_prefixes)
        tail += struct.pack("<I", encode_batch)
        if version == VERSION_V6:
            tail += struct.pack("<I", ctx_budget)
        footer_hash = xxh64(header + tail)
        out += tail
        out += struct.pack("<Q", footer_hash)
        out += struct.pack("<II", len(streams), len(tail) + 8)
        out += {VERSION_V4: _V4_END_MAGIC, VERSION_V5: _V5_END_MAGIC,
                VERSION_V6: _V6_END_MAGIC}[version]
    return bytes(out)


def check_container_config(info: ContainerInfo, *, vocab: int,
                           chunk_size: int, topk: int,
                           precision: int) -> None:
    """Raise ContainerError unless the container's coding geometry matches
    the decoder's configuration — shared by the grouped compressor and the
    service so the two validation paths cannot drift."""
    if info.vocab != vocab or info.chunk_size != chunk_size \
            or info.topk != topk or info.precision != precision:
        raise ContainerError(
            "compressor configuration mismatch with container "
            f"(container: vocab={info.vocab} chunk={info.chunk_size} "
            f"topk={info.topk} precision={info.precision})")


def assign_context_recipes(n_chunks: int, *, context_window: int = 0,
                           stripes: int = 1,
                           shared: bool = False) -> list[tuple[int, int]]:
    """The writer-side recipe plan: split ``n_chunks`` into ``stripes``
    contiguous carry chains. Each stripe's first chunk starts fresh
    (RECIPE_SHARED when a shared prefix is in play, RECIPE_NONE
    otherwise) and every later chunk carries the previous chunk's
    ``context_window``-token tail. Striping is what keeps decode
    parallel: one lane per chain, chains decode round-robin, so carry
    never serializes the whole archive. With ``context_window == 0``
    every chunk starts fresh (all-shared when ``shared``)."""
    head = (RECIPE_SHARED, 0) if shared else (RECIPE_NONE, 0)
    if context_window <= 0:
        return [head] * n_chunks
    stripes = max(1, min(int(stripes), n_chunks)) if n_chunks else 1
    q, r = divmod(n_chunks, stripes)
    recipes: list[tuple[int, int]] = []
    for b in range(stripes):
        ln = q + (1 if b < r else 0)
        if ln:
            recipes.append(head)
            recipes.extend([(RECIPE_CARRY, context_window)] * (ln - 1))
    return recipes


def recipe_context(recipes, chunks: np.ndarray, valid: np.ndarray, j: int,
                   shared_prefixes) -> np.ndarray:
    """Materialize chunk ``j``'s declared context from the *input* side
    (encode: all chunk tokens are known). Returns an int32 token vector,
    possibly empty."""
    kind, param = recipes[j]
    if kind == RECIPE_CARRY:
        prev = chunks[j - 1, :int(valid[j - 1])]
        return prev[max(0, prev.size - param):].astype(np.int32)
    if kind == RECIPE_SHARED:
        return np.asarray(shared_prefixes[param][1], np.int32)
    return np.zeros(0, np.int32)


def context_budget(recipes, valid, shared_prefixes) -> int:
    """The decode-length budget a recipe plan needs: the longest context
    any chunk materializes (carry windows clamp to the predecessor's
    valid length; shared recipes take the full dictionary prefix). The
    model program is sized chunk_size + budget positions for EVERY group
    of the archive — cache length is coding geometry, so one length must
    cover them all — and the v6 footer records it (``ctx_budget``)."""
    budget = 0
    for j, (kind, param) in enumerate(recipes):
        if kind == RECIPE_CARRY:
            budget = max(budget, min(int(param), int(valid[j - 1])))
        elif kind == RECIPE_SHARED:
            budget = max(budget,
                         int(np.asarray(shared_prefixes[param][1]).size))
    return budget


def container_is_model_free(info: ContainerInfo) -> bool:
    """True when every chunk is fallback-coded — such an archive decodes
    (and range-decodes) without constructing a predictor at all."""
    return bool(info.entries) and all(not e.is_llm for e in info.entries)


def _decode_fallback(idx: int, entry: ChunkEntry, stream: bytes,
                     vocab: int) -> np.ndarray:
    """Decode one fallback-tagged chunk stream; structural problems
    become ContainerError (the stream passed its checksum, so any
    failure here means a crafted/mis-tagged container)."""
    try:
        return CodecRouter.decode_fallback(entry.codec_name, stream,
                                           entry.n_tokens, vocab)
    except ValueError as e:
        raise ContainerError(f"corrupt container: chunk {idx}: {e}")


def decompress_model_free(blob: bytes) -> np.ndarray:
    """Decode an all-fallback v5/v6 archive without a model: no
    predictor, no prefix cache, no device dispatch. Raises
    ContainerError if any chunk is LLM-coded (those need a predictor)."""
    info, streams = parse_container(blob)
    if info.n_chunks == 0:
        return np.zeros(0, np.int32)
    if not container_is_model_free(info):
        raise ContainerError(
            "container has LLM-coded chunks; model-free decode needs an "
            "all-fallback archive")
    out = np.zeros(info.n_tokens, np.int32)
    C = info.chunk_size
    for i, e in enumerate(info.entries):
        out[i * C:i * C + e.n_tokens] = _decode_fallback(
            i, e, streams[i], info.vocab)
    return out


def check_chunk_range(info: ContainerInfo, chunk_start: int,
                      chunk_stop: int) -> None:
    """Bounds-validate a [chunk_start, chunk_stop) range request."""
    if chunk_start >= chunk_stop:
        raise ContainerError(
            f"invalid chunk range [{chunk_start}, {chunk_stop}): "
            + ("empty" if chunk_start == chunk_stop else "reversed")
            + " range selects no chunks")
    if chunk_start < 0 or chunk_stop > info.n_chunks:
        raise ContainerError(
            f"chunk range [{chunk_start}, {chunk_stop}) out of bounds: "
            f"container has chunks [0, {info.n_chunks})")


def decompress_range_model_free(blob: bytes, chunk_start: int,
                                chunk_stop: int | None = None) -> np.ndarray:
    """Range-decode chunks [chunk_start, chunk_stop) of an archive where
    every *requested* chunk is fallback-coded, without a model. Fallback
    chunks always carry recipe ``none`` (enforced at read and write), so
    no carry closure can pull in an LLM chunk."""
    info = read_index(blob)
    if chunk_stop is None:
        chunk_stop = chunk_start + 1
    check_chunk_range(info, chunk_start, chunk_stop)
    parts = []
    for j in range(chunk_start, chunk_stop):
        e = info.entries[j]
        if e.is_llm:
            raise ContainerError(
                f"chunk {j} is LLM-coded; model-free range decode needs "
                f"fallback-coded chunks")
        s = blob[e.offset:e.offset + e.length]
        if xxh64(s) != e.checksum:
            raise ContainerError(
                f"corrupt container: chunk {j} checksum mismatch")
        parts.append(_decode_fallback(j, e, s, info.vocab))
    return np.concatenate(parts) if parts else np.zeros(0, np.int32)


@dataclass
class CompressionStats:
    n_tokens: int = 0
    payload_bytes: int = 0
    header_bytes: int = 0
    n_escapes: int = 0
    ideal_bits: float = 0.0  # -sum log2 p from the un-quantized model
    # per-chunk obs.ChunkDiagnostics (DESIGN.md §10) — populated when the
    # compressor's registry is enabled; empty otherwise. This is the
    # signal the ROADMAP's adaptive codec router consumes: bits/token and
    # escape rate per chunk, previously computed and thrown away.
    chunks: list = field(default_factory=list)
    # per-chunk router.RouteDecision records (routed compressors only) —
    # the encode-side story of every codec tag written to the v5 index.
    routes: list = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.header_bytes


class LLMCompressor:
    """Chunked LLM-predictor + entropy-coding lossless compressor."""

    def __init__(self, predictor: PredictorAdapter, *,
                 chunk_size: int = 256,
                 topk: int = 0,
                 precision: int = DEFAULT_PRECISION,
                 decode_batch: int = 64,
                 codec: str = "rans",
                 container_version: int | None = None,
                 route: str = ROUTE_LLM,
                 router: CodecRouter | RouterConfig | None = None,
                 draft_k: int = 0,
                 draft=None,
                 context_window: int = 0,
                 context_stripes: int | None = None,
                 shared_prefix: np.ndarray | None = None,
                 shared_prefix_name: str = "shared",
                 registry: obs.MetricsRegistry | None = None):
        if topk and topk >= predictor.vocab_size:
            topk = 0
        if codec not in CODEC_IDS:
            raise ValueError(f"unknown codec {codec!r} "
                             f"(choose from {sorted(CODEC_IDS)})")
        if route not in (ROUTE_LLM, ROUTE_AUTO) \
                and route not in FALLBACK_CODEC_IDS:
            raise ValueError(
                f"unknown route {route!r} (choose 'llm', 'auto', or a "
                f"fallback codec from {sorted(FALLBACK_CODEC_IDS)})")
        self.context_window = int(context_window)
        self.context_stripes = None if context_stripes is None \
            else int(context_stripes)
        if self.context_window < 0 or self.context_window > 0xFFFF:
            raise ValueError(
                f"context_window {context_window} outside [0, 65535]")
        if shared_prefix is not None:
            shared_prefix = np.asarray(shared_prefix,
                                       np.int32).ravel()
            if not 1 <= shared_prefix.size <= MAX_PREFIX_TOKENS:
                raise ValueError(
                    f"shared_prefix has {shared_prefix.size} tokens "
                    f"(1..{MAX_PREFIX_TOKENS} allowed)")
            if not 0 <= int(shared_prefix.min()) \
                    <= int(shared_prefix.max()) < predictor.vocab_size:
                raise ValueError("shared_prefix token ids outside vocab")
        self.shared_prefix = shared_prefix
        self.shared_prefix_name = str(shared_prefix_name)
        ctx_on = self.context_window > 0 or shared_prefix is not None
        # routing needs per-chunk codec tags (v5+); context recipes need
        # v6; a plain pure-LLM compressor defaults to the wire-minimal v3
        if container_version is None:
            if ctx_on:
                container_version = VERSION_V6
            elif route == ROUTE_LLM:
                container_version = VERSION_V3
            else:
                container_version = VERSION_V5
        if container_version not in (VERSION_V3,) + _INDEXED_VERSIONS:
            raise ValueError(f"cannot write container version "
                             f"{container_version} (v2 is read-only)")
        if route != ROUTE_LLM and container_version < VERSION_V5:
            raise ValueError(
                f"route={route!r} requires a v5+ container (per-chunk "
                f"codec tags); cannot write v{container_version}")
        if ctx_on and container_version != VERSION_V6:
            raise ValueError(
                f"context_window/shared_prefix require a v6 container "
                f"(per-chunk context recipes); cannot write "
                f"v{container_version}")
        self._ctx_on = ctx_on
        self._prefix_ok = None      # lazy: begin_decode accepts prefix=?
        self.route = route
        if isinstance(router, CodecRouter):
            self.router = router
        elif isinstance(router, RouterConfig):
            self.router = CodecRouter(router)
        elif route in FALLBACK_CODEC_IDS:
            self.router = CodecRouter(RouterConfig(fallbacks=(route,)))
        else:
            self.router = CodecRouter()
        self.predictor = predictor
        self.chunk_size = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self.decode_batch = int(decode_batch)
        self.codec = codec
        self.container_version = int(container_version)
        if (1 << precision) <= (topk + 1 if topk else predictor.vocab_size):
            raise ValueError("precision too small for alphabet")
        # only the rANS backend caps precision (AC handles up to 30 bits);
        # decoding a foreign-codec container never hits the encoder limit
        if codec == "rans" and precision > rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} exceeds rANS coder "
                             f"limit {rans.MAX_PRECISION}")
        # escape symbols: AC codes exactly over V; rANS over 2**esc_bits >= V
        self._esc_bits = rans.uniform_bits(predictor.vocab_size)
        # Speculative decompression (DESIGN.md §9): draft_k > 0 turns on
        # the draft/verify/accept decode path for rANS containers when the
        # predictor exposes verify_steps/rollback (serve.ModelPredictor and
        # the table predictors do). Decoded tokens are identical either
        # way — the coded stream arbitrates every position — so this is
        # purely a wall-clock knob.
        self.draft_k = int(draft_k)
        self.draft = draft if draft is not None else SuffixDraft()
        # adaptive fallthrough: after _spec_window rounds, drop to
        # lock-step for the rest of the group if fewer than _spec_floor
        # drafted tokens per round were accepted (adversarial or
        # unpredictable streams must never pay the (K+1)-deep verify
        # forward for a 1-token/round yield indefinitely)
        self._spec_window = 8
        self._spec_floor = 0.75
        # telemetry (DESIGN.md §10): defaults to the process-global
        # registry; inject a private MetricsRegistry to isolate. Strictly
        # read-only with respect to output bytes (property-tested).
        self._registry = registry if registry is not None else obs.registry()
        self._c_cmp_tokens = self._registry.counter(
            "compress.tokens", "tokens entropy-coded (compress side)")
        self._c_cmp_escapes = self._registry.counter(
            "compress.escapes", "escape symbols emitted while encoding")
        self._c_dec_tokens = self._registry.counter(
            "decompress.tokens", "tokens entropy-decoded")
        self._c_dec_escapes = self._registry.counter(
            "decompress.escapes", "escape symbols hit while decoding")
        # router decision counters (canonical names: obs.metrics.ROUTER_*)
        self._c_route_llm = self._registry.counter(
            obs.ROUTER_CHUNKS_LLM, "chunks routed to the LLM entropy path")
        self._c_route_fb = self._registry.counter(
            obs.ROUTER_CHUNKS_FALLBACK,
            "chunks routed to a fallback byte codec")
        self._c_route_skips = self._registry.counter(
            obs.ROUTER_PROBE_SKIPS,
            "chunks that skipped LLM encode on the probe estimate")
        self._c_route_flips = self._registry.counter(
            obs.ROUTER_FLIPS,
            "chunks where LLM encode ran but the fallback stream won")

    # ------------------------------------------------------------- compress
    def compress(self, tokens: np.ndarray, *,
                 exact: bool = True) -> tuple[bytes, CompressionStats]:
        """Compress a token stream.

        exact=True (default) scores with the *decode program* (the same
        jitted step the decompressor runs), guaranteeing bit-identical CDFs
        on both sides — the lossless requirement. exact=False scores with
        the teacher-forced prefill pass: ~C× fewer model invocations and
        identical in exact arithmetic, but float reduction-order
        differences between the prefill and decode programs can flip a
        quantization bucket on rare tokens, so it is reserved for ratio
        estimation / benchmarking (see DESIGN.md §6).

        With ``route != "llm"`` (DESIGN.md §11) each chunk is first
        offered to the router: the realized best-fallback stream is
        always built, a cheap prefill probe estimates the LLM code
        length, chunks the probe rejects skip the model entirely, and
        every chunk that *was* LLM-encoded still flips to its fallback if
        the fallback stream turned out smaller — so the routed container
        is per-chunk min(LLM, best fallback) and decode follows the
        recorded tags. Only the LLM subset enters the model batch; the
        recorded encode lane count covers exactly those chunks (lane
        *composition* is free — lanes are independent — so later flips
        don't invalidate it).
        """
        tokens = np.asarray(tokens, dtype=np.int32).ravel()
        n = tokens.size
        C = self.chunk_size
        n_chunks = -(-n // C)            # 0 tokens => 0 chunks, no model
        padded = np.zeros(n_chunks * C, dtype=np.int32)
        padded[:n] = tokens
        chunks = padded.reshape(n_chunks, C)
        valid_all = chunk_valid_lengths(n, C)

        stats = CompressionStats(n_tokens=n)
        streams: list = [b""] * n_chunks
        tags = [CODEC_IDS[self.codec]] * n_chunks
        if self.route == ROUTE_LLM:
            decisions = fb = None
            llm_idx = list(range(n_chunks))
        else:
            decisions, fb = self._route_chunks(chunks, valid_all)
            llm_idx = [i for i, d in enumerate(decisions)
                       if d.codec == self.codec]
        recipes = None
        cb = 0
        if self._ctx_on and n_chunks:
            recipes = assign_context_recipes(
                n_chunks, context_window=self.context_window,
                stripes=min(self.context_stripes or self.decode_batch,
                            n_chunks),
                shared=self.shared_prefix is not None)
            # decode-length geometry for the whole archive (recorded in
            # the footer): computed from the pre-routing plan, since that
            # is the budget every group — flips included — encoded under
            cb = context_budget(recipes, valid_all,
                                self._shared_prefix_list())
        # The model program runs at ONE lane count for the whole archive:
        # batch shape is coding geometry (XLA reduction order varies with
        # B), so a ragged tail group is padded with dead lanes rather than
        # shrinking the program — and the count recorded in the v4+ footer
        # is therefore exactly what every LLM chunk was encoded at.
        with obs.span("compress.job", self._registry):
            if recipes is not None:
                # carried/shared context always scores through the decode
                # program — the declared context must be consumed exactly
                # the way decode will consume it
                B = self._compress_carried(chunks, valid_all, recipes,
                                           llm_idx, streams, stats, cb)
            else:
                B = min(self.decode_batch, len(llm_idx))
                for g in range(0, len(llm_idx), max(1, B)):
                    sel = llm_idx[g:g + B]
                    batch = chunks[sel]
                    nb = len(sel)
                    if nb < B:
                        batch = np.concatenate(
                            [batch, np.zeros((B - nb, C), np.int32)])
                    if exact:
                        with obs.span("compress.score", self._registry):
                            logits = self._score_incremental(batch)
                    else:
                        logits = np.asarray(
                            self.predictor.score_chunks(batch))
                    enc = self._encode_batch(batch[:nb], logits[:nb],
                                             valid_all[sel], sel, stats)
                    for k, j in enumerate(sel):
                        streams[j] = enc[k]
        if decisions is not None:
            self._apply_routes(decisions, fb, streams, tags, valid_all,
                               stats)
        if recipes is not None:
            # a fallback-coded chunk never consumes model context: its
            # recipe is erased so all-fallback archives stay model-free
            # (carry successors still reference its *tokens*, which decode
            # materializes host-side)
            recipes = [r if tags[i] in LLM_CODECS else (RECIPE_NONE, 0)
                       for i, r in enumerate(recipes)]
            self._annotate_context(stats, recipes)
        self._c_cmp_tokens.inc(n)
        self._c_cmp_escapes.inc(stats.n_escapes)
        self._registry.counter("compress.chunks").inc(n_chunks)
        blob = write_container(
            streams, version=self.container_version, chunk_size=C,
            n_tokens=n, vocab=self.predictor.vocab_size, topk=self.topk,
            precision=self.precision, codec_id=CODEC_IDS[self.codec],
            encode_batch=B,
            codec_tags=tags if self.container_version >= VERSION_V5
            else None,
            recipes=recipes,
            shared_prefixes=self._shared_prefix_list()
            if self.container_version == VERSION_V6 else None,
            ctx_budget=cb)
        stats.payload_bytes = sum(len(s) for s in streams)
        stats.header_bytes = len(blob) - stats.payload_bytes
        return blob, stats

    # -------------------------------------------------------------- routing
    def _route_chunks(self, chunks, valid_all):
        """Route decisions + realized fallback streams for every chunk.
        Forced-fallback routes (``route="zstd"`` etc.) skip the probe:
        every chunk goes to its best fallback. ``route="auto"`` runs one
        prefill probe over the first ``probe_tokens`` positions of all
        chunks and keeps the LLM path unless it is projected to lose by
        more than the safety margin."""
        with obs.span("compress.route", self._registry):
            return route_chunks(self.router, self.predictor, chunks,
                                valid_all, self.codec,
                                auto=self.route == ROUTE_AUTO)

    def _apply_routes(self, decisions, fb, streams, tags, valid_all,
                      stats) -> None:
        """Post-encode routing resolution: install fallback streams for
        probe-skipped / forced chunks, and flip any LLM-encoded chunk
        whose realized fallback stream is strictly smaller. Updates
        streams/tags in place and finalizes per-chunk diagnostics."""
        tel = self._registry.enabled
        by_idx = {d.chunk_index: d for d in stats.chunks}
        for i, d in enumerate(decisions):
            name, s = fb[i]
            if d.codec == self.codec and d.llm_bits_est >= 0:
                # probe-vs-realized calibration (adaptive skip margin):
                # observations land after this job's decisions were all
                # made, steering the *next* job's probe threshold
                self.router.observe(d.llm_bits_est,
                                    8.0 * len(streams[i]), len(s))
            if d.codec != self.codec:       # LLM encode never ran
                streams[i] = s
                tags[i] = FALLBACK_CODEC_IDS[name]
                self._c_route_fb.inc()
                if d.llm_bits_est >= 0:     # auto probe said skip
                    self._c_route_skips.inc()
                if tel:
                    stats.chunks.append(obs.ChunkDiagnostics(
                        chunk_index=i, n_tokens=int(valid_all[i]),
                        stream_bytes=len(s), coded_bits=8.0 * len(s),
                        codec=name))
            elif len(s) < len(streams[i]):  # LLM ran and lost: flip
                d.codec, d.flipped = name, True
                streams[i] = s
                tags[i] = FALLBACK_CODEC_IDS[name]
                self._c_route_fb.inc()
                self._c_route_flips.inc()
                if tel and i in by_idx:
                    dg = by_idx[i]
                    dg.codec, dg.stream_bytes = name, len(s)
                    dg.coded_bits = 8.0 * len(s)
            else:
                self._c_route_llm.inc()
        stats.routes = decisions
        stats.chunks.sort(key=lambda c: c.chunk_index)

    def _shared_prefix_list(self) -> list[tuple[str, np.ndarray]]:
        if self.shared_prefix is None:
            return []
        return [(self.shared_prefix_name, self.shared_prefix)]

    def _annotate_context(self, stats, recipes) -> None:
        """Stamp the final per-chunk recipe into diagnostics (v6 only;
        the field stays absent from v2-v5 sidecars)."""
        if not self._registry.enabled:
            return
        for d in stats.chunks:
            rk, rp = recipes[d.chunk_index]
            d.context = ChunkEntry(0, 0, 0, recipe_kind=rk,
                                   recipe_param=rp).recipe_name \
                if rk != RECIPE_NONE else ""

    def _compress_carried(self, chunks, valid_all, recipes, llm_idx,
                          streams, stats, budget: int = 0) -> int:
        """Encode under context recipes: chains (one per stripe) advance
        round-robin, one chunk per lane per round, each lane's model
        input being the self-contained [BOS, context, chunk] sequence its
        recipe declares. Probe-routed fallback chunks never enter the
        model — their lane is dead for that round (lanes are independent,
        so a dead lane can't perturb live ones). Returns the lane count
        recorded as the archive's encode batch."""
        n_chunks, C = chunks.shape
        llm = set(llm_idx)
        chains: list[list[int]] = []
        for j in range(n_chunks):
            if recipes[j][0] == RECIPE_CARRY and chains:
                chains[-1].append(j)
            else:
                chains.append([j])
        prefixes = self._shared_prefix_list()
        B = min(self.context_stripes or self.decode_batch, len(chains))
        for blk in range(0, len(chains), B):
            block = chains[blk:blk + B]
            for r in range(max(len(c) for c in block)):
                sel = [(lane, c[r]) for lane, c in enumerate(block)
                       if r < len(c) and c[r] in llm]
                if not sel:
                    continue
                batch = np.zeros((B, C), np.int32)
                ctx_rows: list = [None] * B
                for lane, j in sel:
                    batch[lane] = chunks[j]
                    ctx_rows[lane] = recipe_context(
                        recipes, chunks, valid_all, j, prefixes)
                L = max(c.size for c in ctx_rows if c is not None)
                ctx = ctx_len = None
                if L:
                    ctx = np.zeros((B, L), np.int32)
                    ctx_len = np.zeros(B, np.int64)
                    for lane, _ in sel:
                        c = ctx_rows[lane]
                        ctx[lane, :c.size] = c
                        ctx_len[lane] = c.size
                live = np.zeros(B, bool)
                live[[lane for lane, _ in sel]] = True
                with obs.span("compress.score", self._registry):
                    logits = self._score_incremental(batch, ctx, ctx_len,
                                                     live, budget)
                rows = [lane for lane, _ in sel]
                idxs = [j for _, j in sel]
                enc = self._encode_batch(batch[rows], logits[rows],
                                         valid_all[idxs], idxs, stats)
                for k, j in enumerate(idxs):
                    streams[j] = enc[k]
        return B

    def _accepts_prefix(self) -> bool:
        """Does predictor.begin_decode take a ``prefix`` keyword? (The
        fast prefill path — one scan dispatch instead of L decode
        steps. Detected once by signature; adapters without it get the
        token-at-a-time fallback, which is bit-identical.)"""
        if self._prefix_ok is None:
            try:
                self._prefix_ok = "prefix" in inspect.signature(
                    self.predictor.begin_decode).parameters
            except (TypeError, ValueError):
                self._prefix_ok = False
        return self._prefix_ok

    def _score_incremental(self, batch: np.ndarray, ctx=None, ctx_len=None,
                           live=None, budget: int = 0) -> np.ndarray:
        """Teacher-forced scoring through the decode program: one call to
        the decompressor's own jitted step per position, ground-truth token
        fed back. Bit-exact with decompression by construction. With
        ``ctx`` (B, L) / ``ctx_len`` (B,), each lane first consumes its
        declared context — via the predictor's prefix prefill when
        supported and the context is lane-uniform, else fed token by
        token with per-lane offsets."""
        B, C = batch.shape
        state, prev, consumed = self._begin_group(B, C, ctx, ctx_len, live,
                                                  budget)
        logits = np.zeros((B, C, self.predictor.vocab_size), np.float32)
        if ctx is None or consumed.any():
            # fresh context, or the prefix was prefilled device-side —
            # every lane codes position t at step t
            for t in range(C):
                lg, state = self.predictor.decode_step(state, prev)
                logits[:, t] = lg
                prev = batch[:, t]
            return logits
        cl = np.asarray(ctx_len, np.int64)
        lanes = np.arange(B)
        for s in range(int(cl.max(initial=0)) + C):
            lg, state = self.predictor.decode_step(state, prev)
            t = s - cl                       # per-lane chunk position
            m = (t >= 0) & (t < C)
            rows = np.nonzero(m)[0]
            logits[rows, t[rows]] = lg[rows]
            nxt = np.where(m, batch[lanes, np.clip(t, 0, C - 1)], prev)
            pf = s < cl                      # lanes still consuming context
            if pf.any():
                nxt[pf] = ctx[pf, s]
            prev = nxt.astype(np.int32)
        return logits

    # -------------------------------------------------------------- encode
    def _encode_batch(self, batch, logits, valid, chunk_indices, stats):
        """Entropy-encode one (nb, C) batch. ``valid`` is the per-row
        valid-token count and ``chunk_indices`` the rows' absolute chunk
        ids (the routed path encodes a non-contiguous LLM subset, so
        neither is derivable from an offset anymore)."""
        valid = np.asarray(valid, np.int64)
        ideal_rows = self._accumulate_ideal_bits(batch, logits, valid,
                                                 stats)
        if self.codec == "rans":
            streams, bits_rows, esc_rows = self._encode_batch_rans(
                batch, logits, valid, stats)
        else:
            streams, bits_rows, esc_rows = self._encode_batch_ac(
                batch, logits, valid, stats)
        if self._registry.enabled:
            h = self._registry.histogram(
                "chunk.bits_per_token",
                "realized payload bits/token per chunk")
            for b, s in enumerate(streams):
                d = obs.ChunkDiagnostics(
                    chunk_index=int(chunk_indices[b]),
                    n_tokens=int(valid[b]),
                    stream_bytes=len(s),
                    coded_bits=float(bits_rows[b]),
                    ideal_bits=float(ideal_rows[b]),
                    n_escapes=int(esc_rows[b]),
                    codec=self.codec)
                stats.chunks.append(d)
                h.observe(d.bits_per_token)
        return streams

    def _accumulate_ideal_bits(self, batch, logits, valid, stats):
        """Accumulate the un-quantized model cross-entropy into ``stats``;
        returns the per-chunk row sums (bits) for diagnostics."""
        lp = logits.astype(np.float64)
        lp -= lp.max(axis=-1, keepdims=True)
        lse = np.log(np.exp(lp).sum(axis=-1))
        tok_lp = np.take_along_axis(lp, batch[..., None].astype(np.int64),
                                    axis=-1)[..., 0]
        m = np.arange(batch.shape[1])[None, :] < valid[:, None]
        rows = ((lse - tok_lp) * m).sum(axis=1) / np.log(2.0)
        stats.ideal_bits += float(rows.sum())
        return rows

    def _encode_batch_rans(self, batch, logits, valid, stats):
        """All B chunk-streams advance through one vectorized coder step
        per token position: vectorized top-K slot lookup, masked escape
        steps, and a single LIFO flush in finish()."""
        B, C = batch.shape
        enc = rans.BatchedRansEncoder(B)
        pos = np.arange(C)[None, :] < valid[:, None]          # (B, C) active
        tel = self._registry.enabled
        bits_rows = np.zeros(B, np.float64)
        esc_rows = np.zeros(B, np.int64)
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)            # (B,C,K),(B,C,K+2)
            match = ids == batch[..., None]
            has = match.any(axis=-1)
            slots = np.where(has, match.argmax(axis=-1), self.topk)
            starts = np.take_along_axis(cdfs, slots[..., None],
                                        axis=-1)[..., 0]
            ends = np.take_along_axis(cdfs, slots[..., None] + 1,
                                      axis=-1)[..., 0]
            esc_rows = (~has & pos).sum(axis=1)
            stats.n_escapes += int(esc_rows.sum())
            if tel:   # quantized code length per chunk (diagnostics only)
                fr = np.maximum((ends - starts).astype(np.float64), 1.0)
                bits_rows = ((self.precision - np.log2(fr)) * pos) \
                    .sum(axis=1) + esc_rows * self._esc_bits
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                enc.put(starts[:, t], ends[:, t] - starts[:, t],
                        self.precision, m)
                em = m & ~has[:, t]
                if em.any():
                    enc.put_uniform(batch[:, t], self._esc_bits, em)
        else:
            # per-position CDFs: a (B, C, V+1) int64 tensor would be tens
            # of GB at production vocab sizes, so quantize one (B, V+1)
            # slab per step — same shape the decode path uses
            lanes = np.arange(B)
            syms_all = batch.astype(np.int64)
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                cdfs = logits_to_cdf(logits[:, t], self.precision)
                enc.put_symbols(batch[:, t].astype(np.int64), cdfs,
                                self.precision, m)
                if tel:
                    sy = syms_all[:, t]
                    fr = np.maximum(
                        (cdfs[lanes, sy + 1] - cdfs[lanes, sy])
                        .astype(np.float64), 1.0)
                    bits_rows += (self.precision - np.log2(fr)) * m
        return enc.finish(), bits_rows, esc_rows

    def _encode_batch_ac(self, batch, logits, valid, stats):
        """Legacy per-stream arithmetic-coding loops (reference codec)."""
        V = self.predictor.vocab_size
        streams = []
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)
        esc_rows = np.zeros(batch.shape[0], np.int64)
        for b in range(batch.shape[0]):
            enc = ac.ArithmeticEncoder()
            for t in range(int(valid[b])):
                sym = int(batch[b, t])
                if self.topk:
                    slot = np.nonzero(ids[b, t] == sym)[0]
                    if slot.size:
                        enc.encode(int(slot[0]), cdfs[b, t])
                    else:  # escape, then uniform over the full vocab
                        stats.n_escapes += 1
                        esc_rows[b] += 1
                        enc.encode(self.topk, cdfs[b, t])
                        enc.encode(sym, ac.uniform_cdf(V))
                else:
                    cdf = logits_to_cdf(logits[b, t], self.precision)
                    enc.encode(sym, cdf)
            streams.append(enc.finish() if valid[b] else b"")
        # the AC path is the legacy reference: stream bytes supply
        # bits/token in diagnostics, quantized code length is not accrued
        return streams, np.zeros(batch.shape[0], np.float64), esc_rows

    # ----------------------------------------------------------- decompress
    def _check_config(self, info: ContainerInfo) -> None:
        check_container_config(info, vocab=self.predictor.vocab_size,
                               chunk_size=self.chunk_size, topk=self.topk,
                               precision=self.precision)

    def decompress(self, blob: bytes) -> np.ndarray:
        info, streams = parse_container(blob)
        self._check_config(info)
        if info.n_chunks == 0:           # valid empty container
            return np.zeros(0, np.int32)
        if any(e.recipe_kind != RECIPE_NONE for e in info.entries):
            return self._decompress_carried(info, streams)
        if any(not e.is_llm for e in info.entries):
            return self._decompress_mixed(info, streams)
        valid = np.array([e.n_tokens for e in info.entries], np.int64)
        C = self.chunk_size
        out = np.zeros(info.n_chunks * C, dtype=np.int32)
        # decode at the encoder's recorded lane count (v4+); v2/v3 record
        # nothing, so decode_batch must match the encoder's — mirror its
        # min() and dead-lane padding either way
        B = info.encode_batch or min(self.decode_batch, info.n_chunks)
        with obs.span("decompress.job", self._registry):
            for i in range(0, info.n_chunks, B):
                group = streams[i:i + B]
                ng = len(group)
                v = valid[i:i + B]
                if ng < B:
                    group = group + [b""] * (B - ng)
                    v = np.concatenate([v, np.zeros(B - ng, np.int64)])
                dec_tokens = self._decode_group(group, v, info.codec,
                                                chunk_offset=i,
                                                budget=info.ctx_budget)
                out[i * C:(i + ng) * C] = dec_tokens[:ng].ravel()
        self._c_dec_tokens.inc(info.n_tokens)
        self._registry.counter("decompress.chunks").inc(info.n_chunks)
        return out[:info.n_tokens]

    def _decode_fallback_entry(self, idx: int, entry: ChunkEntry,
                               stream: bytes, vocab: int) -> np.ndarray:
        return _decode_fallback(idx, entry, stream, vocab)

    def _carried_decode(self, info: ContainerInfo, get_stream,
                        need: set[int] | None) -> dict[int, np.ndarray]:
        """The recipe-aware decode engine shared by full decompress and
        range decode of v6 archives. Chunks are organized into carry
        *chains* (a chain starts at every non-carry recipe — read_index
        guarantees chunk 0 starts one); chains decode round-robin, one
        lane per chain, in blocks of the recorded encode lane count.
        ``need`` (range decode) limits work to the requested chunks plus
        their carry closure — every chain is decoded forward only to the
        deepest requested position, which is exactly what materializes a
        ranged chunk's declared context. Fallback chunks inside a chain
        decode host-side in their round (their *tokens* may be the next
        chunk's context even though they never touch the model). Returns
        {chunk index: valid tokens}."""
        entries = info.entries
        chains: list[list[int]] = []
        for j, e in enumerate(entries):
            if e.recipe_kind == RECIPE_CARRY and chains:
                chains[-1].append(j)
            else:
                chains.append([j])
        if need is not None:
            trimmed = []
            for c in chains:
                depth = max((k for k, j in enumerate(c) if j in need),
                            default=-1)
                if depth >= 0:
                    trimmed.append(c[:depth + 1])
            chains = trimmed
        decoded: dict[int, np.ndarray] = {}
        B = info.encode_batch or min(self.decode_batch, max(1, len(chains)))
        for blk in range(0, len(chains), B):
            block = chains[blk:blk + B]
            for r in range(max((len(c) for c in block), default=0)):
                group = [b""] * B
                v = np.zeros(B, np.int64)
                ctx_rows: list = [None] * B
                sel: list[tuple[int, int]] = []
                for lane, c in enumerate(block):
                    if r >= len(c):
                        continue
                    j = c[r]
                    e = entries[j]
                    s = get_stream(j)
                    if not e.is_llm:
                        decoded[j] = self._decode_fallback_entry(
                            j, e, s, info.vocab)
                        continue
                    group[lane] = s
                    v[lane] = e.n_tokens
                    if e.recipe_kind == RECIPE_CARRY:
                        prevt = decoded[j - 1]
                        ctx_rows[lane] = prevt[
                            max(0, prevt.size - e.recipe_param):]
                    elif e.recipe_kind == RECIPE_SHARED:
                        ctx_rows[lane] = \
                            info.shared_prefixes[e.recipe_param][1]
                    sel.append((lane, j))
                if not sel:
                    continue
                L = max((c.size for c in ctx_rows if c is not None),
                        default=0)
                ctx = ctx_len = None
                if L:
                    ctx = np.zeros((B, L), np.int32)
                    ctx_len = np.zeros(B, np.int64)
                    for lane, c in enumerate(ctx_rows):
                        if c is not None:
                            ctx[lane, :c.size] = c
                            ctx_len[lane] = c.size
                toks = self._decode_group(group, v, info.codec,
                                          chunk_offset=sel[0][1],
                                          ctx=ctx, ctx_len=ctx_len,
                                          budget=info.ctx_budget)
                for lane, j in sel:
                    decoded[j] = toks[lane, :entries[j].n_tokens].copy()
        return decoded

    def _decompress_carried(self, info: ContainerInfo,
                            streams: list) -> np.ndarray:
        """Full decode of a v6 archive with context recipes."""
        C = self.chunk_size
        with obs.span("decompress.job", self._registry):
            decoded = self._carried_decode(info, lambda j: streams[j],
                                           None)
        out = np.zeros(info.n_chunks * C, np.int32)
        for j, toks in decoded.items():
            out[j * C:j * C + toks.size] = toks
        self._c_dec_tokens.inc(info.n_tokens)
        self._registry.counter("decompress.chunks").inc(info.n_chunks)
        n_fb = sum(1 for e in info.entries if not e.is_llm)
        if n_fb:
            self._registry.counter(
                "decompress.fallback_chunks",
                "fallback-tagged chunks decoded without the model").inc(
                n_fb)
        return out[:info.n_tokens]

    def _decompress_mixed(self, info: ContainerInfo,
                          streams: list) -> np.ndarray:
        """v5 mixed-codec decode: fallback-tagged chunks decode directly
        on the host; the surviving LLM-tagged chunks are grouped at the
        recorded encode lane count, in tag order. Encode-time group
        *composition* is not (and cannot be) reconstructed — post-encode
        flips changed it — but lanes are independent, so only the lane
        count is coding geometry (DESIGN.md §8)."""
        C = self.chunk_size
        out = np.zeros(info.n_chunks * C, dtype=np.int32)
        llm_idx = [i for i, e in enumerate(info.entries) if e.is_llm]
        with obs.span("decompress.job", self._registry):
            for i, e in enumerate(info.entries):
                if e.is_llm:
                    continue
                toks = self._decode_fallback_entry(i, e, streams[i],
                                                   info.vocab)
                out[i * C:i * C + e.n_tokens] = toks
            B = info.encode_batch or min(self.decode_batch,
                                         max(1, len(llm_idx)))
            for g in range(0, len(llm_idx), B):
                sel = llm_idx[g:g + B]
                group = [streams[j] for j in sel] + [b""] * (B - len(sel))
                v = np.zeros(B, np.int64)
                v[:len(sel)] = [info.entries[j].n_tokens for j in sel]
                toks = self._decode_group(group, v, info.codec,
                                          chunk_offset=sel[0],
                                          budget=info.ctx_budget)
                for k, j in enumerate(sel):
                    nt = info.entries[j].n_tokens
                    out[j * C:j * C + nt] = toks[k, :nt]
        self._c_dec_tokens.inc(info.n_tokens)
        self._registry.counter("decompress.chunks").inc(info.n_chunks)
        self._registry.counter(
            "decompress.fallback_chunks",
            "fallback-tagged chunks decoded without the model").inc(
            info.n_chunks - len(llm_idx))
        return out[:info.n_tokens]

    def decompress_range(self, blob: bytes, chunk_start: int,
                         chunk_stop: int | None = None) -> np.ndarray:
        """Random-access decode of chunks [chunk_start, chunk_stop) from a
        v4 container — the index footer locates the streams, so only the
        requested chunks' bytes are read, verified, and decoded. The
        result is bit-identical to the corresponding slice of a full
        ``decompress`` (chunks are independent by construction, §5.4).

        Bit-exactness on real models needs more than chunk independence:
        logits are only reproducible at the encoder's model-program batch
        shape (XLA reduction order varies with B). So the requested chunks
        are regrouped into their *encode-time* groups — stride taken from
        the container's recorded encode batch — and each group runs at its
        encode-time lane count, with unrequested lanes left empty (masked
        out of the coder; lanes are independent, so their content never
        reaches the requested lanes' logits)."""
        info = read_index(blob)
        self._check_config(info)
        if chunk_stop is None:
            chunk_stop = chunk_start + 1
        check_chunk_range(info, chunk_start, chunk_stop)
        B = info.encode_batch or min(self.decode_batch, info.n_chunks)
        C = self.chunk_size
        out = np.zeros((chunk_stop - chunk_start) * C, dtype=np.int32)
        if any(e.recipe_kind != RECIPE_NONE for e in info.entries):
            return self._range_carried(blob, info, chunk_start, chunk_stop,
                                       out)
        if any(not e.is_llm for e in info.entries):
            return self._range_mixed(blob, info, chunk_start, chunk_stop,
                                     B, out)
        total = 0
        for g in range(chunk_start // B, (chunk_stop - 1) // B + 1):
            g_lo = g * B
            g_hi = min(g_lo + B, info.n_chunks)
            sel_lo = max(chunk_start, g_lo)
            sel_hi = min(chunk_stop, g_hi)
            group = [b""] * B               # encode-time lane count, always
            v = np.zeros(B, np.int64)
            for j in range(sel_lo, sel_hi):
                e = info.entries[j]
                s = blob[e.offset:e.offset + e.length]
                if xxh64(s) != e.checksum:
                    raise ContainerError(
                        f"corrupt container: chunk {j} checksum mismatch")
                group[j - g_lo] = s
                v[j - g_lo] = e.n_tokens
            toks = self._decode_group(group, v, info.codec,
                                      budget=info.ctx_budget)
            for j in range(sel_lo, sel_hi):
                b = j - g_lo
                out[total:total + int(v[b])] = toks[b, :int(v[b])]
                total += int(v[b])
        return out[:total]

    def _range_carried(self, blob, info: ContainerInfo, chunk_start: int,
                       chunk_stop: int, out: np.ndarray) -> np.ndarray:
        """Range decode over a recipe-bearing v6 container: the carry
        closure (each requested chunk's chain ancestors) is decoded
        forward to materialize declared contexts — that closure, and only
        that closure, is read and checksum-verified from the blob."""
        verified: dict[int, bytes] = {}

        def get_stream(j: int) -> bytes:
            if j not in verified:
                e = info.entries[j]
                s = blob[e.offset:e.offset + e.length]
                if xxh64(s) != e.checksum:
                    raise ContainerError(
                        f"corrupt container: chunk {j} checksum mismatch")
                verified[j] = s
            return verified[j]

        need = set(range(chunk_start, chunk_stop))
        decoded = self._carried_decode(info, get_stream, need)
        total = 0
        for j in range(chunk_start, chunk_stop):
            t = decoded[j]
            out[total:total + t.size] = t
            total += t.size
        return out[:total]

    def _range_mixed(self, blob, info: ContainerInfo, chunk_start: int,
                     chunk_stop: int, B: int, out: np.ndarray) -> np.ndarray:
        """Range decode over a mixed-codec v5 container: fallback-tagged
        chunks decode individually, the requested LLM-tagged chunks are
        grouped at the recorded lane count (composition is free — see
        ``_decompress_mixed``)."""
        toks_by_chunk: dict[int, np.ndarray] = {}
        llm_sel: list[tuple[int, bytes]] = []
        for j in range(chunk_start, chunk_stop):
            e = info.entries[j]
            s = blob[e.offset:e.offset + e.length]
            if xxh64(s) != e.checksum:
                raise ContainerError(
                    f"corrupt container: chunk {j} checksum mismatch")
            if e.is_llm:
                llm_sel.append((j, s))
            else:
                toks_by_chunk[j] = self._decode_fallback_entry(
                    j, e, s, info.vocab)
        for g in range(0, len(llm_sel), B):
            grp = llm_sel[g:g + B]
            group = [s for _, s in grp] + [b""] * (B - len(grp))
            v = np.zeros(B, np.int64)
            v[:len(grp)] = [info.entries[j].n_tokens for j, _ in grp]
            toks = self._decode_group(group, v, info.codec,
                                      chunk_offset=grp[0][0],
                                      budget=info.ctx_budget)
            for k, (j, _) in enumerate(grp):
                toks_by_chunk[j] = toks[k, :info.entries[j].n_tokens]
        total = 0
        for j in range(chunk_start, chunk_stop):
            t = toks_by_chunk[j]
            out[total:total + t.size] = t
            total += t.size
        return out[:total]

    # Decode groups take explicit per-stream valid lengths (slot-resumable
    # form): the same inner loops serve full decompress, range decode, and
    # the continuous-batching scheduler's drain path.
    def _decode_group(self, streams, valid: np.ndarray, codec: int,
                      chunk_offset: int = 0, ctx=None, ctx_len=None,
                      budget: int = 0):
        with obs.span("decode.group", self._registry):
            if codec == CODEC_RANS:
                if ctx is None and self.draft_k > 0 \
                        and hasattr(self.predictor, "verify_steps"):
                    # speculative decode stays context-free: a lane's
                    # draft/verify frontier and its context prefill don't
                    # compose, so recipe groups take the lock-step path
                    return self._decode_group_rans_spec(streams, valid,
                                                        chunk_offset,
                                                        budget)
                return self._decode_group_rans(streams, valid, ctx,
                                               ctx_len, budget)
            return self._decode_group_ac(streams, valid, ctx, ctx_len,
                                         budget)

    def _begin_group(self, B, C, ctx=None, ctx_len=None, live=None,
                     budget: int = 0):
        """Open a decode/score group. With a context (B, L)/(B,) pair:
        when every live lane shares the full context length L and the
        predictor's ``begin_decode`` accepts a prefix, the whole context
        is prefilled in one call — the state has consumed
        [BOS, ctx[:, :-1]] and ``prev`` is ctx[:, -1]; ``consumed`` is L
        per lane. Otherwise the caller feeds the context through
        ``decode_step`` itself (``consumed`` all zero). Dead lanes are
        fed the (zero-padded) prefix too in the fast path — lanes are
        independent, so their content never reaches live lanes.

        ``budget`` is the archive-wide context budget (v6 footer field):
        the model program is sized C + budget for EVERY group, context-
        free ones included — cache length changes the jitted program's
        reduction shapes and therefore the logits bitwise, so one
        archive must run at one length on both sides."""
        L = 0 if ctx is None else int(ctx.shape[1])
        if hasattr(self.predictor, "set_decode_len"):
            self.predictor.set_decode_len(C + max(L, int(budget)))
        if L:
            cl = np.asarray(ctx_len, np.int64)
            lv = np.ones(B, bool) if live is None else np.asarray(live)
            if lv.any() and bool(np.all(cl[lv] == L)) \
                    and self._accepts_prefix():
                state = self.predictor.begin_decode(
                    B, prefix=np.ascontiguousarray(ctx, dtype=np.int32))
                prev = np.ascontiguousarray(ctx[:, -1], dtype=np.int32)
                return state, prev, np.full(B, L, np.int64)
        state = self.predictor.begin_decode(B)
        prev = np.full((B,), self.predictor.bos_id, dtype=np.int32)
        return state, prev, np.zeros(B, np.int64)

    def _coder_decode_step(self, dec, logits, m):
        """One vectorized entropy-decode step for the lanes in ``m``:
        fused on-device top-k → quantized CDF → symbol-interval lookup on
        the coder's peeked slot bits (kernels/ac_cdf.py on TPU), then one
        host ``advance``. Bit-identical to the former host path (the CDF
        integers are the same — see cdf.topk_cdf); what changed is that
        no (B, K+2) cumsum or per-row search runs on the host anymore.
        Returns decoded token ids (B,) int64 (0 on inactive lanes)."""
        slots_bits = dec.peek(self.precision)
        if self.topk:
            ids, _, slots, starts, freqs = (np.asarray(a) for a in
                                            topk_cdf_lookup_jit(
                logits, slots_bits.astype(np.int32), self.topk,
                self.precision))
            dec.advance(slots, starts, freqs, self.precision, m)
            esc = m & (slots == self.topk)
            syms = np.take_along_axis(
                ids, np.minimum(slots, self.topk - 1)[:, None],
                axis=-1)[:, 0].astype(np.int64)
            if esc.any():
                u = dec.get_uniform(self._esc_bits, esc)
                syms = np.where(esc, u, syms)
                self._c_dec_escapes.inc(int(esc.sum()))
        else:
            syms, starts, freqs = (np.asarray(a) for a in full_cdf_lookup_jit(
                logits, slots_bits.astype(np.int32), self.precision))
            syms = syms.astype(np.int64)
            dec.advance(syms, starts, freqs, self.precision, m)
        return np.where(m, syms, 0)

    def _round_cdfs(self, logits):
        """Build every CDF row a speculative round can consume in ONE
        device dispatch: ``logits`` (B, K+1, V) -> (ids (B, K+1, k) or
        None, cdf (B, K+1, A+1) int64) where A is the coded alphabet
        (top-k + escape, or V). The integers are exactly the rows the
        fused per-step lookup would build — interval search over
        identical integers is exact — so batching the build per round
        instead of per position changes dispatch count, not bits."""
        if self.topk:
            ids, cdf = topk_cdf_jit(logits, self.topk, self.precision)
            return np.asarray(ids), np.asarray(cdf, np.int64)
        return None, np.asarray(full_cdf_jit(logits, self.precision),
                                np.int64)

    def _coder_decode_host(self, dec, ids, cdf, m):
        """One vectorized entropy-decode step against PREBUILT integer CDF
        rows (``_round_cdfs``): host interval search on the peeked slot
        bits + one ``advance``. The speculative inner loop uses this so a
        round of K+1 positions costs one device dispatch total rather
        than one per position. cdf[:, -1] == 2**precision > slot always,
        so the right-edge sentinel never matches."""
        slot = dec.peek(self.precision)
        lanes = np.arange(cdf.shape[0])
        syms = (cdf[:, 1:-1] <= slot[:, None]).sum(axis=1, dtype=np.int64)
        dec.advance(syms, cdf[lanes, syms],
                    cdf[lanes, syms + 1] - cdf[lanes, syms],
                    self.precision, m)
        if ids is not None:
            esc = m & (syms == self.topk)
            syms = ids[lanes, np.minimum(syms, self.topk - 1)].astype(
                np.int64)
            if esc.any():
                u = dec.get_uniform(self._esc_bits, esc)
                syms = np.where(esc, u, syms)
                self._c_dec_escapes.inc(int(esc.sum()))
        return np.where(m, syms, 0)

    def _decode_group_rans(self, streams, valid, ctx=None, ctx_len=None,
                           budget: int = 0):
        """Lock-step batched decode: one model step + one fused CDF/lookup
        dispatch + one vectorized coder step per token position. With a
        context, each lane first consumes its declared prefix (prefilled
        in one call when uniform + supported, else fed per step with
        per-lane offsets) before its first coded position."""
        B, C = len(streams), self.chunk_size
        valid = np.asarray(valid, np.int64)
        dec = rans.BatchedRansDecoder(streams)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev, consumed = self._begin_group(B, C, ctx, ctx_len,
                                                  live=valid > 0,
                                                  budget=budget)
        if ctx is None or consumed.any():
            for t in range(int(valid.max(initial=0))):
                logits, state = self.predictor.decode_step(state, prev)
                m = valid > t
                syms = self._coder_decode_step(dec, np.asarray(logits), m)
                nxt = np.where(m, syms, 0).astype(np.int32)
                tokens[:, t] = nxt
                prev = nxt
            return tokens
        cl = np.where(valid > 0, np.asarray(ctx_len, np.int64), 0)
        for s in range(int((cl + valid).max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            t = s - cl
            m = (t >= 0) & (t < valid)
            if m.any():
                syms = self._coder_decode_step(dec, np.asarray(logits), m)
                tokens[m, t[m]] = syms[m]
                nxt = np.where(m, syms, prev)
            else:
                nxt = prev.astype(np.int64)
            pf = s < cl
            if pf.any():
                nxt = np.asarray(nxt).copy()
                nxt[pf] = ctx[pf, s]
            prev = nxt.astype(np.int32)
        return tokens

    def _decode_group_rans_spec(self, streams, valid, chunk_offset=0,
                                budget: int = 0):
        """Speculative batched decode (DESIGN.md §9): per round, a cheap
        self-draft proposes K tokens per lane, ONE verify dispatch scores
        all K+1 positions (predictor.verify_steps — bit-identical to K+1
        lock-step calls by construction), and the rANS decoder accepts
        greedily against the coded stream. A lane keeps consuming verify
        logits while its decoded token matches its draft; the first
        mismatch still yields a correct token (the coder decoded it from
        the real stream — acceptance is exact, not probabilistic), after
        which the lane waits for the next round. Lanes that match all K
        drafts decode a bonus (K+1)-th token from the last verify slot.
        ``predictor.rollback`` then rewinds each lane's cache to its
        accepted frontier. Worst case (every draft wrong) each round
        still decodes 1 token/lane — the lock-step rate — and the
        adaptive fallthrough stops paying the deeper verify forward."""
        B, C = len(streams), self.chunk_size
        K = self.draft_k
        valid = np.asarray(valid, np.int64)
        dec = rans.BatchedRansDecoder(streams)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev, _ = self._begin_group(B, C, budget=budget)
        pos = np.zeros(B, np.int64)
        if hasattr(self.draft, "begin_group"):
            self.draft.begin_group(chunk_offset)
        rounds = drafted_hits = offered = rollbacks = 0
        tel = self._registry.enabled
        depth_h = self._registry.histogram(
            "spec.accept_depth",
            "tokens decoded per lane per speculative round") if tel else None
        lanes = np.arange(B)
        while True:
            active = pos < valid
            if not active.any():
                break
            if rounds >= self._spec_window and \
                    drafted_hits < self._spec_floor * rounds:
                self._registry.counter(
                    "spec.lockstep_fallthroughs",
                    "groups that abandoned drafting mid-decode").inc()
                self._lockstep_tail(dec, state, prev, pos, valid, tokens)
                break
            with obs.span("decode.verify_round", self._registry):
                drafts = np.clip(
                    self.draft.propose(tokens, pos, K), 0,
                    self.predictor.vocab_size - 1).astype(np.int32)
                seq = np.concatenate([prev[:, None], drafts], axis=1)
                logits, snaps = self.predictor.verify_steps(state, seq)
                ids_a, cdf_a = self._round_cdfs(np.asarray(logits))
                acc = np.zeros(B, np.int64)
                chain = active.copy()
                for j in range(K + 1):
                    mj = chain & (pos + j < valid)
                    if not mj.any():
                        break
                    syms = self._coder_decode_host(
                        dec, None if ids_a is None else ids_a[:, j],
                        cdf_a[:, j], mj)
                    tokens[mj, (pos + j)[mj]] = syms[mj]
                    acc[mj] += 1
                    chain = mj & (syms == drafts[:, j]) if j < K else \
                        np.zeros(B, bool)
                # lane b resumed from the snapshot after acc[b] verify
                # inputs: [prev, d_0..d_{acc-2}] — the acc'th accepted
                # token is NOT fed back here; it is the next round's `prev`
                state = self.predictor.rollback(snaps, acc.astype(np.int32))
                pos += acc
                prev = np.where(acc > 0,
                                tokens[lanes, np.maximum(pos - 1, 0)],
                                prev).astype(np.int32)
                rounds += 1
                offered += int(active.sum()) * K
                drafted_hits += int(np.maximum(acc - 1, 0).sum())
                rollbacks += int((active & (acc < K + 1)).sum())
                if tel:
                    depth_h.observe_many(acc[active])
        self._registry.counter(
            "spec.rounds", "speculative draft/verify rounds").inc(rounds)
        self._registry.counter(
            "spec.drafted_tokens", "draft slots offered for "
            "verification").inc(offered)
        self._registry.counter(
            "spec.drafted_accepted",
            "drafted tokens accepted beyond the per-round floor of "
            "1").inc(drafted_hits)
        self._registry.counter(
            "spec.rollbacks", "lane cache rewinds (acc < K+1)").inc(
            rollbacks)
        return tokens

    def _lockstep_tail(self, dec, state, prev, pos, valid, tokens):
        """Finish a group lock-step from per-lane positions — the
        speculative path's fallthrough when drafts stop earning their
        verify depth. Mutates pos/tokens in place."""
        B = tokens.shape[0]
        lanes = np.arange(B)
        while True:
            m = pos < valid
            if not m.any():
                return
            logits, state = self.predictor.decode_step(state, prev)
            syms = self._coder_decode_step(dec, np.asarray(logits), m)
            tokens[m, pos[m]] = syms[m]
            pos += m
            prev = np.where(m, syms, prev).astype(np.int32)

    def _decode_group_ac(self, streams, valid, ctx=None, ctx_len=None,
                         budget: int = 0):
        """Legacy per-stream arithmetic decode (reference codec + v2),
        with the same per-lane context offsets as the rANS path."""
        V = self.predictor.vocab_size
        B, C = len(streams), self.chunk_size
        valid = np.asarray(valid, np.int64)
        decoders = [ac.ArithmeticDecoder(s) for s in streams]
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev, consumed = self._begin_group(B, C, ctx, ctx_len,
                                                  live=valid > 0,
                                                  budget=budget)
        if ctx is None or consumed.any():
            cl = np.zeros(B, np.int64)
        else:
            cl = np.where(valid > 0, np.asarray(ctx_len, np.int64), 0)
        for s in range(int((cl + valid).max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            logits = np.asarray(logits)
            tv = s - cl
            m = (tv >= 0) & (tv < valid)
            if m.any() and self.topk:
                ids, qpmf = topk_quantized_jit(logits, self.topk,
                                               self.precision)
                ids = np.asarray(ids)
                cdfs = pmf_to_cdf(np.asarray(qpmf))
            nxt = prev.astype(np.int32).copy()
            for b in range(B):
                if not m[b]:
                    continue
                t = int(tv[b])
                if self.topk:
                    slot = decoders[b].decode(cdfs[b])
                    if slot == self.topk:  # escape
                        sym = decoders[b].decode(ac.uniform_cdf(V))
                    else:
                        sym = int(ids[b, slot])
                else:
                    cdf = logits_to_cdf(logits[b], self.precision)
                    sym = decoders[b].decode(cdf)
                tokens[b, t] = sym
                nxt[b] = sym
            pf = s < cl
            if pf.any():
                nxt[pf] = ctx[pf, s]
            prev = nxt
        return tokens

    # ------------------------------------------------------------- metrics
    @staticmethod
    def ratio(original_bytes: int, blob: bytes) -> float:
        return original_bytes / max(1, len(blob))
