"""The paper's LLM-based lossless compressor (§4), as a framework component.

Design
------
The text is tokenized, split into fixed-size **chunks** (paper §5.4), and
each chunk is coded *independently* given a fresh context. Independence is
what makes the workload batchable:

* **compress** — one teacher-forced scoring pass over a (B, C) batch of
  chunks (a prefill-shaped pjit computation) yields P(x_t | x_<t) for every
  position; each actual token is then entropy-coded with its quantized CDF.
  Model cost: one forward pass per C tokens.

* **decompress** — B chunks are decoded in lock-step: one `decode_step`
  (serve-shaped computation, KV/SSM cache) per position for the whole
  batch; the entropy decoder picks each stream's next token from the
  model CDF, which is then fed back as the next input.

Losslessness requires the *same* quantized CDFs on both sides. Both sides
run the same jitted function on the same weights with integer quantization,
so the CDFs are bit-identical (this is exactly why the paper compresses
instead of re-generating, §4.4 — we make the determinism explicit).

Beyond-paper: top-K + escape coding (see core/cdf.py) bounds host-coder
work per token at K+1 instead of |V|, at a measured ~0 ratio cost for
well-predicted text (escapes coded uniformly remain lossless).

Entropy backends (DESIGN.md §7)
-------------------------------
Two host coders share the container:

* ``codec="rans"`` (id 1, default) — batched interleaved rANS
  (core/rans.py): all B chunk-streams advance through ONE vectorized
  coder step per token position. This is the production path; host cost
  per token is a few numpy ufuncs amortized over the batch.
* ``codec="ac"`` (id 0) — the reference Witten–Neal–Cleary arithmetic
  coder (core/ac.py): per-stream Python loops, kept as the legacy /
  cross-check backend and for decoding v2 archives.

Container format (little-endian)
--------------------------------
Shared header (v3 and v4; v2 lacks the codec byte):
  magic 'LLMC' | u8 version | u8 flags | u16 chunk_size | u32 n_tokens
  u32 vocab | u16 topk (0 => full vocab) | u8 precision | u8 codec
Body (all versions): per chunk, varint byte-length + codec stream.

Version 4 appends a **seekable footer** after the body (DESIGN.md §8):
one index entry per chunk —
  u64 stream offset (from container start) | u32 stream length
  u32 valid token count | u64 xxh64(stream)
— followed by u32 encode batch (the lane count the encoder's model
program ran at; 0 = unrecorded), u64 xxh64(header || entries || encode
batch), u32 n_chunks, u32 footer length, and the end magic 'LC4F'. The
encode batch is recorded because on real models the logits are only
bit-reproducible at the *same* batch shape (XLA reduction order varies
with B), so it is the decode batch/slot count required for bit-exact
decode — advisory for batch-invariant predictors, load-bearing for
production models. The index enables random-access decode
of chunk ranges (``decompress_range``) and out-of-order chunk completion
from the service scheduler; the checksums turn silent corruption into
``ContainerError`` before the entropy coder runs on garbage.

Version 5 (DESIGN.md §11) is v4 plus **adaptive codec routing**: each
index entry carries a u8 codec tag —
  u64 offset | u32 stream length | u32 valid tokens | u8 codec | u64 xxh64
— end magic 'LC5F'. The header codec byte still names the container's
LLM *entropy* codec (ac/rans); a per-chunk tag either repeats it (the
chunk is LLM-coded) or names a fallback byte codec (zstd=2, lzma=3,
raw=4 — core/baselines.py) the router chose because the model fit was
poor. The tags live inside the hash-covered footer, so a flipped tag is
detected like any other index corruption, and decode reconstructs each
chunk with exactly the recorded backend — the router runs at encode
only, never guesses at decode. LLM-tagged chunks are grouped at the
recorded encode batch for decode; lanes are independent, so *which*
chunks share a group is free while the lane count stays load-bearing.

The codec, version and geometry used for decode come from the container,
never from this object's configuration. Version compatibility: v2
read-only (AC implied), v3/v4/v5 read/write. A bare
``LLMCompressor`` writes v3 — the wire-minimal format every ratio
benchmark measures (the v4 index costs a fixed 24 B/chunk, which
amortizes over production payloads but distorts micro-scale ratios);
the service layer (repro.service) and the ``llmc`` CLI write v4, where
seekability and integrity checking earn their bytes, and v5 whenever
routing is enabled (``route != "llm"``).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Callable, Protocol

import numpy as np

from repro import obs
from . import ac, rans
from .cdf import (DEFAULT_PRECISION, build_topk_cdfs, full_cdf_jit,
                  full_cdf_lookup_jit, logits_to_cdf, pmf_to_cdf,
                  topk_cdf_jit, topk_cdf_lookup_jit, topk_quantized_jit)
from .checksum import xxh64
from .draft import SuffixDraft
from .router import (ROUTE_AUTO, ROUTE_LLM, CodecRouter, RouterConfig,
                     route_chunks)

MAGIC = b"LLMC"
VERSION_V3 = 3
VERSION_V4 = 4
VERSION_V5 = 5
VERSION = VERSION_V5                 # newest supported container version
_V2_HEADER = "<BBHIIHB"              # seed header (no codec byte)
_V3_HEADER = "<BBHIIHBB"             # v3/v4/v5 share this header layout
_V4_ENTRY = "<QIIQ"                  # offset, stream len, valid tokens, xxh64
_V4_ENTRY_SIZE = struct.calcsize(_V4_ENTRY)
_V4_END_MAGIC = b"LC4F"
_V5_ENTRY = "<QIIBQ"                 # v4 entry + u8 per-chunk codec tag
_V5_ENTRY_SIZE = struct.calcsize(_V5_ENTRY)
_V5_END_MAGIC = b"LC5F"
_V4_TRAILER = 12                     # u32 n_chunks | u32 footer_len | magic

# LLM entropy codecs — legal in the header codec byte of any version
CODEC_AC = 0
CODEC_RANS = 1
# fallback byte codecs — legal only in v5 per-chunk tags (the router's
# choices; backends live in core/baselines.py)
CODEC_ZSTD = 2
CODEC_LZMA = 3
CODEC_RAW = 4
CODEC_IDS = {"ac": CODEC_AC, "rans": CODEC_RANS}
FALLBACK_CODEC_IDS = {"zstd": CODEC_ZSTD, "lzma": CODEC_LZMA,
                      "raw": CODEC_RAW}
CODEC_NAMES = {v: k for k, v in {**CODEC_IDS,
                                 **FALLBACK_CODEC_IDS}.items()}
LLM_CODECS = frozenset(CODEC_IDS.values())


class ContainerError(ValueError):
    """Malformed, truncated, corrupt, or configuration-mismatched container.

    Everything the parser can detect raises this (a ValueError subclass),
    never a bare IndexError/struct.error from running off the end of a
    truncated blob."""


class PredictorAdapter(Protocol):
    """What the compressor needs from a model. See serve/engine.py for the
    production implementation over the model zoo."""

    vocab_size: int
    bos_id: int

    def score_chunks(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (B, C) int32 -> logits (B, C, V): logits[:, t] predicts
        tokens[:, t] (i.e. the model input is [BOS, x_0 .. x_{C-2}])."""
        ...

    def begin_decode(self, batch: int):
        """-> opaque decode state positioned to predict token 0 of each chunk."""
        ...

    def decode_step(self, state, prev_tokens: np.ndarray):
        """(state, prev (B,) int32) -> (logits (B, V), new state)."""
        ...


def _write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _read_varint(buf: bytes, pos: int, end: int | None = None) -> tuple[int, int]:
    """Bounds-checked varint read from ``buf[pos:end]``."""
    end = len(buf) if end is None else end
    shift = 0
    val = 0
    while True:
        if pos >= end:
            raise ContainerError(
                f"truncated container: varint runs past byte {end}")
        b = buf[pos]
        pos += 1
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, pos
        shift += 7
        if shift > 63:
            raise ContainerError("corrupt container: varint longer than 64 bits")


# ---------------------------------------------------------------- container
@dataclass
class ChunkEntry:
    """One v4/v5 index-footer entry (also synthesized for v2/v3 at
    parse). ``codec`` is the chunk's own codec id: the container's
    entropy codec for every chunk of a v2-v4 archive, and the recorded
    per-chunk routing decision for v5 (possibly a fallback codec)."""
    offset: int          # byte offset of the stream from container start
    length: int          # stream byte length
    n_tokens: int        # valid tokens in this chunk (<= chunk_size)
    checksum: int = 0    # xxh64 of the stream bytes (0 for v2/v3)
    codec: int = -1      # per-chunk codec id (filled in at parse)

    @property
    def codec_name(self) -> str:
        return CODEC_NAMES[self.codec]

    @property
    def is_llm(self) -> bool:
        return self.codec in LLM_CODECS


@dataclass
class ContainerInfo:
    """Parsed header (+ index when v4) of an .llmc container."""
    version: int
    flags: int
    chunk_size: int
    n_tokens: int
    vocab: int
    topk: int
    precision: int
    codec: int
    header_size: int
    n_chunks: int
    entries: list[ChunkEntry] = field(default_factory=list)
    # v4 only: the model-program lane count the encoder ran at (0 when
    # unrecorded / v2 / v3). Bit-exact decode of non-batch-invariant
    # models requires decoding at this same batch shape.
    encode_batch: int = 0

    @property
    def codec_name(self) -> str:
        return CODEC_NAMES[self.codec]


def chunk_valid_lengths(n_tokens: int, chunk_size: int) -> np.ndarray:
    """Valid token count per chunk for a contiguous n_tokens stream.
    Zero tokens means zero chunks (an empty container has an empty body),
    so the returned array is empty — callers must not assume max()."""
    n_chunks = -(-n_tokens // chunk_size)
    ends = np.minimum(np.arange(1, n_chunks + 1) * chunk_size, n_tokens)
    starts = np.arange(n_chunks) * chunk_size
    return np.maximum(ends - starts, 0).astype(np.int64)


def read_header(blob: bytes) -> ContainerInfo:
    """Parse and validate the container header (any supported version)."""
    if len(blob) < 4 or blob[:4] != MAGIC:
        raise ContainerError("bad magic (not an LLMC container)")
    if len(blob) < 5:
        raise ContainerError("truncated container: missing version byte")
    version = blob[4]
    if version == 2:
        hdr = _V2_HEADER
    elif version in (VERSION_V3, VERSION_V4, VERSION_V5):
        hdr = _V3_HEADER
    else:
        raise ContainerError(f"unsupported container version {version}")
    hsize = 4 + struct.calcsize(hdr)
    if len(blob) < hsize:
        raise ContainerError(
            f"truncated container: {len(blob)} bytes < {hsize}-byte header")
    fields = struct.unpack(hdr, blob[4:hsize])
    if version == 2:
        _, flags, C, n, vocab, topk, precision = fields
        codec = CODEC_AC              # v2 archives predate the codec byte
    else:
        _, flags, C, n, vocab, topk, precision, codec = fields
        # the header byte names the container's LLM *entropy* codec;
        # fallback byte-codec ids (zstd/lzma/raw) are only legal in v5
        # per-chunk tags, never here
        if codec not in LLM_CODECS:
            raise ContainerError(f"unknown codec id {codec} in header "
                                 f"(entropy codec expected)")
    if C == 0:
        raise ContainerError("corrupt header: chunk_size is zero")
    # the *container's* codec decides which limits apply: a 24-bit-precision
    # AC container is legal, the same precision under rANS is not decodable
    if codec == CODEC_RANS and precision > rans.MAX_PRECISION:
        raise ContainerError(
            f"container precision {precision} exceeds rANS coder limit "
            f"{rans.MAX_PRECISION}")
    if precision < 1 or (1 << precision) <= (topk + 1 if topk else vocab):
        raise ContainerError(
            f"corrupt header: precision {precision} too small for "
            f"{'top-' + str(topk) if topk else 'vocab ' + str(vocab)} alphabet")
    n_chunks = -(-n // C)                # 0 tokens => 0 chunks
    return ContainerInfo(version, flags, C, n, vocab, topk, precision,
                         codec, hsize, n_chunks)


def read_index(blob: bytes, info: ContainerInfo | None = None) -> ContainerInfo:
    """Parse + verify the v4/v5 index footer; returns info with
    ``entries`` populated. Verifies the footer checksum (which covers the
    header too) but not the per-chunk stream checksums — those are checked
    by ``parse_container``/``decompress_range`` for the chunks actually
    read. v5 entries additionally carry the per-chunk codec tag, validated
    here: a fallback id is fine, an LLM id must match the header's entropy
    codec (a v5 archive never mixes rANS and AC chunks)."""
    info = info or read_header(blob)
    if info.version == VERSION_V4:
        entry_fmt, entry_size, end_magic = \
            _V4_ENTRY, _V4_ENTRY_SIZE, _V4_END_MAGIC
    elif info.version == VERSION_V5:
        entry_fmt, entry_size, end_magic = \
            _V5_ENTRY, _V5_ENTRY_SIZE, _V5_END_MAGIC
    else:
        raise ContainerError(
            f"container version {info.version} has no index footer "
            f"(random access requires v4+)")
    if len(blob) < info.header_size + _V4_TRAILER:
        raise ContainerError("truncated container: missing index footer")
    if blob[-4:] != end_magic:
        raise ContainerError(
            f"truncated or corrupt container: "
            f"v{info.version} end magic missing")
    n_chunks_f, footer_len = struct.unpack("<II", blob[-12:-4])
    expect_len = n_chunks_f * entry_size + 12
    if footer_len != expect_len:
        raise ContainerError(
            f"corrupt footer: length field {footer_len} != {expect_len} "
            f"for {n_chunks_f} chunks")
    if n_chunks_f != info.n_chunks:
        raise ContainerError(
            f"corrupt container: footer indexes {n_chunks_f} chunks, header "
            f"implies {info.n_chunks}")
    footer_start = len(blob) - _V4_TRAILER - footer_len
    if footer_start < info.header_size:
        raise ContainerError("truncated container: footer overlaps header")
    entries_end = footer_start + n_chunks_f * entry_size
    (encode_batch,) = struct.unpack("<I", blob[entries_end:entries_end + 4])
    (footer_hash,) = struct.unpack("<Q",
                                   blob[entries_end + 4:entries_end + 12])
    if xxh64(blob[:info.header_size] + blob[footer_start:entries_end + 4]) \
            != footer_hash:
        raise ContainerError("corrupt container: footer checksum mismatch "
                             "(header or index damaged)")
    entries = []
    for i in range(n_chunks_f):
        rec = struct.unpack_from(entry_fmt, blob,
                                 footer_start + i * entry_size)
        if info.version == VERSION_V4:
            off, ln, nt, cks = rec
            ctag = info.codec
        else:
            off, ln, nt, ctag, cks = rec
            if ctag not in CODEC_NAMES:
                raise ContainerError(
                    f"corrupt index: chunk {i} has unknown codec id {ctag}")
            if ctag in LLM_CODECS and ctag != info.codec:
                raise ContainerError(
                    f"corrupt index: chunk {i} tagged entropy codec {ctag} "
                    f"but the container codec is {info.codec}")
        if nt > info.chunk_size:
            raise ContainerError(
                f"corrupt index: chunk {i} claims {nt} tokens "
                f"(chunk_size {info.chunk_size})")
        if off < info.header_size or off + ln > footer_start:
            raise ContainerError(
                f"corrupt index: chunk {i} stream [{off}, {off + ln}) "
                f"outside body [{info.header_size}, {footer_start})")
        entries.append(ChunkEntry(off, ln, nt, cks, ctag))
    if sum(e.n_tokens for e in entries) != info.n_tokens:
        raise ContainerError(
            "corrupt container: index token counts disagree with header "
            f"n_tokens {info.n_tokens}")
    info.entries = entries
    info.encode_batch = encode_batch
    return info


def parse_container(blob: bytes) -> tuple[ContainerInfo, list[bytes]]:
    """Full parse: header (+ index when v4/v5) + per-chunk streams, with
    all integrity checks. Returns (info-with-entries, streams). Every
    entry's ``codec`` is populated regardless of version, so downstream
    decode logic never special-cases the container version."""
    info = read_header(blob)
    if info.version in (VERSION_V4, VERSION_V5):
        info = read_index(blob, info)
        entry_size = _V4_ENTRY_SIZE if info.version == VERSION_V4 \
            else _V5_ENTRY_SIZE
        body_end = len(blob) - _V4_TRAILER - \
            (info.n_chunks * entry_size + 12)
    else:
        body_end = len(blob)
    pos = info.header_size
    streams: list[bytes] = []
    valid = chunk_valid_lengths(info.n_tokens, info.chunk_size)
    for i in range(info.n_chunks):
        ln, pos = _read_varint(blob, pos, body_end)
        if pos + ln > body_end:
            raise ContainerError(
                f"truncated container: chunk {i} claims {ln} bytes, "
                f"{body_end - pos} remain")
        stream = blob[pos:pos + ln]
        if info.version in (VERSION_V4, VERSION_V5):
            e = info.entries[i]
            if e.offset != pos or e.length != ln:
                raise ContainerError(
                    f"corrupt container: chunk {i} framing ({pos}, {ln}) "
                    f"disagrees with index ({e.offset}, {e.length})")
            if xxh64(stream) != e.checksum:
                raise ContainerError(
                    f"corrupt container: chunk {i} checksum mismatch")
        else:
            info.entries.append(ChunkEntry(pos, ln, int(valid[i]),
                                           codec=info.codec))
        streams.append(stream)
        pos += ln
    return info, streams


def write_container(streams: list[bytes], *, version: int, chunk_size: int,
                    n_tokens: int, vocab: int, topk: int, precision: int,
                    codec_id: int,
                    valid_lengths: np.ndarray | None = None,
                    encode_batch: int = 0,
                    codec_tags: list[int] | None = None) -> bytes:
    """Assemble a v3/v4/v5 container from per-chunk codec streams (in
    chunk order — the service scheduler completes chunks out of order and
    reorders before calling this). ``encode_batch`` (v4+) records the
    model-program lane count every LLM chunk was encoded at (ragged
    groups are dead-lane padded, never shrunk) — the batch shape a
    decoder must use for bit-exact logits on non-batch-invariant models.
    ``codec_tags`` (v5) is the per-chunk codec id list the router chose;
    it defaults to the container codec for every chunk. Passing a tag
    that differs from ``codec_id`` in a v3/v4 write is an error — those
    formats cannot represent it."""
    if version not in (VERSION_V3, VERSION_V4, VERSION_V5):
        raise ValueError(f"cannot write container version {version}")
    if codec_tags is not None:
        if len(codec_tags) != len(streams):
            raise ValueError(
                f"{len(codec_tags)} codec tags for {len(streams)} streams")
        if version != VERSION_V5 and any(t != codec_id for t in codec_tags):
            raise ValueError(
                f"per-chunk codec tags require a v5 container "
                f"(got version {version})")
        for t in codec_tags:
            if t not in CODEC_NAMES:
                raise ValueError(f"unknown codec id {t} in codec_tags")
            if t in LLM_CODECS and t != codec_id:
                raise ValueError(
                    f"chunk tagged entropy codec {t} but the container "
                    f"codec is {codec_id}")
    flags = 1 if topk else 0
    out = bytearray()
    out += MAGIC
    out += struct.pack(_V3_HEADER, version, flags, chunk_size, n_tokens,
                       vocab, topk, precision, codec_id)
    header = bytes(out)
    if valid_lengths is None:
        valid_lengths = chunk_valid_lengths(n_tokens, chunk_size)
    indexed = version in (VERSION_V4, VERSION_V5)
    entries = bytearray()
    for i, (s, nv) in enumerate(zip(streams, valid_lengths)):
        _write_varint(out, len(s))
        if version == VERSION_V4:   # v3 skips the index + per-stream hash
            entries += struct.pack(_V4_ENTRY, len(out), len(s), int(nv),
                                   xxh64(s))
        elif version == VERSION_V5:
            tag = codec_id if codec_tags is None else codec_tags[i]
            entries += struct.pack(_V5_ENTRY, len(out), len(s), int(nv),
                                   tag, xxh64(s))
        out += s
    if indexed:
        tail = bytes(entries) + struct.pack("<I", encode_batch)
        footer_hash = xxh64(header + tail)
        out += tail
        out += struct.pack("<Q", footer_hash)
        out += struct.pack("<II", len(streams), len(tail) + 8)
        out += _V4_END_MAGIC if version == VERSION_V4 else _V5_END_MAGIC
    return bytes(out)


def check_container_config(info: ContainerInfo, *, vocab: int,
                           chunk_size: int, topk: int,
                           precision: int) -> None:
    """Raise ContainerError unless the container's coding geometry matches
    the decoder's configuration — shared by the grouped compressor and the
    service so the two validation paths cannot drift."""
    if info.vocab != vocab or info.chunk_size != chunk_size \
            or info.topk != topk or info.precision != precision:
        raise ContainerError(
            "compressor configuration mismatch with container "
            f"(container: vocab={info.vocab} chunk={info.chunk_size} "
            f"topk={info.topk} precision={info.precision})")


@dataclass
class CompressionStats:
    n_tokens: int = 0
    payload_bytes: int = 0
    header_bytes: int = 0
    n_escapes: int = 0
    ideal_bits: float = 0.0  # -sum log2 p from the un-quantized model
    # per-chunk obs.ChunkDiagnostics (DESIGN.md §10) — populated when the
    # compressor's registry is enabled; empty otherwise. This is the
    # signal the ROADMAP's adaptive codec router consumes: bits/token and
    # escape rate per chunk, previously computed and thrown away.
    chunks: list = field(default_factory=list)
    # per-chunk router.RouteDecision records (routed compressors only) —
    # the encode-side story of every codec tag written to the v5 index.
    routes: list = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.header_bytes


class LLMCompressor:
    """Chunked LLM-predictor + entropy-coding lossless compressor."""

    def __init__(self, predictor: PredictorAdapter, *,
                 chunk_size: int = 256,
                 topk: int = 0,
                 precision: int = DEFAULT_PRECISION,
                 decode_batch: int = 64,
                 codec: str = "rans",
                 container_version: int | None = None,
                 route: str = ROUTE_LLM,
                 router: CodecRouter | RouterConfig | None = None,
                 draft_k: int = 0,
                 draft=None,
                 registry: obs.MetricsRegistry | None = None):
        if topk and topk >= predictor.vocab_size:
            topk = 0
        if codec not in CODEC_IDS:
            raise ValueError(f"unknown codec {codec!r} "
                             f"(choose from {sorted(CODEC_IDS)})")
        if route not in (ROUTE_LLM, ROUTE_AUTO) \
                and route not in FALLBACK_CODEC_IDS:
            raise ValueError(
                f"unknown route {route!r} (choose 'llm', 'auto', or a "
                f"fallback codec from {sorted(FALLBACK_CODEC_IDS)})")
        # routing needs per-chunk codec tags, which only v5 carries; a
        # pure-LLM compressor defaults to the wire-minimal v3 as before
        if container_version is None:
            container_version = VERSION_V3 if route == ROUTE_LLM \
                else VERSION_V5
        if container_version not in (VERSION_V3, VERSION_V4, VERSION_V5):
            raise ValueError(f"cannot write container version "
                             f"{container_version} (v2 is read-only)")
        if route != ROUTE_LLM and container_version != VERSION_V5:
            raise ValueError(
                f"route={route!r} requires a v5 container (per-chunk codec "
                f"tags); cannot write v{container_version}")
        self.route = route
        if isinstance(router, CodecRouter):
            self.router = router
        elif isinstance(router, RouterConfig):
            self.router = CodecRouter(router)
        elif route in FALLBACK_CODEC_IDS:
            self.router = CodecRouter(RouterConfig(fallbacks=(route,)))
        else:
            self.router = CodecRouter()
        self.predictor = predictor
        self.chunk_size = int(chunk_size)
        self.topk = int(topk)
        self.precision = int(precision)
        self.decode_batch = int(decode_batch)
        self.codec = codec
        self.container_version = int(container_version)
        if (1 << precision) <= (topk + 1 if topk else predictor.vocab_size):
            raise ValueError("precision too small for alphabet")
        # only the rANS backend caps precision (AC handles up to 30 bits);
        # decoding a foreign-codec container never hits the encoder limit
        if codec == "rans" and precision > rans.MAX_PRECISION:
            raise ValueError(f"precision {precision} exceeds rANS coder "
                             f"limit {rans.MAX_PRECISION}")
        # escape symbols: AC codes exactly over V; rANS over 2**esc_bits >= V
        self._esc_bits = rans.uniform_bits(predictor.vocab_size)
        # Speculative decompression (DESIGN.md §9): draft_k > 0 turns on
        # the draft/verify/accept decode path for rANS containers when the
        # predictor exposes verify_steps/rollback (serve.ModelPredictor and
        # the table predictors do). Decoded tokens are identical either
        # way — the coded stream arbitrates every position — so this is
        # purely a wall-clock knob.
        self.draft_k = int(draft_k)
        self.draft = draft if draft is not None else SuffixDraft()
        # adaptive fallthrough: after _spec_window rounds, drop to
        # lock-step for the rest of the group if fewer than _spec_floor
        # drafted tokens per round were accepted (adversarial or
        # unpredictable streams must never pay the (K+1)-deep verify
        # forward for a 1-token/round yield indefinitely)
        self._spec_window = 8
        self._spec_floor = 0.75
        # telemetry (DESIGN.md §10): defaults to the process-global
        # registry; inject a private MetricsRegistry to isolate. Strictly
        # read-only with respect to output bytes (property-tested).
        self._registry = registry if registry is not None else obs.registry()
        self._c_cmp_tokens = self._registry.counter(
            "compress.tokens", "tokens entropy-coded (compress side)")
        self._c_cmp_escapes = self._registry.counter(
            "compress.escapes", "escape symbols emitted while encoding")
        self._c_dec_tokens = self._registry.counter(
            "decompress.tokens", "tokens entropy-decoded")
        self._c_dec_escapes = self._registry.counter(
            "decompress.escapes", "escape symbols hit while decoding")
        # router decision counters (canonical names: obs.metrics.ROUTER_*)
        self._c_route_llm = self._registry.counter(
            obs.ROUTER_CHUNKS_LLM, "chunks routed to the LLM entropy path")
        self._c_route_fb = self._registry.counter(
            obs.ROUTER_CHUNKS_FALLBACK,
            "chunks routed to a fallback byte codec")
        self._c_route_skips = self._registry.counter(
            obs.ROUTER_PROBE_SKIPS,
            "chunks that skipped LLM encode on the probe estimate")
        self._c_route_flips = self._registry.counter(
            obs.ROUTER_FLIPS,
            "chunks where LLM encode ran but the fallback stream won")

    # ------------------------------------------------------------- compress
    def compress(self, tokens: np.ndarray, *,
                 exact: bool = True) -> tuple[bytes, CompressionStats]:
        """Compress a token stream.

        exact=True (default) scores with the *decode program* (the same
        jitted step the decompressor runs), guaranteeing bit-identical CDFs
        on both sides — the lossless requirement. exact=False scores with
        the teacher-forced prefill pass: ~C× fewer model invocations and
        identical in exact arithmetic, but float reduction-order
        differences between the prefill and decode programs can flip a
        quantization bucket on rare tokens, so it is reserved for ratio
        estimation / benchmarking (see DESIGN.md §6).

        With ``route != "llm"`` (DESIGN.md §11) each chunk is first
        offered to the router: the realized best-fallback stream is
        always built, a cheap prefill probe estimates the LLM code
        length, chunks the probe rejects skip the model entirely, and
        every chunk that *was* LLM-encoded still flips to its fallback if
        the fallback stream turned out smaller — so the routed container
        is per-chunk min(LLM, best fallback) and decode follows the
        recorded tags. Only the LLM subset enters the model batch; the
        recorded encode lane count covers exactly those chunks (lane
        *composition* is free — lanes are independent — so later flips
        don't invalidate it).
        """
        tokens = np.asarray(tokens, dtype=np.int32).ravel()
        n = tokens.size
        C = self.chunk_size
        n_chunks = -(-n // C)            # 0 tokens => 0 chunks, no model
        padded = np.zeros(n_chunks * C, dtype=np.int32)
        padded[:n] = tokens
        chunks = padded.reshape(n_chunks, C)
        valid_all = chunk_valid_lengths(n, C)

        stats = CompressionStats(n_tokens=n)
        streams: list = [b""] * n_chunks
        tags = [CODEC_IDS[self.codec]] * n_chunks
        if self.route == ROUTE_LLM:
            decisions = fb = None
            llm_idx = list(range(n_chunks))
        else:
            decisions, fb = self._route_chunks(chunks, valid_all)
            llm_idx = [i for i, d in enumerate(decisions)
                       if d.codec == self.codec]
        # The model program runs at ONE lane count for the whole archive:
        # batch shape is coding geometry (XLA reduction order varies with
        # B), so a ragged tail group is padded with dead lanes rather than
        # shrinking the program — and the count recorded in the v4+ footer
        # is therefore exactly what every LLM chunk was encoded at.
        B = min(self.decode_batch, len(llm_idx))
        with obs.span("compress.job", self._registry):
            for g in range(0, len(llm_idx), max(1, B)):
                sel = llm_idx[g:g + B]
                batch = chunks[sel]
                nb = len(sel)
                if nb < B:
                    batch = np.concatenate(
                        [batch, np.zeros((B - nb, C), np.int32)])
                if exact:
                    with obs.span("compress.score", self._registry):
                        logits = self._score_incremental(batch)
                else:
                    logits = np.asarray(self.predictor.score_chunks(batch))
                enc = self._encode_batch(batch[:nb], logits[:nb],
                                         valid_all[sel], sel, stats)
                for k, j in enumerate(sel):
                    streams[j] = enc[k]
        if decisions is not None:
            self._apply_routes(decisions, fb, streams, tags, valid_all,
                               stats)
        self._c_cmp_tokens.inc(n)
        self._c_cmp_escapes.inc(stats.n_escapes)
        self._registry.counter("compress.chunks").inc(n_chunks)
        blob = write_container(
            streams, version=self.container_version, chunk_size=C,
            n_tokens=n, vocab=self.predictor.vocab_size, topk=self.topk,
            precision=self.precision, codec_id=CODEC_IDS[self.codec],
            encode_batch=B,
            codec_tags=tags if self.container_version == VERSION_V5
            else None)
        stats.payload_bytes = sum(len(s) for s in streams)
        stats.header_bytes = len(blob) - stats.payload_bytes
        return blob, stats

    # -------------------------------------------------------------- routing
    def _route_chunks(self, chunks, valid_all):
        """Route decisions + realized fallback streams for every chunk.
        Forced-fallback routes (``route="zstd"`` etc.) skip the probe:
        every chunk goes to its best fallback. ``route="auto"`` runs one
        prefill probe over the first ``probe_tokens`` positions of all
        chunks and keeps the LLM path unless it is projected to lose by
        more than the safety margin."""
        with obs.span("compress.route", self._registry):
            return route_chunks(self.router, self.predictor, chunks,
                                valid_all, self.codec,
                                auto=self.route == ROUTE_AUTO)

    def _apply_routes(self, decisions, fb, streams, tags, valid_all,
                      stats) -> None:
        """Post-encode routing resolution: install fallback streams for
        probe-skipped / forced chunks, and flip any LLM-encoded chunk
        whose realized fallback stream is strictly smaller. Updates
        streams/tags in place and finalizes per-chunk diagnostics."""
        tel = self._registry.enabled
        by_idx = {d.chunk_index: d for d in stats.chunks}
        for i, d in enumerate(decisions):
            name, s = fb[i]
            if d.codec != self.codec:       # LLM encode never ran
                streams[i] = s
                tags[i] = FALLBACK_CODEC_IDS[name]
                self._c_route_fb.inc()
                if d.llm_bits_est >= 0:     # auto probe said skip
                    self._c_route_skips.inc()
                if tel:
                    stats.chunks.append(obs.ChunkDiagnostics(
                        chunk_index=i, n_tokens=int(valid_all[i]),
                        stream_bytes=len(s), coded_bits=8.0 * len(s),
                        codec=name))
            elif len(s) < len(streams[i]):  # LLM ran and lost: flip
                d.codec, d.flipped = name, True
                streams[i] = s
                tags[i] = FALLBACK_CODEC_IDS[name]
                self._c_route_fb.inc()
                self._c_route_flips.inc()
                if tel and i in by_idx:
                    dg = by_idx[i]
                    dg.codec, dg.stream_bytes = name, len(s)
                    dg.coded_bits = 8.0 * len(s)
            else:
                self._c_route_llm.inc()
        stats.routes = decisions
        stats.chunks.sort(key=lambda c: c.chunk_index)

    def _score_incremental(self, batch: np.ndarray) -> np.ndarray:
        """Teacher-forced scoring through the decode program: one call to
        the decompressor's own jitted step per position, ground-truth token
        fed back. Bit-exact with decompression by construction."""
        B, C = batch.shape
        if hasattr(self.predictor, "set_decode_len"):
            self.predictor.set_decode_len(C)
        state = self.predictor.begin_decode(B)
        prev = np.full((B,), self.predictor.bos_id, dtype=np.int32)
        logits = np.zeros((B, C, self.predictor.vocab_size), np.float32)
        for t in range(C):
            lg, state = self.predictor.decode_step(state, prev)
            logits[:, t] = lg
            prev = batch[:, t]
        return logits

    # -------------------------------------------------------------- encode
    def _encode_batch(self, batch, logits, valid, chunk_indices, stats):
        """Entropy-encode one (nb, C) batch. ``valid`` is the per-row
        valid-token count and ``chunk_indices`` the rows' absolute chunk
        ids (the routed path encodes a non-contiguous LLM subset, so
        neither is derivable from an offset anymore)."""
        valid = np.asarray(valid, np.int64)
        ideal_rows = self._accumulate_ideal_bits(batch, logits, valid,
                                                 stats)
        if self.codec == "rans":
            streams, bits_rows, esc_rows = self._encode_batch_rans(
                batch, logits, valid, stats)
        else:
            streams, bits_rows, esc_rows = self._encode_batch_ac(
                batch, logits, valid, stats)
        if self._registry.enabled:
            h = self._registry.histogram(
                "chunk.bits_per_token",
                "realized payload bits/token per chunk")
            for b, s in enumerate(streams):
                d = obs.ChunkDiagnostics(
                    chunk_index=int(chunk_indices[b]),
                    n_tokens=int(valid[b]),
                    stream_bytes=len(s),
                    coded_bits=float(bits_rows[b]),
                    ideal_bits=float(ideal_rows[b]),
                    n_escapes=int(esc_rows[b]),
                    codec=self.codec)
                stats.chunks.append(d)
                h.observe(d.bits_per_token)
        return streams

    def _accumulate_ideal_bits(self, batch, logits, valid, stats):
        """Accumulate the un-quantized model cross-entropy into ``stats``;
        returns the per-chunk row sums (bits) for diagnostics."""
        lp = logits.astype(np.float64)
        lp -= lp.max(axis=-1, keepdims=True)
        lse = np.log(np.exp(lp).sum(axis=-1))
        tok_lp = np.take_along_axis(lp, batch[..., None].astype(np.int64),
                                    axis=-1)[..., 0]
        m = np.arange(batch.shape[1])[None, :] < valid[:, None]
        rows = ((lse - tok_lp) * m).sum(axis=1) / np.log(2.0)
        stats.ideal_bits += float(rows.sum())
        return rows

    def _encode_batch_rans(self, batch, logits, valid, stats):
        """All B chunk-streams advance through one vectorized coder step
        per token position: vectorized top-K slot lookup, masked escape
        steps, and a single LIFO flush in finish()."""
        B, C = batch.shape
        enc = rans.BatchedRansEncoder(B)
        pos = np.arange(C)[None, :] < valid[:, None]          # (B, C) active
        tel = self._registry.enabled
        bits_rows = np.zeros(B, np.float64)
        esc_rows = np.zeros(B, np.int64)
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)            # (B,C,K),(B,C,K+2)
            match = ids == batch[..., None]
            has = match.any(axis=-1)
            slots = np.where(has, match.argmax(axis=-1), self.topk)
            starts = np.take_along_axis(cdfs, slots[..., None],
                                        axis=-1)[..., 0]
            ends = np.take_along_axis(cdfs, slots[..., None] + 1,
                                      axis=-1)[..., 0]
            esc_rows = (~has & pos).sum(axis=1)
            stats.n_escapes += int(esc_rows.sum())
            if tel:   # quantized code length per chunk (diagnostics only)
                fr = np.maximum((ends - starts).astype(np.float64), 1.0)
                bits_rows = ((self.precision - np.log2(fr)) * pos) \
                    .sum(axis=1) + esc_rows * self._esc_bits
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                enc.put(starts[:, t], ends[:, t] - starts[:, t],
                        self.precision, m)
                em = m & ~has[:, t]
                if em.any():
                    enc.put_uniform(batch[:, t], self._esc_bits, em)
        else:
            # per-position CDFs: a (B, C, V+1) int64 tensor would be tens
            # of GB at production vocab sizes, so quantize one (B, V+1)
            # slab per step — same shape the decode path uses
            lanes = np.arange(B)
            syms_all = batch.astype(np.int64)
            for t in range(C):
                m = pos[:, t]
                if not m.any():
                    break
                cdfs = logits_to_cdf(logits[:, t], self.precision)
                enc.put_symbols(batch[:, t].astype(np.int64), cdfs,
                                self.precision, m)
                if tel:
                    sy = syms_all[:, t]
                    fr = np.maximum(
                        (cdfs[lanes, sy + 1] - cdfs[lanes, sy])
                        .astype(np.float64), 1.0)
                    bits_rows += (self.precision - np.log2(fr)) * m
        return enc.finish(), bits_rows, esc_rows

    def _encode_batch_ac(self, batch, logits, valid, stats):
        """Legacy per-stream arithmetic-coding loops (reference codec)."""
        V = self.predictor.vocab_size
        streams = []
        if self.topk:
            ids, qpmf = topk_quantized_jit(logits, self.topk, self.precision)
            ids, cdfs = build_topk_cdfs(ids, qpmf)
        esc_rows = np.zeros(batch.shape[0], np.int64)
        for b in range(batch.shape[0]):
            enc = ac.ArithmeticEncoder()
            for t in range(int(valid[b])):
                sym = int(batch[b, t])
                if self.topk:
                    slot = np.nonzero(ids[b, t] == sym)[0]
                    if slot.size:
                        enc.encode(int(slot[0]), cdfs[b, t])
                    else:  # escape, then uniform over the full vocab
                        stats.n_escapes += 1
                        esc_rows[b] += 1
                        enc.encode(self.topk, cdfs[b, t])
                        enc.encode(sym, ac.uniform_cdf(V))
                else:
                    cdf = logits_to_cdf(logits[b, t], self.precision)
                    enc.encode(sym, cdf)
            streams.append(enc.finish() if valid[b] else b"")
        # the AC path is the legacy reference: stream bytes supply
        # bits/token in diagnostics, quantized code length is not accrued
        return streams, np.zeros(batch.shape[0], np.float64), esc_rows

    # ----------------------------------------------------------- decompress
    def _check_config(self, info: ContainerInfo) -> None:
        check_container_config(info, vocab=self.predictor.vocab_size,
                               chunk_size=self.chunk_size, topk=self.topk,
                               precision=self.precision)

    def decompress(self, blob: bytes) -> np.ndarray:
        info, streams = parse_container(blob)
        self._check_config(info)
        if info.n_chunks == 0:           # valid empty container
            return np.zeros(0, np.int32)
        if any(not e.is_llm for e in info.entries):
            return self._decompress_mixed(info, streams)
        valid = np.array([e.n_tokens for e in info.entries], np.int64)
        C = self.chunk_size
        out = np.zeros(info.n_chunks * C, dtype=np.int32)
        # decode at the encoder's recorded lane count (v4+); v2/v3 record
        # nothing, so decode_batch must match the encoder's — mirror its
        # min() and dead-lane padding either way
        B = info.encode_batch or min(self.decode_batch, info.n_chunks)
        with obs.span("decompress.job", self._registry):
            for i in range(0, info.n_chunks, B):
                group = streams[i:i + B]
                ng = len(group)
                v = valid[i:i + B]
                if ng < B:
                    group = group + [b""] * (B - ng)
                    v = np.concatenate([v, np.zeros(B - ng, np.int64)])
                dec_tokens = self._decode_group(group, v, info.codec,
                                                chunk_offset=i)
                out[i * C:(i + ng) * C] = dec_tokens[:ng].ravel()
        self._c_dec_tokens.inc(info.n_tokens)
        self._registry.counter("decompress.chunks").inc(info.n_chunks)
        return out[:info.n_tokens]

    def _decode_fallback_entry(self, idx: int, entry: ChunkEntry,
                               stream: bytes, vocab: int) -> np.ndarray:
        """Decode one fallback-tagged chunk stream; structural problems
        become ContainerError (the stream passed its checksum, so any
        failure here means a crafted/mis-tagged container)."""
        try:
            return CodecRouter.decode_fallback(entry.codec_name, stream,
                                               entry.n_tokens, vocab)
        except ValueError as e:
            raise ContainerError(f"corrupt container: chunk {idx}: {e}")

    def _decompress_mixed(self, info: ContainerInfo,
                          streams: list) -> np.ndarray:
        """v5 mixed-codec decode: fallback-tagged chunks decode directly
        on the host; the surviving LLM-tagged chunks are grouped at the
        recorded encode lane count, in tag order. Encode-time group
        *composition* is not (and cannot be) reconstructed — post-encode
        flips changed it — but lanes are independent, so only the lane
        count is coding geometry (DESIGN.md §8)."""
        C = self.chunk_size
        out = np.zeros(info.n_chunks * C, dtype=np.int32)
        llm_idx = [i for i, e in enumerate(info.entries) if e.is_llm]
        with obs.span("decompress.job", self._registry):
            for i, e in enumerate(info.entries):
                if e.is_llm:
                    continue
                toks = self._decode_fallback_entry(i, e, streams[i],
                                                   info.vocab)
                out[i * C:i * C + e.n_tokens] = toks
            B = info.encode_batch or min(self.decode_batch,
                                         max(1, len(llm_idx)))
            for g in range(0, len(llm_idx), B):
                sel = llm_idx[g:g + B]
                group = [streams[j] for j in sel] + [b""] * (B - len(sel))
                v = np.zeros(B, np.int64)
                v[:len(sel)] = [info.entries[j].n_tokens for j in sel]
                toks = self._decode_group(group, v, info.codec,
                                          chunk_offset=sel[0])
                for k, j in enumerate(sel):
                    nt = info.entries[j].n_tokens
                    out[j * C:j * C + nt] = toks[k, :nt]
        self._c_dec_tokens.inc(info.n_tokens)
        self._registry.counter("decompress.chunks").inc(info.n_chunks)
        self._registry.counter(
            "decompress.fallback_chunks",
            "fallback-tagged chunks decoded without the model").inc(
            info.n_chunks - len(llm_idx))
        return out[:info.n_tokens]

    def decompress_range(self, blob: bytes, chunk_start: int,
                         chunk_stop: int | None = None) -> np.ndarray:
        """Random-access decode of chunks [chunk_start, chunk_stop) from a
        v4 container — the index footer locates the streams, so only the
        requested chunks' bytes are read, verified, and decoded. The
        result is bit-identical to the corresponding slice of a full
        ``decompress`` (chunks are independent by construction, §5.4).

        Bit-exactness on real models needs more than chunk independence:
        logits are only reproducible at the encoder's model-program batch
        shape (XLA reduction order varies with B). So the requested chunks
        are regrouped into their *encode-time* groups — stride taken from
        the container's recorded encode batch — and each group runs at its
        encode-time lane count, with unrequested lanes left empty (masked
        out of the coder; lanes are independent, so their content never
        reaches the requested lanes' logits)."""
        info = read_index(blob)
        self._check_config(info)
        if chunk_stop is None:
            chunk_stop = chunk_start + 1
        if chunk_start >= chunk_stop:
            raise ContainerError(
                f"invalid chunk range [{chunk_start}, {chunk_stop}): "
                + ("empty" if chunk_start == chunk_stop else "reversed")
                + " range selects no chunks")
        if chunk_start < 0 or chunk_stop > info.n_chunks:
            raise ContainerError(
                f"chunk range [{chunk_start}, {chunk_stop}) out of bounds: "
                f"container has chunks [0, {info.n_chunks})")
        B = info.encode_batch or min(self.decode_batch, info.n_chunks)
        C = self.chunk_size
        out = np.zeros((chunk_stop - chunk_start) * C, dtype=np.int32)
        if any(not e.is_llm for e in info.entries):
            return self._range_mixed(blob, info, chunk_start, chunk_stop,
                                     B, out)
        total = 0
        for g in range(chunk_start // B, (chunk_stop - 1) // B + 1):
            g_lo = g * B
            g_hi = min(g_lo + B, info.n_chunks)
            sel_lo = max(chunk_start, g_lo)
            sel_hi = min(chunk_stop, g_hi)
            group = [b""] * B               # encode-time lane count, always
            v = np.zeros(B, np.int64)
            for j in range(sel_lo, sel_hi):
                e = info.entries[j]
                s = blob[e.offset:e.offset + e.length]
                if xxh64(s) != e.checksum:
                    raise ContainerError(
                        f"corrupt container: chunk {j} checksum mismatch")
                group[j - g_lo] = s
                v[j - g_lo] = e.n_tokens
            toks = self._decode_group(group, v, info.codec)
            for j in range(sel_lo, sel_hi):
                b = j - g_lo
                out[total:total + int(v[b])] = toks[b, :int(v[b])]
                total += int(v[b])
        return out[:total]

    def _range_mixed(self, blob, info: ContainerInfo, chunk_start: int,
                     chunk_stop: int, B: int, out: np.ndarray) -> np.ndarray:
        """Range decode over a mixed-codec v5 container: fallback-tagged
        chunks decode individually, the requested LLM-tagged chunks are
        grouped at the recorded lane count (composition is free — see
        ``_decompress_mixed``)."""
        toks_by_chunk: dict[int, np.ndarray] = {}
        llm_sel: list[tuple[int, bytes]] = []
        for j in range(chunk_start, chunk_stop):
            e = info.entries[j]
            s = blob[e.offset:e.offset + e.length]
            if xxh64(s) != e.checksum:
                raise ContainerError(
                    f"corrupt container: chunk {j} checksum mismatch")
            if e.is_llm:
                llm_sel.append((j, s))
            else:
                toks_by_chunk[j] = self._decode_fallback_entry(
                    j, e, s, info.vocab)
        for g in range(0, len(llm_sel), B):
            grp = llm_sel[g:g + B]
            group = [s for _, s in grp] + [b""] * (B - len(grp))
            v = np.zeros(B, np.int64)
            v[:len(grp)] = [info.entries[j].n_tokens for j, _ in grp]
            toks = self._decode_group(group, v, info.codec,
                                      chunk_offset=grp[0][0])
            for k, (j, _) in enumerate(grp):
                toks_by_chunk[j] = toks[k, :info.entries[j].n_tokens]
        total = 0
        for j in range(chunk_start, chunk_stop):
            t = toks_by_chunk[j]
            out[total:total + t.size] = t
            total += t.size
        return out[:total]

    # Decode groups take explicit per-stream valid lengths (slot-resumable
    # form): the same inner loops serve full decompress, range decode, and
    # the continuous-batching scheduler's drain path.
    def _decode_group(self, streams, valid: np.ndarray, codec: int,
                      chunk_offset: int = 0):
        with obs.span("decode.group", self._registry):
            if codec == CODEC_RANS:
                if self.draft_k > 0 and hasattr(self.predictor,
                                                "verify_steps"):
                    return self._decode_group_rans_spec(streams, valid,
                                                        chunk_offset)
                return self._decode_group_rans(streams, valid)
            return self._decode_group_ac(streams, valid)

    def _begin_group(self, B, C):
        if hasattr(self.predictor, "set_decode_len"):
            self.predictor.set_decode_len(C)
        state = self.predictor.begin_decode(B)
        prev = np.full((B,), self.predictor.bos_id, dtype=np.int32)
        return state, prev

    def _coder_decode_step(self, dec, logits, m):
        """One vectorized entropy-decode step for the lanes in ``m``:
        fused on-device top-k → quantized CDF → symbol-interval lookup on
        the coder's peeked slot bits (kernels/ac_cdf.py on TPU), then one
        host ``advance``. Bit-identical to the former host path (the CDF
        integers are the same — see cdf.topk_cdf); what changed is that
        no (B, K+2) cumsum or per-row search runs on the host anymore.
        Returns decoded token ids (B,) int64 (0 on inactive lanes)."""
        slots_bits = dec.peek(self.precision)
        if self.topk:
            ids, _, slots, starts, freqs = (np.asarray(a) for a in
                                            topk_cdf_lookup_jit(
                logits, slots_bits.astype(np.int32), self.topk,
                self.precision))
            dec.advance(slots, starts, freqs, self.precision, m)
            esc = m & (slots == self.topk)
            syms = np.take_along_axis(
                ids, np.minimum(slots, self.topk - 1)[:, None],
                axis=-1)[:, 0].astype(np.int64)
            if esc.any():
                u = dec.get_uniform(self._esc_bits, esc)
                syms = np.where(esc, u, syms)
                self._c_dec_escapes.inc(int(esc.sum()))
        else:
            syms, starts, freqs = (np.asarray(a) for a in full_cdf_lookup_jit(
                logits, slots_bits.astype(np.int32), self.precision))
            syms = syms.astype(np.int64)
            dec.advance(syms, starts, freqs, self.precision, m)
        return np.where(m, syms, 0)

    def _round_cdfs(self, logits):
        """Build every CDF row a speculative round can consume in ONE
        device dispatch: ``logits`` (B, K+1, V) -> (ids (B, K+1, k) or
        None, cdf (B, K+1, A+1) int64) where A is the coded alphabet
        (top-k + escape, or V). The integers are exactly the rows the
        fused per-step lookup would build — interval search over
        identical integers is exact — so batching the build per round
        instead of per position changes dispatch count, not bits."""
        if self.topk:
            ids, cdf = topk_cdf_jit(logits, self.topk, self.precision)
            return np.asarray(ids), np.asarray(cdf, np.int64)
        return None, np.asarray(full_cdf_jit(logits, self.precision),
                                np.int64)

    def _coder_decode_host(self, dec, ids, cdf, m):
        """One vectorized entropy-decode step against PREBUILT integer CDF
        rows (``_round_cdfs``): host interval search on the peeked slot
        bits + one ``advance``. The speculative inner loop uses this so a
        round of K+1 positions costs one device dispatch total rather
        than one per position. cdf[:, -1] == 2**precision > slot always,
        so the right-edge sentinel never matches."""
        slot = dec.peek(self.precision)
        lanes = np.arange(cdf.shape[0])
        syms = (cdf[:, 1:-1] <= slot[:, None]).sum(axis=1, dtype=np.int64)
        dec.advance(syms, cdf[lanes, syms],
                    cdf[lanes, syms + 1] - cdf[lanes, syms],
                    self.precision, m)
        if ids is not None:
            esc = m & (syms == self.topk)
            syms = ids[lanes, np.minimum(syms, self.topk - 1)].astype(
                np.int64)
            if esc.any():
                u = dec.get_uniform(self._esc_bits, esc)
                syms = np.where(esc, u, syms)
                self._c_dec_escapes.inc(int(esc.sum()))
        return np.where(m, syms, 0)

    def _decode_group_rans(self, streams, valid):
        """Lock-step batched decode: one model step + one fused CDF/lookup
        dispatch + one vectorized coder step per token position."""
        B, C = len(streams), self.chunk_size
        valid = np.asarray(valid, np.int64)
        dec = rans.BatchedRansDecoder(streams)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev = self._begin_group(B, C)
        for t in range(int(valid.max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            m = valid > t
            syms = self._coder_decode_step(dec, np.asarray(logits), m)
            nxt = np.where(m, syms, 0).astype(np.int32)
            tokens[:, t] = nxt
            prev = nxt
        return tokens

    def _decode_group_rans_spec(self, streams, valid, chunk_offset=0):
        """Speculative batched decode (DESIGN.md §9): per round, a cheap
        self-draft proposes K tokens per lane, ONE verify dispatch scores
        all K+1 positions (predictor.verify_steps — bit-identical to K+1
        lock-step calls by construction), and the rANS decoder accepts
        greedily against the coded stream. A lane keeps consuming verify
        logits while its decoded token matches its draft; the first
        mismatch still yields a correct token (the coder decoded it from
        the real stream — acceptance is exact, not probabilistic), after
        which the lane waits for the next round. Lanes that match all K
        drafts decode a bonus (K+1)-th token from the last verify slot.
        ``predictor.rollback`` then rewinds each lane's cache to its
        accepted frontier. Worst case (every draft wrong) each round
        still decodes 1 token/lane — the lock-step rate — and the
        adaptive fallthrough stops paying the deeper verify forward."""
        B, C = len(streams), self.chunk_size
        K = self.draft_k
        valid = np.asarray(valid, np.int64)
        dec = rans.BatchedRansDecoder(streams)
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev = self._begin_group(B, C)
        pos = np.zeros(B, np.int64)
        if hasattr(self.draft, "begin_group"):
            self.draft.begin_group(chunk_offset)
        rounds = drafted_hits = offered = rollbacks = 0
        tel = self._registry.enabled
        depth_h = self._registry.histogram(
            "spec.accept_depth",
            "tokens decoded per lane per speculative round") if tel else None
        lanes = np.arange(B)
        while True:
            active = pos < valid
            if not active.any():
                break
            if rounds >= self._spec_window and \
                    drafted_hits < self._spec_floor * rounds:
                self._registry.counter(
                    "spec.lockstep_fallthroughs",
                    "groups that abandoned drafting mid-decode").inc()
                self._lockstep_tail(dec, state, prev, pos, valid, tokens)
                break
            with obs.span("decode.verify_round", self._registry):
                drafts = np.clip(
                    self.draft.propose(tokens, pos, K), 0,
                    self.predictor.vocab_size - 1).astype(np.int32)
                seq = np.concatenate([prev[:, None], drafts], axis=1)
                logits, snaps = self.predictor.verify_steps(state, seq)
                ids_a, cdf_a = self._round_cdfs(np.asarray(logits))
                acc = np.zeros(B, np.int64)
                chain = active.copy()
                for j in range(K + 1):
                    mj = chain & (pos + j < valid)
                    if not mj.any():
                        break
                    syms = self._coder_decode_host(
                        dec, None if ids_a is None else ids_a[:, j],
                        cdf_a[:, j], mj)
                    tokens[mj, (pos + j)[mj]] = syms[mj]
                    acc[mj] += 1
                    chain = mj & (syms == drafts[:, j]) if j < K else \
                        np.zeros(B, bool)
                # lane b resumed from the snapshot after acc[b] verify
                # inputs: [prev, d_0..d_{acc-2}] — the acc'th accepted
                # token is NOT fed back here; it is the next round's `prev`
                state = self.predictor.rollback(snaps, acc.astype(np.int32))
                pos += acc
                prev = np.where(acc > 0,
                                tokens[lanes, np.maximum(pos - 1, 0)],
                                prev).astype(np.int32)
                rounds += 1
                offered += int(active.sum()) * K
                drafted_hits += int(np.maximum(acc - 1, 0).sum())
                rollbacks += int((active & (acc < K + 1)).sum())
                if tel:
                    depth_h.observe_many(acc[active])
        self._registry.counter(
            "spec.rounds", "speculative draft/verify rounds").inc(rounds)
        self._registry.counter(
            "spec.drafted_tokens", "draft slots offered for "
            "verification").inc(offered)
        self._registry.counter(
            "spec.drafted_accepted",
            "drafted tokens accepted beyond the per-round floor of "
            "1").inc(drafted_hits)
        self._registry.counter(
            "spec.rollbacks", "lane cache rewinds (acc < K+1)").inc(
            rollbacks)
        return tokens

    def _lockstep_tail(self, dec, state, prev, pos, valid, tokens):
        """Finish a group lock-step from per-lane positions — the
        speculative path's fallthrough when drafts stop earning their
        verify depth. Mutates pos/tokens in place."""
        B = tokens.shape[0]
        lanes = np.arange(B)
        while True:
            m = pos < valid
            if not m.any():
                return
            logits, state = self.predictor.decode_step(state, prev)
            syms = self._coder_decode_step(dec, np.asarray(logits), m)
            tokens[m, pos[m]] = syms[m]
            pos += m
            prev = np.where(m, syms, prev).astype(np.int32)

    def _decode_group_ac(self, streams, valid):
        """Legacy per-stream arithmetic decode (reference codec + v2)."""
        V = self.predictor.vocab_size
        B, C = len(streams), self.chunk_size
        valid = np.asarray(valid, np.int64)
        decoders = [ac.ArithmeticDecoder(s) for s in streams]
        tokens = np.zeros((B, C), dtype=np.int32)
        state, prev = self._begin_group(B, C)
        for t in range(int(valid.max(initial=0))):
            logits, state = self.predictor.decode_step(state, prev)
            logits = np.asarray(logits)
            if self.topk:
                ids, qpmf = topk_quantized_jit(logits, self.topk,
                                               self.precision)
                ids = np.asarray(ids)
                cdfs = pmf_to_cdf(np.asarray(qpmf))
            nxt = np.zeros((B,), dtype=np.int32)
            for b in range(B):
                if t >= valid[b]:
                    continue
                if self.topk:
                    slot = decoders[b].decode(cdfs[b])
                    if slot == self.topk:  # escape
                        sym = decoders[b].decode(ac.uniform_cdf(V))
                    else:
                        sym = int(ids[b, slot])
                else:
                    cdf = logits_to_cdf(logits[b], self.precision)
                    sym = decoders[b].decode(cdf)
                tokens[b, t] = sym
                nxt[b] = sym
            prev = nxt
        return tokens

    # ------------------------------------------------------------- metrics
    @staticmethod
    def ratio(original_bytes: int, blob: bytes) -> float:
        return original_bytes / max(1, len(blob))
