"""Model distribution -> quantized integer CDFs.

The bridge between the LLM (which emits logits) and the arithmetic coder
(which consumes integer CDFs). Two paths:

* ``quantize_pmf`` / ``logits_to_cdf`` — full-vocabulary CDF. Exact
  quantization with every-symbol-nonzero guarantee; the coder overhead vs
  true cross-entropy is O(V / 2^precision) bits/token.

* ``logits_to_topk_cdf`` — **top-K + escape** (beyond-paper optimization,
  still lossless): only the K most likely tokens get individual slots; all
  remaining mass goes to one ESCAPE symbol. If the actual token escapes, it
  is coded uniformly over the vocabulary (log2 V extra bits). For a
  well-matched predictor on LLM-generated text, escapes are rare, and the
  host coder now touches K+1 integers per token instead of V=151936.
  The fused TPU kernel for this transform lives in kernels/ac_cdf.py.

All jnp functions are jit-safe and vmap-able over leading axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_PRECISION = 16


def quantize_cdf_points(probs: jnp.ndarray,
                        precision: int = DEFAULT_PRECISION) -> jnp.ndarray:
    """Quantize a pmf (last axis, size V) into integer CDF interior points
    by **cumulative rounding**:

        cdf_i = round(P(x <= i) * (T - V)) + (i + 1),   i = 0..V-1

    Properties: strictly increasing (every symbol gets >= 1 quantum),
    cdf_{V-1} == T exactly, single streaming cumsum (no sort) — which is
    what makes the fused TPU kernel (kernels/ac_cdf.py) a one-pass
    prefix-scan. Returns int32 (..., V) = cdf[1:] (prepend 0 for the coder).
    """
    V = probs.shape[-1]
    T = 1 << precision
    if T <= V:
        raise ValueError(f"precision {precision} too small for vocab {V}")
    budget = jnp.float32(T - V)
    cum = jnp.cumsum(probs.astype(jnp.float32), axis=-1)
    cum = cum / cum[..., -1:]                       # exact 1.0 tail
    pts = jnp.floor(cum * budget + 0.5).astype(jnp.int32)
    return pts + (1 + jnp.arange(V, dtype=jnp.int32))


def quantize_pmf(probs: jnp.ndarray, precision: int = DEFAULT_PRECISION) -> jnp.ndarray:
    """Integer pmf (sums to 2**precision, every entry >= 1) via
    cumulative rounding — see quantize_cdf_points."""
    pts = quantize_cdf_points(probs, precision)
    return jnp.diff(pts, axis=-1, prepend=jnp.zeros_like(pts[..., :1]))


def pmf_to_cdf(q: np.ndarray) -> np.ndarray:
    """Integer pmf -> CDF array (numpy, host side)."""
    q = np.asarray(q, dtype=np.int64)
    cdf = np.zeros(q.shape[:-1] + (q.shape[-1] + 1,), dtype=np.int64)
    np.cumsum(q, axis=-1, out=cdf[..., 1:])
    return cdf


@jax.jit
def _full_pmf(logits: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def logits_to_cdf(logits, precision: int = DEFAULT_PRECISION) -> np.ndarray:
    """Full-vocab quantized CDF(s) from logits. Returns numpy int64 (..., V+1)."""
    probs = _full_pmf(jnp.asarray(logits))
    q = quantize_pmf(probs, precision)
    return pmf_to_cdf(np.asarray(q))


def topk_quantized(logits: jnp.ndarray, k: int,
                   precision: int = DEFAULT_PRECISION,
                   temperature: float = 1.0):
    """Fused (on TPU: see kernels/ac_cdf.py) top-K + escape quantization.

    Returns (ids, qpmf):
      ids  int32 (..., k)    — vocabulary ids of the top-k slots
      qpmf int32 (..., k+1)  — integer pmf over [k slots, ESCAPE], sums to 2**precision

    Escape slot always has >= 1 quantum, so out-of-top-K tokens stay codable.
    """
    logits = logits.astype(jnp.float32) / temperature
    top_vals, ids = jax.lax.top_k(logits, k)
    # Stable softmax over the full vocab, then renormalize the top-k slice.
    m = jnp.max(logits, axis=-1, keepdims=True)
    denom = jnp.sum(jnp.exp(logits - m), axis=-1, keepdims=True)
    top_p = jnp.exp(top_vals - m) / denom          # (..., k), sums to <= 1
    escape_p = jnp.clip(1.0 - jnp.sum(top_p, axis=-1, keepdims=True), 0.0, 1.0)
    pmf = jnp.concatenate([top_p, escape_p], axis=-1)
    pmf = pmf / jnp.sum(pmf, axis=-1, keepdims=True)
    q = quantize_pmf(pmf, precision)
    return ids, q


topk_quantized_jit = jax.jit(topk_quantized, static_argnums=(1, 2))


def topk_cdf(logits: jnp.ndarray, k: int,
             precision: int = DEFAULT_PRECISION):
    """Fused top-K selection + quantization + **integer CDF build** in one
    device computation: returns (ids (..., k) int32, cdf (..., k+2) int32)
    with cdf[..., 0] == 0 and cdf[..., -1] == 2**precision.

    The CDF rows are bit-identical to the host path
    ``pmf_to_cdf(topk_quantized(logits, k, precision)[1])``: the pmf is the
    same float computation and the cumsum is exact integer arithmetic
    (2**precision <= 2**23 fits int32), so golden containers are
    unaffected. This is what removes the per-step host-side
    ``pmf_to_cdf`` slicing from the decode loops; on TPU the same
    transform runs as the fused Pallas kernel (kernels/ac_cdf.py
    ``topk_cdf_points``)."""
    ids, q = topk_quantized(logits, k, precision)
    zero = jnp.zeros_like(q[..., :1])
    cdf = jnp.concatenate([zero, jnp.cumsum(q, axis=-1)], axis=-1)
    return ids, cdf


topk_cdf_jit = jax.jit(topk_cdf, static_argnums=(1, 2))


def topk_cdf_lookup(logits: jnp.ndarray, slots: jnp.ndarray, k: int,
                    precision: int = DEFAULT_PRECISION):
    """Fused decode step: top-K + CDF build + **symbol-interval lookup**
    for the rANS decoder's peeked slot bits, all on device.

    ``slots`` (...,) int32 are the coder states' low ``precision`` bits
    (``BatchedRansDecoder.peek``). Returns (ids, cdf, syms, starts,
    freqs): syms[i] is the unique s with cdf[s] <= slot < cdf[s+1]
    (s == k means ESCAPE), and (starts, freqs) are that symbol's interval
    — exactly what ``BatchedRansDecoder.advance`` consumes."""
    ids, cdf = topk_cdf(logits, k, precision)
    syms = jnp.sum((cdf[..., 1:] <= slots[..., None]).astype(jnp.int32),
                   axis=-1)
    starts = jnp.take_along_axis(cdf, syms[..., None], axis=-1)[..., 0]
    ends = jnp.take_along_axis(cdf, syms[..., None] + 1, axis=-1)[..., 0]
    return ids, cdf, syms, starts, ends - starts


topk_cdf_lookup_jit = jax.jit(topk_cdf_lookup, static_argnums=(2, 3))


def full_cdf(logits: jnp.ndarray, precision: int = DEFAULT_PRECISION):
    """Full-vocabulary quantized CDF rows (..., V+1) int32 built entirely
    on device (leading 0 included) — bit-identical integers to the host
    ``logits_to_cdf`` (the interior points are the same cumulative-rounding
    values; no diff+recumsum detour)."""
    pts = quantize_cdf_points(_full_pmf(logits), precision)
    zero = jnp.zeros_like(pts[..., :1])
    return jnp.concatenate([zero, pts], axis=-1)


full_cdf_jit = jax.jit(full_cdf, static_argnums=(1,))


def full_cdf_lookup(logits: jnp.ndarray, slots: jnp.ndarray,
                    precision: int = DEFAULT_PRECISION):
    """Full-vocabulary analog of ``topk_cdf_lookup``: quantized-CDF build
    + symbol-interval lookup on device (no (B, V+1) host cumsum in the
    decode loop). Returns (syms, starts, freqs) — the decoded symbols ARE
    the token ids here. Bit-identical to searching the host
    ``logits_to_cdf`` rows: the interior points are the same integers."""
    pts = quantize_cdf_points(_full_pmf(logits), precision)   # (..., V)
    syms = jax.vmap(lambda p, s: jnp.searchsorted(p, s, side="right"))(
        pts.reshape(-1, pts.shape[-1]),
        slots.astype(pts.dtype).reshape(-1)).reshape(slots.shape)
    starts = jnp.where(
        syms > 0,
        jnp.take_along_axis(pts, jnp.maximum(syms - 1, 0)[..., None],
                            axis=-1)[..., 0], 0)
    ends = jnp.take_along_axis(pts, syms[..., None], axis=-1)[..., 0]
    return syms, starts, ends - starts


full_cdf_lookup_jit = jax.jit(full_cdf_lookup, static_argnums=(2,))


def topk_quantized_sharded(logits, k: int, precision: int, mesh,
                           batch_axes=("data",)):
    """Hierarchical top-K + escape quantization for VOCAB-SHARDED logits.

    Plain lax.top_k over a sharded dim makes the SPMD partitioner
    all-gather the full fp32 logits (measured 38 GiB + 608 GiB per
    1-layer prefill probe on qwen3-1.7b!). Instead, inside shard_map:
    each vocab shard computes its local top-k, the tp*k candidates
    (not V) are all-gathered, and the softmax denominator is a psum of
    local sum-exps. Collective bytes per token drop from O(V) to O(tp*k).

    logits (..., V) sharded (batch_axes..., None, 'model').
    Returns (ids, qpmf) replicated over 'model'.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    tp = mesh.shape["model"]
    V = logits.shape[-1]
    assert V % tp == 0
    v_loc = V // tp

    def mapped(lg):
        lg = lg.astype(jnp.float32)
        lmax = jnp.max(lg, axis=-1, keepdims=True)
        gmax = jax.lax.pmax(lmax, "model")
        denom = jax.lax.psum(
            jnp.sum(jnp.exp(lg - gmax), axis=-1, keepdims=True), "model")
        vals, idx = jax.lax.top_k(lg, k)
        idx = idx + jax.lax.axis_index("model") * v_loc
        cand_v = jax.lax.all_gather(vals, "model", axis=-1, tiled=True)
        cand_i = jax.lax.all_gather(idx, "model", axis=-1, tiled=True)
        vals2, pos = jax.lax.top_k(cand_v, k)
        ids = jnp.take_along_axis(cand_i, pos, axis=-1)
        top_p = jnp.exp(vals2 - gmax) / denom
        escape_p = jnp.clip(1.0 - jnp.sum(top_p, axis=-1, keepdims=True),
                            0.0, 1.0)
        pmf = jnp.concatenate([top_p, escape_p], axis=-1)
        pmf = pmf / jnp.sum(pmf, axis=-1, keepdims=True)
        return ids.astype(jnp.int32), quantize_pmf(pmf, precision)

    # batch axes on dim 0, None in between, 'model' on the vocab dim
    nd = logits.ndim
    dims = [None] * nd
    dims[0] = tuple(batch_axes) if batch_axes else None
    dims[-1] = "model"
    in_spec = P(*dims)
    out_dims = list(dims)
    out_dims[-1] = None
    out_spec = P(*out_dims)
    return shard_map(mapped, mesh=mesh, in_specs=in_spec,
                     out_specs=(out_spec, out_spec), check_rep=False)(logits)


def build_topk_cdfs(ids: np.ndarray, qpmf: np.ndarray):
    """Host-side: (ids, qpmf) -> per-position (ids, cdf) pairs."""
    return np.asarray(ids), pmf_to_cdf(np.asarray(qpmf))


def coding_cost_bits(logits, tokens) -> float:
    """Ideal (un-quantized) coding cost of ``tokens`` under ``logits`` in bits.
    This is the paper's Eq. (4) summed over the sequence; the measured AC
    output should exceed it only by quantization + termination overhead."""
    logp = jax.nn.log_softmax(jnp.asarray(logits).astype(jnp.float32), axis=-1)
    tok = jnp.asarray(tokens)
    nll = -jnp.take_along_axis(logp, tok[..., None], axis=-1)[..., 0]
    return float(jnp.sum(nll) / jnp.log(2.0))
