"""Compressibility analysis tools — paper §3 (Fig 2, Table 2).

N-gram redundancy, entropy-per-byte at several tokenization granularities,
and mutual information between consecutive words.
"""
from __future__ import annotations

import math
import re
from collections import Counter

import numpy as np


def ngram_top_coverage(text: str, n: int, top: int = 10) -> float:
    """Fraction of all n-grams covered by the ``top`` most frequent ones
    (paper Fig 2: low coverage => dedup won't help)."""
    toks = text.split()
    grams = list(zip(*(toks[i:] for i in range(n)))) if len(toks) >= n else []
    if not grams:
        return 0.0
    c = Counter(grams)
    return sum(f for _, f in c.most_common(top)) / len(grams)


def _entropy(counter: Counter) -> float:
    total = sum(counter.values())
    return -sum((f / total) * math.log2(f / total) for f in counter.values())


def char_entropy_per_byte(text: str) -> float:
    c = Counter(text)
    avg_len = float(np.mean([len(ch.encode()) for ch in c.elements()]))
    return _entropy(c) / avg_len


def word_entropy_per_byte(text: str) -> float:
    words = re.findall(r"\S+", text)
    c = Counter(words)
    total = sum(c.values())
    avg_len = sum(f * (len(w.encode()) + 1) for w, f in c.items()) / total
    return _entropy(c) / avg_len


def subword_entropy_per_byte(text: str, piece: int = 4) -> float:
    """Fixed-length piece tokenization as a BPE stand-in (deterministic,
    dependency-free)."""
    pieces = [text[i:i + piece] for i in range(0, len(text), piece)]
    c = Counter(pieces)
    total = sum(c.values())
    avg_len = sum(f * len(p.encode()) for p, f in c.items()) / total
    return _entropy(c) / avg_len


def consecutive_word_mutual_information(text: str) -> float:
    """MI(W_i; W_{i+1}) in bits — paper Table 2's predictability probe."""
    words = re.findall(r"\S+", text)
    if len(words) < 2:
        return 0.0
    uni = Counter(words)
    bi = Counter(zip(words, words[1:]))
    n_uni = sum(uni.values())
    n_bi = sum(bi.values())
    mi = 0.0
    for (a, b), f in bi.items():
        p_ab = f / n_bi
        p_a = uni[a] / n_uni
        p_b = uni[b] / n_uni
        mi += p_ab * math.log2(p_ab / (p_a * p_b))
    return mi


def analyze(text: str) -> dict[str, float]:
    return {
        "char_entropy_per_byte": round(char_entropy_per_byte(text), 3),
        "subword_entropy_per_byte": round(subword_entropy_per_byte(text), 3),
        "word_entropy_per_byte": round(word_entropy_per_byte(text), 3),
        "mutual_info_bits": round(consecutive_word_mutual_information(text), 3),
        "unigram_top10_coverage": round(ngram_top_coverage(text, 1), 4),
        "bigram_top10_coverage": round(ngram_top_coverage(text, 2), 4),
        "trigram_top10_coverage": round(ngram_top_coverage(text, 3), 4),
        "fourgram_top10_coverage": round(ngram_top_coverage(text, 4), 4),
    }
