"""Batched interleaved rANS entropy coder (codec id 1 of the container).

The host-side successor to the reference arithmetic coder in ``ac.py``.
`ac.py` is a per-stream, bit-by-bit Python loop: correct, portable, and
the throughput bottleneck of the whole system once the model runs on the
accelerator (the paper's coder cost, §4.3, is what bounds tokens/s at
scale). rANS (Duda 2014) admits a *vectorized interleaved* formulation:
the coder state of every stream in a decode batch advances with a
handful of numpy ufunc calls per token position, so host cost per token
is O(1) numpy ops amortized over B streams instead of B Python loops.

Layout and invariants
---------------------
* One independent byte stream per chunk (the container keeps per-chunk
  framing, so groups of chunks remain independently decodable).
* State: ``uint64`` vector over all B streams, normalized interval
  ``[RANS_L, 256 * RANS_L)`` with byte-wise renormalization.
* Symbol model: the same quantized integer CDFs the Pallas ``ac_cdf``
  kernel / ``core.cdf`` emit. rANS requires ``total`` to divide the
  interval bound, so **all totals must be powers of two** — which the
  quantizer guarantees (``total == 2**precision``) and the escape path
  achieves by coding uniformly over ``2**ceil(log2 V)`` (≤ 1 extra bit
  per escape vs. the AC's exact uniform-over-V; escapes are rare).
* Encoding is LIFO: ``put*`` calls only record (start, freq, bits)
  triples; ``finish()`` runs the vectorized coder backwards over the
  recorded steps, writing each stream's bytes back-to-front so the
  decoder consumes them strictly forward. Each stream is framed as
  ``u32-LE final state || renorm bytes``.
* Decoding is streaming-forward and vectorized: one masked coder step
  per token position across all active streams.

Bit-exactness: everything is integer arithmetic on int/uint64 numpy
arrays — no floats anywhere — so encode/decode are portable across
platforms by construction, same as the reference AC.
"""
from __future__ import annotations

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

RANS_L = 1 << 23          # lower bound of the normalized state interval
_STATE_BYTES = 4          # final state flush (state < 256 * RANS_L < 2**32)
MAX_PRECISION = 23        # total = 2**bits must satisfy total <= RANS_L


def _count_flush(n_streams: int, n_bytes: int) -> None:
    """Cold-path coder telemetry — stream flushes only, never per step.
    Goes to the process-global registry: the coder has no injection point
    and flush counts are process-wide facts."""
    reg = _metrics.registry()
    reg.counter("rans.streams_flushed",
                "rANS streams materialized").inc(n_streams)
    reg.counter("rans.stream_bytes",
                "total rANS payload bytes flushed").inc(n_bytes)

_U64 = np.uint64
_U8 = np.uint8


def uniform_bits(n: int) -> int:
    """Bits of the power-of-two uniform alphabet covering n symbols
    (the rANS escape path: code uniformly over 2**uniform_bits(V))."""
    if n <= 1:
        return 1
    return int(n - 1).bit_length()


def _as_u64(a) -> np.ndarray:
    return np.asarray(a, dtype=np.int64).astype(_U64)


def _find_slots(cdfs: np.ndarray, slots: np.ndarray) -> np.ndarray:
    """Vectorized per-row symbol lookup: sym[b] s.t.
    cdfs[b, sym] <= slots[b] < cdfs[b, sym+1]."""
    n = cdfs.shape[-1] - 1
    if n <= 1024:
        # one broadsided comparison — fastest for top-K-sized alphabets
        return (cdfs[:, :-1] <= slots[:, None]).sum(axis=1).astype(
            np.int64) - 1
    out = np.empty(cdfs.shape[0], np.int64)
    for b in range(cdfs.shape[0]):  # full-vocab alphabets: log-time per row
        out[b] = np.searchsorted(cdfs[b], slots[b], side="right") - 1
    return out


class BatchedRansEncoder:
    """LIFO interleaved encoder over ``n_streams`` independent streams.

    ``put*`` records one coder step per *active* stream (masked steps
    leave a stream untouched); ``finish()`` materializes the byte
    streams. All (start, freq) pairs must come from CDFs whose total is
    ``2**bits`` with ``bits <= MAX_PRECISION`` and ``freq >= 1``.
    """

    def __init__(self, n_streams: int):
        self.n_streams = int(n_streams)
        self._steps: list[tuple] = []   # (starts u64, freqs u64, bits, mask)
        self._counts = np.zeros(self.n_streams, np.int64)

    # ------------------------------------------------------------ recording
    def put(self, starts, freqs, bits: int, mask=None) -> None:
        """Record one step: stream b encodes the slot [starts[b],
        starts[b]+freqs[b]) of a total-2**bits alphabet."""
        if not 0 < bits <= MAX_PRECISION:
            raise ValueError(f"bits {bits} out of range (1..{MAX_PRECISION})")
        # astype() below always copies, so the stored arrays are private
        starts = _as_u64(np.broadcast_to(starts, (self.n_streams,)))
        freqs = _as_u64(np.broadcast_to(freqs, (self.n_streams,)))
        if mask is not None:
            mask = np.asarray(mask, bool).copy()
            if (freqs[mask] == 0).any():
                raise ValueError("zero-frequency symbol")
            # sanitize inactive lanes so finish() never divides by zero
            freqs = np.where(mask, freqs, _U64(1))
            starts = np.where(mask, starts, _U64(0))
            self._counts[mask] += 1
        else:
            if (freqs == 0).any():
                raise ValueError("zero-frequency symbol")
            self._counts += 1
        self._steps.append((starts, freqs, int(bits), mask))

    def put_symbols(self, symbols, cdfs: np.ndarray, bits: int,
                    mask=None) -> None:
        """Record symbols[b] under per-stream CDF rows cdfs (B, n+1)."""
        symbols = np.asarray(symbols, np.int64)
        cdfs = np.asarray(cdfs, np.int64)
        starts = np.take_along_axis(cdfs, symbols[:, None], axis=1)[:, 0]
        ends = np.take_along_axis(cdfs, symbols[:, None] + 1, axis=1)[:, 0]
        self.put(starts, ends - starts, bits, mask)

    def put_uniform(self, symbols, bits: int, mask=None) -> None:
        """Record symbols[b] coded uniformly over 2**bits (freq 1)."""
        self.put(symbols, np.ones(self.n_streams, np.int64), bits, mask)

    # --------------------------------------------------------------- flush
    def finish(self) -> list[bytes]:
        """Run the coder backwards over all recorded steps and return one
        framed byte string per stream. Streams with zero recorded steps
        return ``b""`` (nothing to decode, nothing stored)."""
        with _trace.span("rans.finish"):
            return self._finish()

    def _finish(self) -> list[bytes]:
        B = self.n_streams
        # worst case 3 payload bytes per step (bits <= 23) + state flush
        cap = 3 * (int(self._counts.max()) if B else 0) + _STATE_BYTES + 8
        buf = np.zeros((B, cap), _U8)
        cur = np.full(B, cap, np.int64)
        x = np.full(B, RANS_L, _U64)
        for starts, freqs, bits, mask in reversed(self._steps):
            # renormalize: shift out low bytes while x would overflow
            x_max = ((_U64(RANS_L >> bits) << _U64(8)) * freqs)
            active = (x >= x_max) if mask is None else (mask & (x >= x_max))
            while active.any():
                idx = np.nonzero(active)[0]
                cur[idx] -= 1
                buf[idx, cur[idx]] = (x[idx] & _U64(0xFF)).astype(_U8)
                x[idx] >>= _U64(8)
                active[idx] = x[idx] >= x_max[idx]
            enc = ((x // freqs) << _U64(bits)) + (x % freqs) + starts
            x = enc if mask is None else np.where(mask, enc, x)
        out: list[bytes] = []
        for b in range(B):
            if self._counts[b] == 0:
                out.append(b"")
                continue
            state = int(x[b])
            head = bytes((state >> (8 * i)) & 0xFF
                         for i in range(_STATE_BYTES))
            out.append(head + buf[b, cur[b]:].tobytes())
        _count_flush(len(out), sum(len(s) for s in out))
        return out


def _encode_steps(steps: list[tuple[int, int, int]]) -> bytes:
    """Scalar backward coder over one stream's recorded (start, freq, bits)
    steps — byte-identical to ``BatchedRansEncoder.finish()`` for the same
    step sequence (property-tested in tests/test_rans.py)."""
    if not steps:
        return b""
    x = RANS_L
    tail = bytearray()
    for start, freq, bits in reversed(steps):
        x_max = ((RANS_L >> bits) << 8) * freq
        while x >= x_max:
            tail.append(x & 0xFF)
            x >>= 8
        x = ((x // freq) << bits) + (x % freq) + start
    tail.reverse()
    return x.to_bytes(_STATE_BYTES, "little") + bytes(tail)


class SlotRansEncoder:
    """Per-slot LIFO recorder for the continuous-batching scheduler.

    ``BatchedRansEncoder`` flushes every stream at once in ``finish()`` —
    right for lock-step groups, wrong for a slot machine where chunk
    streams complete out of order. This variant records steps per slot
    and materializes one slot's bytes the moment its chunk finishes
    (``flush_slot``), freeing the slot for refill while its neighbours
    keep coding. Output framing is byte-identical to the batched encoder.
    """

    def __init__(self, n_slots: int):
        self.n_slots = int(n_slots)
        self._steps: list[list[tuple[int, int, int]]] = \
            [[] for _ in range(self.n_slots)]

    def put(self, starts, freqs, bits: int, mask=None) -> None:
        """Record one step for every active slot (see BatchedRansEncoder)."""
        if not 0 < bits <= MAX_PRECISION:
            raise ValueError(f"bits {bits} out of range (1..{MAX_PRECISION})")
        starts = np.broadcast_to(np.asarray(starts, np.int64),
                                 (self.n_slots,))
        freqs = np.broadcast_to(np.asarray(freqs, np.int64), (self.n_slots,))
        active = (np.ones(self.n_slots, bool) if mask is None
                  else np.asarray(mask, bool))
        if (freqs[active] <= 0).any():
            raise ValueError("zero-frequency symbol")
        for b in np.nonzero(active)[0]:
            self._steps[b].append((int(starts[b]), int(freqs[b]), bits))

    def put_symbols(self, symbols, cdfs: np.ndarray, bits: int,
                    mask=None) -> None:
        symbols = np.asarray(symbols, np.int64)
        cdfs = np.asarray(cdfs, np.int64)
        starts = np.take_along_axis(cdfs, symbols[:, None], axis=1)[:, 0]
        ends = np.take_along_axis(cdfs, symbols[:, None] + 1, axis=1)[:, 0]
        self.put(starts, ends - starts, bits, mask)

    def put_uniform(self, symbols, bits: int, mask=None) -> None:
        self.put(symbols, np.ones(self.n_slots, np.int64), bits, mask)

    def pending(self, slot: int) -> int:
        """Number of recorded, unflushed steps in ``slot``."""
        return len(self._steps[slot])

    def slot_cost_bits(self, slot: int) -> float:
        """Quantized code length of the slot's recorded steps,
        sum(bits - log2 freq) — per-chunk diagnostics, read before
        ``flush_slot`` clears the record. Cold path: one numpy pass over
        the chunk, nothing per step."""
        steps = self._steps[slot]
        if not steps:
            return 0.0
        a = np.asarray(steps, np.float64)          # rows: (start, freq, bits)
        return float(a[:, 2].sum() - np.log2(a[:, 1]).sum())

    def flush_slot(self, slot: int) -> bytes:
        """Materialize and clear one slot's stream (LIFO backward pass)."""
        with _trace.span("rans.flush_slot"):
            out = _encode_steps(self._steps[slot])
            self._steps[slot] = []
            _count_flush(1, len(out))
            return out


class BatchedRansDecoder:
    """Streaming forward decoder over B independent framed streams.

    Mirror image of ``BatchedRansEncoder``: call ``get``/``get_uniform``
    in the exact order (and with the exact masks) the encoder ``put`` —
    the adaptive caller (LLMCompressor) reproduces that order because
    each decoded token feeds the model that produces the next CDF.

    Slots are individually re-attachable (``attach``/``detach``) so the
    continuous-batching scheduler can point a finished slot at the next
    chunk stream without rebuilding the decoder.
    """

    def __init__(self, streams: list[bytes]):
        B = len(streams)
        self._lens = np.array([len(s) for s in streams], np.int64)
        cap = max(int(self._lens.max(initial=0)), _STATE_BYTES)
        self._buf = np.zeros((B, cap), _U8)
        for b, s in enumerate(streams):
            if s:
                self._buf[b, :len(s)] = np.frombuffer(s, _U8)
        self._x = np.zeros(B, _U64)
        for i in range(_STATE_BYTES):
            self._x |= self._buf[:, i].astype(_U64) << _U64(8 * i)
        self._cur = np.full(B, _STATE_BYTES, np.int64)
        #: interval freqs of the most recent ``advance``/``get`` call
        #: (inactive lanes read 1) — the per-chunk diagnostics accrual
        #: reads this instead of recomputing CDF lookups (DESIGN.md §10)
        self.last_freq = np.ones(B, np.int64)

    # ------------------------------------------------- per-slot attachment
    def attach(self, slot: int, data: bytes) -> None:
        """Point ``slot`` at a fresh framed stream (state reloaded from its
        header). The other slots' positions and states are untouched."""
        n = len(data)
        if 0 < n < _STATE_BYTES:
            raise ValueError(f"stream shorter than state header ({n} bytes)")
        if n > self._buf.shape[1]:
            grown = np.zeros((self._buf.shape[0], n), _U8)
            grown[:, :self._buf.shape[1]] = self._buf
            self._buf = grown
        self._buf[slot, :n] = np.frombuffer(data, _U8)
        self._lens[slot] = n
        self._cur[slot] = _STATE_BYTES
        x = 0
        for i in range(_STATE_BYTES - 1, -1, -1):
            x = (x << 8) | int(self._buf[slot, i])
        self._x[slot] = _U64(x) if n else _U64(0)

    def detach(self, slot: int) -> None:
        """Mark ``slot`` empty (no stream attached)."""
        self._lens[slot] = 0
        self._cur[slot] = _STATE_BYTES
        self._x[slot] = _U64(0)

    def exhausted(self, slot: int) -> bool:
        """True iff the slot's stream decoded cleanly to its end: every
        byte consumed and the coder state back at its initial value —
        the rANS analogue of a well-formed EOF (decode inverts encode
        exactly, so the state must return to RANS_L)."""
        if self._lens[slot] == 0:
            return True
        return (int(self._cur[slot]) == int(self._lens[slot])
                and int(self._x[slot]) == RANS_L)

    def _renorm(self, mask: np.ndarray) -> None:
        active = mask & (self._x < _U64(RANS_L)) & (self._cur < self._lens)
        while active.any():
            idx = np.nonzero(active)[0]
            self._x[idx] = ((self._x[idx] << _U64(8))
                            | self._buf[idx, self._cur[idx]].astype(_U64))
            self._cur[idx] += 1
            active[idx] = ((self._x[idx] < _U64(RANS_L))
                           & (self._cur[idx] < self._lens[idx]))

    def peek(self, bits: int) -> np.ndarray:
        """Low ``bits`` of every stream's coder state — the slot values a
        symbol-interval lookup (host `_find_slots` or the fused on-device
        kernel) resolves to symbols. Does not consume anything."""
        return (self._x & _U64((1 << bits) - 1)).astype(np.int64)

    def advance(self, syms, starts, freqs, bits: int, mask=None) -> np.ndarray:
        """Consume one symbol per active stream given its already-resolved
        (symbol, start, freq) interval — the second half of ``get`` for
        callers that run the interval lookup elsewhere (e.g. on device in
        the fused top-k→CDF→lookup kernel). The interval MUST correspond
        to this stream's current ``peek(bits)`` slot."""
        B = self._x.shape[0]
        mask = np.ones(B, bool) if mask is None else np.asarray(mask, bool)
        slots = (self._x & _U64((1 << bits) - 1)).astype(np.int64)
        syms = np.where(mask, np.asarray(syms, np.int64), 0)
        starts = np.where(mask, np.asarray(starts, np.int64), 0)
        freqs = np.where(mask, np.asarray(freqs, np.int64), 1)
        nx = (_as_u64(freqs) * (self._x >> _U64(bits))
              + _as_u64(slots) - _as_u64(starts))
        self._x = np.where(mask, nx, self._x)
        self._renorm(mask)
        self.last_freq = freqs
        return syms

    def get(self, cdfs: np.ndarray, bits: int, mask=None) -> np.ndarray:
        """Decode one symbol per active stream under CDF rows cdfs
        (B, n+1) with total 2**bits. Inactive lanes return 0 untouched."""
        B = self._x.shape[0]
        mask = np.ones(B, bool) if mask is None else np.asarray(mask, bool)
        cdfs = np.asarray(cdfs, np.int64)
        slots = self.peek(bits)
        syms = _find_slots(cdfs, slots)
        syms = np.where(mask, syms, 0)
        starts = np.take_along_axis(cdfs, syms[:, None], axis=1)[:, 0]
        ends = np.take_along_axis(cdfs, syms[:, None] + 1, axis=1)[:, 0]
        return self.advance(syms, starts, ends - starts, bits, mask)

    def get_uniform(self, bits: int, mask=None) -> np.ndarray:
        """Decode one uniform-over-2**bits symbol per active stream."""
        B = self._x.shape[0]
        mask = np.ones(B, bool) if mask is None else np.asarray(mask, bool)
        syms = (self._x & _U64((1 << bits) - 1)).astype(np.int64)
        syms = np.where(mask, syms, 0)
        self._x = np.where(mask, self._x >> _U64(bits), self._x)
        self._renorm(mask)
        return syms


# ------------------------------------------------------- single-stream API
def encode_sequence(symbols, cdfs, bits: int) -> bytes:
    """Reference single-stream encode: symbols[i] under cdfs[i] (each a
    length-(n+1) integer CDF with total 2**bits). For tests/benchmarks;
    the compressor uses the batched classes directly."""
    enc = BatchedRansEncoder(1)
    for s, cdf in zip(symbols, cdfs):
        enc.put_symbols(np.array([int(s)]), np.asarray(cdf)[None, :], bits)
    return enc.finish()[0]


def decode_sequence(data: bytes, cdfs, bits: int) -> list[int]:
    """Reference single-stream decode, one symbol per CDF in order."""
    dec = BatchedRansDecoder([data])
    return [int(dec.get(np.asarray(cdf)[None, :], bits)[0]) for cdf in cdfs]
