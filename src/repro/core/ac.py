"""Integer arithmetic (range) coder with quantized CDFs.

This is the entropy-coding backend of the paper's LLM compressor (§4.3).
It is a classic 32-bit Witten–Neal–Cleary coder operating on *integer*
CDFs so that encoding and decoding are bit-exact across platforms — this
deliberately fixes the floating-point-precision worry the paper raises in
§4.4 (float ACs are not portable; integer ones are).

A CDF for an n-symbol alphabet is an int64 numpy array of length n+1 with
``cdf[0] == 0``, strictly increasing, ``cdf[n] == total`` where
``total <= 2**MAX_TOTAL_BITS``. Every symbol must have nonzero mass
(strict monotonicity) so the coder can always represent it.

The coder runs on the host: arithmetic coding is a sequential integer
recurrence with data-dependent renormalization — there is no MXU/VPU
structure to exploit on TPU, so (like the paper / NNCP) the accelerator's
job ends at producing per-token CDFs (see kernels/ac_cdf.py).
"""
from __future__ import annotations

import numpy as np

CODE_BITS = 32
TOP = (1 << CODE_BITS) - 1          # inclusive upper bound of the range
HALF = 1 << (CODE_BITS - 1)
QUARTER = 1 << (CODE_BITS - 2)
THREE_QUARTER = HALF + QUARTER
MASK = TOP
MAX_TOTAL_BITS = 30                 # total * range must fit in 62 bits


class BitWriter:
    """MSB-first bit sink backed by a bytearray."""

    __slots__ = ("_buf", "_acc", "_nbits")

    def __init__(self) -> None:
        self._buf = bytearray()
        self._acc = 0
        self._nbits = 0

    def write(self, bit: int) -> None:
        self._acc = (self._acc << 1) | bit
        self._nbits += 1
        if self._nbits == 8:
            self._buf.append(self._acc)
            self._acc = 0
            self._nbits = 0

    def getvalue(self) -> bytes:
        if self._nbits:
            return bytes(self._buf) + bytes([self._acc << (8 - self._nbits)])
        return bytes(self._buf)

    def bit_length(self) -> int:
        return 8 * len(self._buf) + self._nbits


class BitReader:
    """MSB-first bit source; reads 0 past the end (standard AC convention)."""

    __slots__ = ("_data", "_pos", "_len")

    def __init__(self, data: bytes) -> None:
        self._data = data
        self._pos = 0
        self._len = 8 * len(data)

    def read(self) -> int:
        if self._pos >= self._len:
            self._pos += 1
            return 0
        byte = self._data[self._pos >> 3]
        bit = (byte >> (7 - (self._pos & 7))) & 1
        self._pos += 1
        return bit


class ArithmeticEncoder:
    """Streaming arithmetic encoder over integer CDFs."""

    def __init__(self) -> None:
        self._low = 0
        self._high = TOP
        self._pending = 0
        self._out = BitWriter()
        self._finished = False

    def _emit(self, bit: int) -> None:
        self._out.write(bit)
        while self._pending:
            self._out.write(bit ^ 1)
            self._pending -= 1

    def encode(self, symbol: int, cdf: np.ndarray) -> None:
        total = int(cdf[-1])
        lo_c = int(cdf[symbol])
        hi_c = int(cdf[symbol + 1])
        if hi_c <= lo_c:
            raise ValueError(f"symbol {symbol} has zero mass in CDF")
        span = self._high - self._low + 1
        self._high = self._low + span * hi_c // total - 1
        self._low = self._low + span * lo_c // total
        # Renormalize.
        while True:
            if self._high < HALF:
                self._emit(0)
            elif self._low >= HALF:
                self._emit(1)
                self._low -= HALF
                self._high -= HALF
            elif self._low >= QUARTER and self._high < THREE_QUARTER:
                self._pending += 1
                self._low -= QUARTER
                self._high -= QUARTER
            else:
                break
            self._low = (self._low << 1) & MASK
            self._high = ((self._high << 1) | 1) & MASK

    def finish(self) -> bytes:
        if not self._finished:
            self._pending += 1
            if self._low < QUARTER:
                self._emit(0)
            else:
                self._emit(1)
            self._finished = True
        return self._out.getvalue()

    def bit_length(self) -> int:
        return self._out.bit_length()


class ArithmeticDecoder:
    """Streaming arithmetic decoder; mirror image of the encoder."""

    def __init__(self, data: bytes) -> None:
        self._in = BitReader(data)
        self._low = 0
        self._high = TOP
        self._value = 0
        for _ in range(CODE_BITS):
            self._value = (self._value << 1) | self._in.read()

    def decode(self, cdf: np.ndarray) -> int:
        total = int(cdf[-1])
        span = self._high - self._low + 1
        target = ((self._value - self._low + 1) * total - 1) // span
        # cdf is sorted; find s with cdf[s] <= target < cdf[s+1].
        symbol = int(np.searchsorted(cdf, target, side="right")) - 1
        lo_c = int(cdf[symbol])
        hi_c = int(cdf[symbol + 1])
        self._high = self._low + span * hi_c // total - 1
        self._low = self._low + span * lo_c // total
        while True:
            if self._high < HALF:
                pass
            elif self._low >= HALF:
                self._low -= HALF
                self._high -= HALF
                self._value -= HALF
            elif self._low >= QUARTER and self._high < THREE_QUARTER:
                self._low -= QUARTER
                self._high -= QUARTER
                self._value -= QUARTER
            else:
                break
            self._low = (self._low << 1) & MASK
            self._high = ((self._high << 1) | 1) & MASK
            self._value = ((self._value << 1) | self._in.read()) & MASK
        return symbol


def encode_sequence(symbols, cdfs) -> bytes:
    """Encode ``symbols[i]`` with ``cdfs[i]`` (list/array of per-step CDFs)."""
    enc = ArithmeticEncoder()
    for s, cdf in zip(symbols, cdfs):
        enc.encode(int(s), cdf)
    return enc.finish()


def decode_sequence(data: bytes, cdfs) -> list[int]:
    """Decode one symbol per CDF in order (CDFs may depend on prior symbols
    only through the caller's loop — see LLMCompressor for the adaptive use)."""
    dec = ArithmeticDecoder(data)
    return [dec.decode(cdf) for cdf in cdfs]


def uniform_cdf(n: int) -> np.ndarray:
    """CDF of the uniform distribution over n symbols (used for escape coding)."""
    return np.arange(n + 1, dtype=np.int64)
