"""Self-draft proposers for speculative decompression (DESIGN.md §9).

A proposer guesses the next K tokens of each lane from nothing but that
lane's already-decoded prefix — no model, no side channel. Guesses only
buy speed, never correctness: the rANS decoder arbitrates every position
against the coded stream, so a wrong draft costs one wasted verify slot
and nothing else (the mismatching position still decodes its true token).

``SuffixDraft`` is the production proposer: longest-suffix match (order
down to 1) against the decoded prefix, continuation copied from the most
recent prior occurrence. On LLM-generated text — the paper's target
distribution — local reuse is heavy (§3's n-gram redundancy analysis),
so suffix continuation is a strong, free draft. ``ConstantDraft`` exists
for adversarial tests (an always-wrong proposer must degrade speculative
decode to lock-step rate, never corrupt it).
"""
from __future__ import annotations

import numpy as np

from repro.obs import metrics as _metrics


class DraftProposer:
    """Protocol: propose K next tokens per lane from decoded prefixes."""

    def propose(self, tokens: np.ndarray, pos: np.ndarray,
                k: int) -> np.ndarray:
        """tokens (B, C) decoded-so-far (valid up to pos[b] per lane),
        pos (B,) next undecoded position -> drafts (B, k) int32."""
        raise NotImplementedError


class SuffixDraft(DraftProposer):
    """N-gram / suffix-match proposer over the decoded prefix, per lane.

    For each lane, match the longest suffix of length <= max_order
    against earlier text; on a hit, propose the continuation that
    followed the most recent occurrence. The copy is LZ-style and may
    OVERLAP the frontier: when the source catches up to the undecoded
    boundary it re-reads the tokens just drafted, so a period-p loop
    (argmax cycles, repeated delimiters, table rows) extrapolates
    exactly instead of stuttering on its last token.
    """

    def __init__(self, max_order: int = 3):
        self.max_order = int(max_order)

    def propose(self, tokens, pos, k):
        tokens = np.asarray(tokens)
        pos = np.asarray(pos)
        B = tokens.shape[0]
        _metrics.registry().counter(
            "draft.proposed_tokens",
            "draft tokens proposed (suffix matcher)").inc(B * k)
        out = np.zeros((B, k), np.int32)
        for b in range(B):
            out[b] = self._lane(tokens[b], int(pos[b]), k)
        return out

    def _lane(self, toks, p, k):
        draft = np.zeros(k, np.int32)
        if p == 0:
            return draft
        for order in range(min(self.max_order, p), 0, -1):
            j = self._last_match(toks, p, order)
            if j < 0:
                continue
            s = j + order               # continuation source; s <= p - 1
            for i in range(k):          # overlapping copy, period p - s
                draft[i] = toks[s + i] if s + i < p else draft[i - (p - s)]
            return draft
        draft[:] = toks[p - 1]          # no match at any order: repeat
        return draft

    @staticmethod
    def _last_match(toks, p, order):
        """Start index of the most recent occurrence of toks[p-order:p]
        ending strictly before p-1, or -1. Shifted-slice conjunction
        (order small) — cheaper than materializing a window view for the
        short per-lane prefixes this runs on every round."""
        n = p - order                   # candidate start indices: [0, n)
        if n < 1:
            return -1
        pat = toks[p - order:p]
        ok = toks[:n] == pat[0]
        for d in range(1, order):
            ok &= toks[d:d + n] == pat[d]
        hits = np.nonzero(ok)[0]
        return int(hits[-1]) if hits.size else -1


class ConstantDraft(DraftProposer):
    """Always proposes one fixed token — the adversarial 'always wrong'
    proposer when that token never occurs in the data (tests), or a
    trivially right one on constant streams."""

    def __init__(self, token: int):
        self.token = int(token)

    def propose(self, tokens, pos, k):
        return np.full((np.asarray(tokens).shape[0], k), self.token,
                       np.int32)


class OracleDraft(DraftProposer):
    """Proposes the true continuation (tests only: exercises the
    every-position-accepted bonus-token path at 100% accept rate).
    The decoder announces each group's first chunk index through the
    optional ``begin_group`` hook."""

    def __init__(self, truth: np.ndarray, chunk_size: int):
        self.truth = np.asarray(truth, np.int32).ravel()
        self.C = int(chunk_size)
        self._base = 0

    def begin_group(self, chunk_offset: int) -> None:
        self._base = int(chunk_offset)

    def propose(self, tokens, pos, k):
        B = np.asarray(tokens).shape[0]
        out = np.zeros((B, k), np.int32)
        for b in range(B):
            lo = (self._base + b) * self.C + int(pos[b])
            cont = self.truth[lo:lo + k]
            out[b, :cont.size] = cont
        return out
