"""Baseline compressors from the paper's evaluation (§5.2).

Dictionary-based: gzip (DEFLATE), LZMA, Zstd-22 — stdlib / zstandard.
Entropy-based: Huffman, static (order-0) arithmetic coding, and an
order-N context-model arithmetic coder (the adaptive flavour FSE/NNCP-lite
occupy). All implemented here so every number in the paper's Table 3/5
analog is produced by this repo.

Beyond ratio reporting, this module is also the **fallback codec
registry** of the adaptive router (DESIGN.md §11): real
``compress_bytes``/``decompress_bytes`` paths for the codecs a v5
container may select per chunk when the LLM path would lose — zstd,
LZMA (raw LZMA2 stream: no xz framing, chunks are small), and raw
store. The registry is keyed by the short names the container's codec-id
table (core.compressor.CODEC_IDS) maps to; zstd availability is checked
at call time so the optional-dependency path (``HAVE_ZSTD = False``)
stays testable by monkeypatching.
"""
from __future__ import annotations

import gzip as _gzip
import heapq
import lzma as _lzma
from collections import Counter, defaultdict

import numpy as np

try:  # optional baseline — the [test] extra pulls it in, core never needs it
    import zstandard as _zstd
    HAVE_ZSTD = True
except ImportError:
    _zstd = None
    HAVE_ZSTD = False

from . import ac
from .cdf import pmf_to_cdf


# ----------------------------------------------------------- dictionary-based
def gzip_ratio(data: bytes) -> float:
    return len(data) / len(_gzip.compress(data, compresslevel=9))


def lzma_ratio(data: bytes) -> float:
    return len(data) / len(_lzma.compress(data, preset=9 | _lzma.PRESET_EXTREME))


def zstd_ratio(data: bytes, level: int = 22) -> float:
    if not HAVE_ZSTD:
        raise RuntimeError(
            "zstd baseline requires the 'zstandard' package "
            "(pip install zstandard)")
    return len(data) / len(_zstd.ZstdCompressor(level=level).compress(data))


# -------------------------------------------------------------- entropy-based
def huffman_compress(data: bytes) -> tuple[bytes, dict]:
    """Canonical Huffman over bytes. Returns (bitstream, code table)."""
    freq = Counter(data)
    if len(freq) == 1:  # degenerate
        sym = next(iter(freq))
        return bytes([sym]), {sym: "0"}
    heap = [(f, i, (s,)) for i, (s, f) in enumerate(sorted(freq.items()))]
    heapq.heapify(heap)
    codes = defaultdict(str)
    i = len(heap)
    while len(heap) > 1:
        fa, _, a = heapq.heappop(heap)
        fb, _, b = heapq.heappop(heap)
        for s in a:
            codes[s] = "0" + codes[s]
        for s in b:
            codes[s] = "1" + codes[s]
        heapq.heappush(heap, (fa + fb, i, a + b))
        i += 1
    w = ac.BitWriter()
    for byte in data:
        for c in codes[byte]:
            w.write(c == "1")
    return w.getvalue(), dict(codes)


def huffman_ratio(data: bytes) -> float:
    payload, codes = huffman_compress(data)
    # Table cost: canonical Huffman needs one code length per present symbol.
    table = len(codes) * 2
    return len(data) / (len(payload) + table)


def order0_ac_ratio(data: bytes, precision: int = 16) -> float:
    """Static arithmetic coding with an order-0 byte model (≈ FSE bound)."""
    hist = np.bincount(np.frombuffer(data, dtype=np.uint8), minlength=256)
    pmf = hist / hist.sum()
    budget = (1 << precision) - 256
    q = np.floor(pmf * budget).astype(np.int64)
    rem = budget - q.sum()
    order = np.argsort(-(pmf * budget - q))
    q[order[:rem]] += 1
    cdf = pmf_to_cdf(q + 1)
    enc = ac.ArithmeticEncoder()
    for b in data:
        enc.encode(b, cdf)
    payload = enc.finish()
    return len(data) / (len(payload) + 256 * 2)  # + model table


def orderN_ac_ratio(data: bytes, order: int = 2, precision: int = 14) -> float:
    """Adaptive order-N context-mixing arithmetic coder (small-context PPM
    flavour) — a fair stand-in for the adaptive neural baselines (NNCP/
    TRACE/PAC occupy this niche with learned contexts). Adaptive => no
    table cost; both sides update identical counts."""
    T = 1 << precision
    counts: dict[bytes, np.ndarray] = {}
    enc = ac.ArithmeticEncoder()
    ctx = b"\x00" * order
    for byte in data:
        c = counts.get(ctx)
        if c is None:
            c = np.ones(256, dtype=np.int64)
            counts[ctx] = c
        tot = int(c.sum())
        if tot >= T - 256:  # rescale to keep totals within coder precision
            c = np.maximum(c // 2, 1)
            counts[ctx] = c
        cdf = pmf_to_cdf(c)
        enc.encode(byte, cdf)
        c[byte] += 32
        ctx = (ctx + bytes([byte]))[-order:]
    return len(data) / max(1, len(enc.finish()))


# ------------------------------------------------- fallback byte codecs
# Chunk-scale streams: LZMA uses a raw LZMA2 filter chain (the xz/alone
# containers cost ~20-60 framing bytes, which swamps a 256-byte chunk);
# both sides agree on the filter spec below, so no header is needed.
_LZMA_FILTERS = [{"id": _lzma.FILTER_LZMA2, "preset": 9}]
_ZSTD_LEVEL = 19


def _zstd_compress(data: bytes) -> bytes:
    if not HAVE_ZSTD:
        raise RuntimeError(
            "zstd codec requires the 'zstandard' package "
            "(pip install zstandard)")
    return _zstd.ZstdCompressor(level=_ZSTD_LEVEL).compress(data)


def _zstd_decompress(blob: bytes) -> bytes:
    if not HAVE_ZSTD:
        raise RuntimeError(
            "zstd codec requires the 'zstandard' package "
            "(pip install zstandard)")
    return _zstd.ZstdDecompressor().decompress(blob)


def _lzma_compress(data: bytes) -> bytes:
    return _lzma.compress(data, format=_lzma.FORMAT_RAW,
                          filters=_LZMA_FILTERS)


def _lzma_decompress(blob: bytes) -> bytes:
    return _lzma.decompress(blob, format=_lzma.FORMAT_RAW,
                            filters=_LZMA_FILTERS)


#: name -> (compress_fn, decompress_fn). These are the router's fallback
#: backends; the names are wire-stable (they map to container codec ids).
BYTE_CODECS = {
    "zstd": (_zstd_compress, _zstd_decompress),
    "lzma": (_lzma_compress, _lzma_decompress),
    "raw": (lambda data: bytes(data), lambda blob: bytes(blob)),
}


def available_byte_codecs() -> list[str]:
    """Fallback codec names usable right now, best-ratio-first. Checked
    at call time, not import time, so a monkeypatched ``HAVE_ZSTD``
    (the optional-dep test path) is respected."""
    return [n for n in BYTE_CODECS if n != "zstd" or HAVE_ZSTD]


def compress_bytes(name: str, data: bytes) -> bytes:
    """Compress ``data`` with the named fallback codec. Raises KeyError
    on an unknown name and RuntimeError when zstd is requested without
    the optional ``zstandard`` package."""
    return BYTE_CODECS[name][0](data)


def decompress_bytes(name: str, blob: bytes) -> bytes:
    """Exact inverse of ``compress_bytes(name, ...)``."""
    return BYTE_CODECS[name][1](blob)


ALL_BASELINES = {
    "huffman": huffman_ratio,
    "arith_order0": order0_ac_ratio,
    "arith_order2": orderN_ac_ratio,
    "gzip": gzip_ratio,
    "lzma": lzma_ratio,
    "zstd22": zstd_ratio,
}


def available_baselines() -> list[str]:
    return [n for n in ALL_BASELINES if n != "zstd22" or HAVE_ZSTD]


def run_baselines(data: bytes, names=None) -> dict[str, float]:
    """Ratios for the requested baselines. With no explicit ``names``,
    unavailable optional backends (zstd) are silently skipped; naming one
    explicitly raises so a typo can't masquerade as a result."""
    names = names or available_baselines()
    return {n: round(ALL_BASELINES[n](data), 3) for n in names}
