"""XXH64 content checksums for the v4 container (DESIGN.md §8).

The v4 footer carries one 64-bit checksum per chunk stream plus one over
header+index, so corruption is *detected* before the entropy decoder runs
on garbage (a flipped bit in an rANS stream otherwise decodes "cleanly"
into wrong tokens — the coder has no redundancy of its own).

This is the reference XXH64 algorithm (Collet) in pure Python integers:
no C extension dependency, bit-compatible with the `xxhash` package
(``xxhash.xxh64_intdigest``), fast enough for the per-chunk stream sizes
the container holds (streams are a few KB; the 32-byte stripe loop costs
~a dozen int ops per stripe).
"""
from __future__ import annotations

_P1 = 0x9E3779B185EBCA87
_P2 = 0xC2B2AE3D27D4EB4F
_P3 = 0x165667B19E3779F9
_P4 = 0x85EBCA77C2B2AE63
_P5 = 0x27D4EB2F165667C5
_MASK = (1 << 64) - 1


def _rotl(x: int, r: int) -> int:
    return ((x << r) | (x >> (64 - r))) & _MASK


def _round(acc: int, lane: int) -> int:
    acc = (acc + lane * _P2) & _MASK
    return (_rotl(acc, 31) * _P1) & _MASK


def _merge(h: int, acc: int) -> int:
    h ^= _round(0, acc)
    return (h * _P1 + _P4) & _MASK


def xxh64(data: bytes, seed: int = 0) -> int:
    """64-bit XXH64 digest of ``data`` as an unsigned int."""
    n = len(data)
    end = n - n % 32
    if n >= 32:
        v1 = (seed + _P1 + _P2) & _MASK
        v2 = (seed + _P2) & _MASK
        v3 = seed & _MASK
        v4 = (seed - _P1) & _MASK
        for i in range(0, end, 32):
            v1 = _round(v1, int.from_bytes(data[i:i + 8], "little"))
            v2 = _round(v2, int.from_bytes(data[i + 8:i + 16], "little"))
            v3 = _round(v3, int.from_bytes(data[i + 16:i + 24], "little"))
            v4 = _round(v4, int.from_bytes(data[i + 24:i + 32], "little"))
        h = (_rotl(v1, 1) + _rotl(v2, 7) + _rotl(v3, 12)
             + _rotl(v4, 18)) & _MASK
        h = _merge(h, v1)
        h = _merge(h, v2)
        h = _merge(h, v3)
        h = _merge(h, v4)
    else:
        h = (seed + _P5) & _MASK
    h = (h + n) & _MASK
    i = end
    while i + 8 <= n:
        h ^= _round(0, int.from_bytes(data[i:i + 8], "little"))
        h = (_rotl(h, 27) * _P1 + _P4) & _MASK
        i += 8
    if i + 4 <= n:
        h ^= (int.from_bytes(data[i:i + 4], "little") * _P1) & _MASK
        h = (_rotl(h, 23) * _P2 + _P3) & _MASK
        i += 4
    while i < n:
        h ^= (data[i] * _P5) & _MASK
        h = (_rotl(h, 11) * _P1) & _MASK
        i += 1
    h ^= h >> 33
    h = (h * _P2) & _MASK
    h ^= h >> 29
    h = (h * _P3) & _MASK
    h ^= h >> 32
    return h
