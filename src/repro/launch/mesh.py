"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see the
single real device).

Axes:
  pod   — DCN-connected pods; data-parallel only (gradient all-reduce).
  data  — ICI within a pod; batch + FSDP axis.
  model — ICI; tensor / expert parallel axis.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes=None):
    """Arbitrary mesh for tests / elastic configurations. `shape` may use -1
    for one axis to absorb the remaining devices."""
    shape = tuple(shape)
    n = len(jax.devices())
    if -1 in shape:
        known = int(np.prod([s for s in shape if s != -1]))
        shape = tuple(n // known if s == -1 else s for s in shape)
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) <= 3 \
            else tuple(f"ax{i}" for i in range(len(shape)))
    return jax.make_mesh(shape, tuple(axes))


def local_mesh():
    """Single-device mesh (smoke tests, measured CPU runs)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_degree(mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1
