"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api as model_api

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        return {"tokens": SDS((B, S - n_img), jnp.int32),
                "img_embeds": SDS((B, n_img, cfg.d_model), dt)}
    if cfg.family == "encdec":
        return {"tokens": SDS((B, S), jnp.int32),
                "frames": SDS((B, cfg.max_source_len, cfg.d_model), dt)}
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache_specs, prev_tokens_spec) for one serve_step with a KV cache of
    seq_len tokens (SWA models physically hold only the window)."""
    B, S = shape.global_batch, shape.seq_len
    kw = {}
    if cfg.family == "encdec":
        kw["source_len"] = cfg.max_source_len
    cache = jax.eval_shape(
        lambda: model_api.init_cache(cfg, B, S, **kw))
    prev = SDS((B,), jnp.int32)
    return cache, prev


def abstract_opt_state(params_abs, grad_compress: bool = False):
    f32 = lambda p: SDS(p.shape, jnp.float32)
    st = {"m": jax.tree_util.tree_map(f32, params_abs),
          "v": jax.tree_util.tree_map(f32, params_abs),
          "step": SDS((), jnp.int32)}
    if grad_compress:
        st["err"] = jax.tree_util.tree_map(f32, params_abs)
    return st
