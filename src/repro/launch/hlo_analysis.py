"""Collective-traffic + roofline-term extraction from compiled dry-run
artifacts.

collective_bytes is not in cost_analysis(): we parse the optimized HLO
text and sum the OUTPUT shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (per-participant
bytes, the quantity the ICI/DCN link actually carries).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, *, wire_correction: bool = False) -> dict:
    """Per-collective-kind output bytes (per participant) + op counts.
    `-start` ops are counted once (`-done` carries no shape of its own
    in the tuple form, so only count starts and plain ops).

    wire_correction: the CPU dry-run backend PROMOTES bf16 all-reduces to
    f32 (bf16 reductions unsupported on host) — 2x the bytes a TPU
    lowering moves. Our explicit shard_map psums keep their jax op name
    ('%psum*'); with correction on, f32 all-reduces named psum are counted
    at half (their true bf16 payload). Recorded per cell as
    'wire_corrected_bytes'."""
    by_kind: dict = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    promoted = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if f"{kind}-done(" in line:
            continue
        b = _shape_bytes(shape_str)
        if wire_correction and kind == "all-reduce" and "f32[" in shape_str \
                and re.search(r"%psum(\.\d+)?\s*=", line):
            promoted += b // 2
            b -= b // 2
        by_kind[kind]["bytes"] += b
        by_kind[kind]["count"] += 1
    total = sum(v["bytes"] for v in by_kind.values())
    return {"total_bytes": total, "by_kind": by_kind,
            "bf16_promotion_correction_bytes": promoted}


# ------------------------------------------------------------ roofline terms
V5E_PEAK_FLOPS = 197e12      # bf16 per chip
V5E_HBM_BW = 819e9           # bytes/s per chip
V5E_ICI_BW = 50e9            # bytes/s per link (~per-chip sustained)


def analytic_memory_bytes(cfg, shape, *, n_chips: int, tp: int,
                          num_microbatches: int = 1) -> float:
    """Per-device HBM traffic model assuming flash-style attention (scores
    stay in VMEM) and fused elementwise chains. Used for the roofline
    memory term because the loop-free probes materialize S^2 scores (an
    upper bound) — methodology in EXPERIMENTS.md §Roofline.

    Components (bytes, per device, per step):
      weights     — per-chip weight slice read once per pass
                    (fwd / bwd-dgrad / bwd-wgrad => 3x for train, 1x serve)
      optimizer   — adam m/v/p read+write (train only)
      grad accum  — fp32 buffer r/w per microbatch (train only)
      activations — residual-stream traffic: C_ACT touches of (tok x D)
      logits      — vocab-sharded logits chain, C_LOGIT touches
      kv cache    — decode: read full cache slice; train/prefill: write once
    """
    import numpy as np
    dtype_b = 2 if cfg.dtype == "bfloat16" else 4
    P = 0
    from repro.models.schema import count_params
    P = count_params(cfg)
    dp = max(1, n_chips // tp)
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    D = cfg.d_model
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.family == "encdec" else 0)
    tok_loc = B * (S if kind != "decode" else 1) / dp

    C_ACT_F, C_ACT_B = 12, 30      # touches per token per layer (fwd / bwd+remat)
    C_LOGIT_F, C_LOGIT_B = 6, 10   # fp32 logits chain touches

    w_slice = P * dtype_b / tp     # per-chip weight bytes touched per pass
    Vp_loc = cfg.padded_vocab / tp
    logit_loc = tok_loc * Vp_loc * 4

    if kind == "train":
        weights = 3 * w_slice * num_microbatches
        optim = (P / n_chips) * (dtype_b * 2 + 16 + 4)   # p rw + m,v rw(fp32)
        gacc = 2 * (P / n_chips) * 4 * num_microbatches
        acts = tok_loc * D * dtype_b * L * (C_ACT_F + C_ACT_B)
        logits = logit_loc * (C_LOGIT_F + C_LOGIT_B)
        return weights + optim + gacc + acts + logits
    if kind == "prefill":
        weights = w_slice
        acts = tok_loc * D * dtype_b * L * C_ACT_F
        logits = logit_loc * C_LOGIT_F
        return weights + acts + logits
    # decode: weight slice + full KV-cache slice read + tiny activations
    weights = w_slice
    kv_heads = getattr(cfg, "padded_kv_heads", 0)
    if cfg.family in ("ssm", "hybrid"):
        di, N = cfg.ssm_d_inner, cfg.ssm_state
        state = cfg.n_layers * (B / dp) * cfg.ssm_heads * cfg.ssm_headdim * N * 4
        cache = 2 * state  # read + write
        if cfg.family == "hybrid":
            n_app = cfg.n_layers // cfg.hybrid_ssm_per_block
            eff_S = min(S, cfg.sliding_window or S)
            cache += n_app * B * eff_S * kv_heads * cfg.head_dim * 2 * \
                dtype_b / n_chips
    else:
        eff_S = min(S, cfg.sliding_window or S)
        # cache_pspecs shards over BOTH axes: batch (or seq) -> data,
        # kv-heads (or seq) -> model  =>  divisor = n_chips
        kv_b = 1 if getattr(cfg, "kv_cache_dtype", None) == "int8" else dtype_b
        cache = L * B * eff_S * kv_heads * cfg.head_dim * 2 * kv_b / n_chips
        if kv_b == 1:  # int8 scales (fp16 per position/head)
            cache += L * B * eff_S * kv_heads * 2 * 2 * 2 / n_chips
    acts = tok_loc * D * dtype_b * L * C_ACT_F
    logits = logit_loc * C_LOGIT_F
    return weights + cache + acts + logits


@dataclass
class Roofline:
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float   # per participant (already per-chip)
    n_chips: int
    model_flops: float = 0.0  # 6·N·D analytic
    memory_bytes_analytic: float = 0.0  # per device, flash-corrected model

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.n_chips * V5E_PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        """Memory term. Uses the flash-corrected analytic traffic model when
        available (the probe's HLO bytes materialize S^2 attention scores —
        an upper bound reported separately as t_memory_probe)."""
        if self.memory_bytes_analytic:
            return self.memory_bytes_analytic / V5E_HBM_BW
        return self.hlo_bytes / (self.n_chips * V5E_HBM_BW)

    @property
    def t_memory_probe(self) -> float:
        return self.hlo_bytes / (self.n_chips * V5E_HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / V5E_ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def t_star(self) -> float:
        """The binding roofline bound (max of the three terms): the
        fastest a step with this op mix can possibly run."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline that USEFUL work represents:
        (model_flops / peak) / max(all three terms)."""
        t_star = self.t_star
        if t_star == 0:
            return 0.0
        t_ideal = self.model_flops / (self.n_chips * V5E_PEAK_FLOPS)
        return t_ideal / t_star

    def attainment(self, measured_s: float) -> float:
        """Measured-vs-roofline: fraction of the hardware bound a
        *measured* step time achieves (``t_star / measured``, in (0, 1]
        for an honest measurement; >1 means the model or the measurement
        is wrong — surface it, don't clamp). 0.0 when either side is
        missing. This is the quantitative "as fast as the hardware
        allows" signal (ROADMAP): 1.0 = step time equals the binding
        compute/memory/collective bound."""
        if measured_s is None or measured_s <= 0 or self.t_star <= 0:
            return 0.0
        return self.t_star / float(measured_s)

    def to_dict(self) -> dict:
        return {
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips, "model_flops": self.model_flops,
            "memory_bytes_analytic": self.memory_bytes_analytic,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_probe_s": self.t_memory_probe,
            "t_collective_s": self.t_collective,
            "t_star_s": self.t_star,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, hlo_text: str, n_chips: int,
                           model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_stats(hlo_text)["total_bytes"]
    return Roofline(hlo_flops=flops, hlo_bytes=byts,
                    collective_bytes=float(coll), n_chips=n_chips,
                    model_flops=model_flops)
