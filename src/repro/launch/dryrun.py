import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and record memory / cost / collective
analysis for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-train4k]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single   # 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi    # 2x16x16

Each cell writes an entry into results/dryrun/<arch>__<shape>__<mesh>.json
(incremental — safe to re-run; existing entries are skipped unless --force).
"""
import argparse
import json
import pathlib
import time

import jax

from repro import obs

WIRE_CORRECTION = os.environ.get("REPRO_EXPLICIT_TP", "0") == "1"

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import hlo_analysis
from repro.launch.input_specs import (abstract_opt_state, decode_input_specs,
                                      train_input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.schema import abstract_params

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# long_500k runs only for sub-quadratic archs (SSM / hybrid / SWA);
# see DESIGN.md §5.
SUBQUADRATIC = {"mamba2_130m", "zamba2_7b", "h2o_danube_3_4b"}

# grad-accum microbatch count for train_4k, per arch (memory-driven)
MICROBATCHES = {
    "qwen3_moe_235b_a22b": 16, "llava_next_34b": 16, "qwen3_14b": 16,
    "deepseek_7b": 16, "zamba2_7b": 8, "h2o_danube_3_4b": 8,
    "qwen3_1_7b": 16, "granite_moe_1b_a400m": 8, "whisper_large_v3": 8,
    "mamba2_130m": 4,
}


def cell_applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, "long_500k needs sub-quadratic attention (DESIGN.md §5)"
    return True, ""


def lower_cell(arch_id: str, shape_name: str, mesh, *, attn_impl="masked",
               sharded_topk=True, loss_block=0, extra: dict | None = None):
    """Lower + compile one cell; returns result dict."""
    cfg = get_config(arch_id)
    if extra:
        cfg = cfg.with_(**{k: v for k, v in extra.items()
                           if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    params_abs = abstract_params(cfg)
    n_chips = 1
    for s in mesh.devices.shape:
        n_chips *= s
    t0 = time.time()

    if shape.kind == "train":
        from repro.train.train_loop import make_train_step
        from repro.train.optimizer import AdamWConfig
        nmb = MICROBATCHES.get(arch_id, 4)
        step = make_train_step(cfg, mesh, opt=AdamWConfig(),
                               num_microbatches=nmb, attn_impl=attn_impl,
                               global_batch=shape.global_batch, donate=True,
                               loss_block=loss_block)
        batch = train_input_specs(cfg, shape)
        opt_abs = abstract_opt_state(params_abs)
        lowered = step.lower(params_abs, opt_abs, batch)
        # tokens processed per step (model flops basis)
        n_tokens = shape.global_batch * shape.seq_len
        flops_per_token = 6 * cfg.n_active_params()
    elif shape.kind == "prefill":
        from repro.serve.steps import make_score_step
        step = make_score_step(cfg, mesh, topk=64, attn_impl=attn_impl,
                               global_batch=shape.global_batch,
                               sharded_topk=sharded_topk)
        batch = train_input_specs(cfg, shape)
        lowered = step.lower(params_abs, batch)
        n_tokens = shape.global_batch * shape.seq_len
        flops_per_token = 2 * cfg.n_active_params()
    else:  # decode
        from repro.serve.steps import make_serve_step
        step = make_serve_step(cfg, mesh, batch=shape.global_batch, topk=64,
                               donate=True, sharded_topk=sharded_topk)
        cache_abs, prev = decode_input_specs(cfg, shape)
        lowered = step.lower(params_abs, cache_abs, prev)
        n_tokens = shape.global_batch  # one token per stream
        flops_per_token = 2 * cfg.n_active_params()

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    model_flops = float(flops_per_token) * n_tokens
    roof = hlo_analysis.roofline_from_compiled(
        compiled, hlo, n_chips, model_flops)
    coll = hlo_analysis.collective_stats(hlo,
                                         wire_correction=WIRE_CORRECTION)

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "kind": shape.kind, "n_chips": n_chips,
        "attn_impl": attn_impl, "sharded_topk": sharded_topk,
        "loss_block": loss_block,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0) +
                                getattr(mem, "argument_size_in_bytes", 0) +
                                getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": roof.to_dict(),
        "collectives": coll,
    }
    if extra:
        result["extra"] = extra
    return result


# --------------------------------------------------------------- cost probes
# XLA's HloCostAnalysis counts while-loop bodies ONCE (scan-over-layers,
# microbatch scan, chunked attention all hide their trip counts), so the
# scanned production program under-reports FLOPs/bytes/collective-bytes.
# The probes lower LOOP-FREE programs (scan_layers=False, dense attention,
# one microbatch, single logits block) at 1-2 layers and reduced batch and
# extrapolate linearly — every hidden quantity is linear in (layers,
# microbatches). Caveat recorded in EXPERIMENTS.md: the probes' dense
# attention materializes S^2 scores, so the *memory* term is an upper bound
# for flash-style attention; an analytic score-bytes correction is included.


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw = dict(n_layers=n_layers, scan_layers=False)
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_layers
    return cfg.with_(**kw)


def _probe_metrics(arch_id, cfg, shape, mesh, *, n_layers, global_batch,
                   attn_impl="dense", sharded_topk=False, loss_block=0):
    """Compile one loop-free probe; return metric dict."""
    pc = _probe_cfg(cfg, n_layers)
    pshape = ShapeConfig(shape.name, shape.seq_len, global_batch, shape.kind)
    params_abs = abstract_params(pc)
    if shape.kind == "train":
        from repro.train.train_loop import make_train_step
        from repro.train.optimizer import AdamWConfig
        step = make_train_step(pc, mesh, opt=AdamWConfig(),
                               num_microbatches=1, attn_impl=attn_impl,
                               global_batch=global_batch, donate=False,
                               loss_block=0)
        lowered = step.lower(params_abs,
                             abstract_opt_state(params_abs),
                             train_input_specs(pc, pshape))
    elif shape.kind == "prefill":
        from repro.serve.steps import make_score_step
        step = make_score_step(pc, mesh, topk=64, attn_impl=attn_impl,
                               s_block=shape.seq_len,
                               global_batch=global_batch,
                               sharded_topk=sharded_topk)
        lowered = step.lower(params_abs, train_input_specs(pc, pshape))
    else:
        from repro.serve.steps import make_serve_step
        step = make_serve_step(pc, mesh, batch=global_batch, topk=64,
                               donate=False, sharded_topk=sharded_topk)
        cache_abs, prev = decode_input_specs(pc, pshape)
        lowered = step.lower(params_abs, cache_abs, prev)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = hlo_analysis.collective_stats(compiled.as_text(),
                                         wire_correction=WIRE_CORRECTION)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"])}


def _probe_layer_counts(cfg: ModelConfig):
    return 1, 2, cfg.n_layers


def _hybrid_probe_cfgs(cfg):
    """(n_layers, per_block) probe pairs separating SSM-layer and shared-
    attn slopes: slope(2,2)=2s+a+..., slope(8,4)=4s+a."""
    return [(2, 2), (4, 2), (8, 4)]


def _attn_flops_dense(cfg: ModelConfig, shape) -> tuple:
    """Analytic dense-attention FLOPs over all passes, and the block-causal
    compute fraction ((nq+1)/(2 nq) of dense). Used to correct probe FLOPs
    when attn_impl='block_causal' (the triangular scan cannot be probed
    loop-free)."""
    if cfg.family == "ssm" or not cfg.padded_heads or shape.kind == "decode":
        return 0.0, 1.0
    S = shape.seq_len
    tokens = shape.global_batch * S
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.hybrid_ssm_per_block
    per_tok = 4.0 * S * cfg.padded_heads * cfg.head_dim
    passes = 4.0 if shape.kind == "train" else 1.0  # fwd + bwd(2x) + remat
    nq = max(1, S // 512)
    frac = (nq + 1) / (2.0 * nq)
    return per_tok * tokens * n_attn * passes, frac


def probe_roofline(arch_id: str, shape_name: str, mesh,
                   sharded_topk=True, attn_impl="masked",
                   cfg_extra=None) -> dict:
    """Loop-corrected cost metrics for one cell (single-pod mesh).

    Simplified extrapolation: 2 probes in layer count at one microbatch
    size; the whole program scales x num_microbatches. The optimizer
    update is wrongly scaled by that (it runs once per step), a <=2%
    FLOP error on these models — recorded in EXPERIMENTS.md §Roofline.
    """
    cfg = get_config(arch_id)
    if cfg_extra:
        cfg = cfg.with_(**{k: v for k, v in cfg_extra.items()
                           if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    L1, L2, L_eff = _probe_layer_counts(cfg)
    metrics = {}
    if shape.kind == "train":
        nmb = MICROBATCHES.get(arch_id, 4)
        b = max(shape.global_batch // nmb, 16)
        nmb_eff = shape.global_batch / b
        if cfg.family == "hybrid":
            per = cfg.hybrid_ssm_per_block
            probes = []
            for (L, pb) in _hybrid_probe_cfgs(cfg):
                pc = cfg.with_(hybrid_ssm_per_block=pb)
                probes.append(_probe_metrics(
                    arch_id, pc, shape, mesh, n_layers=L, global_batch=b))
            A, B, C = probes   # groups: 1x(2s+a), 2x(2s+a), 2x(4s+a)
            n_groups = cfg.n_layers // per
            n_rest = cfg.n_layers - n_groups * per
            for k in ("flops", "bytes", "coll"):
                g2 = B[k] - A[k]            # 2s + a
                g4 = (C[k] - (A[k] - g2))    # 2*(4s+a) => per-group:
                g4 = (C[k] - (A[k] - g2)) / 2.0
                s_lay = (g4 - g2) / 2.0
                a_att = g2 - 2 * s_lay
                fix = A[k] - g2
                total_1mb = fix + cfg.n_layers * s_lay + n_groups * a_att
                metrics[k] = max(0.0, total_1mb * nmb_eff)
            return metrics
        C1 = _probe_metrics(arch_id, cfg, shape, mesh, n_layers=L1,
                            global_batch=b)
        C2 = _probe_metrics(arch_id, cfg, shape, mesh, n_layers=L2,
                            global_batch=b)
        for k in ("flops", "bytes", "coll"):
            slope = (C2[k] - C1[k]) / (L2 - L1)
            metrics[k] = max(0.0, (C1[k] + slope * (L_eff - L1)) * nmb_eff)
    else:
        if cfg.family == "hybrid":
            per = cfg.hybrid_ssm_per_block
            probes = []
            for (L, pb) in _hybrid_probe_cfgs(cfg):
                pc = cfg.with_(hybrid_ssm_per_block=pb)
                probes.append(_probe_metrics(
                    arch_id, pc, shape, mesh, n_layers=L,
                    global_batch=shape.global_batch))
            A, B, C = probes
            n_groups = cfg.n_layers // per
            for k in ("flops", "bytes", "coll"):
                g2 = B[k] - A[k]
                g4 = (C[k] - (A[k] - g2)) / 2.0
                s_lay = (g4 - g2) / 2.0
                a_att = g2 - 2 * s_lay
                fix = A[k] - g2
                metrics[k] = max(0.0, fix + cfg.n_layers * s_lay +
                                 n_groups * a_att)
            return metrics
        C1 = _probe_metrics(arch_id, cfg, shape, mesh, n_layers=L1,
                            global_batch=shape.global_batch,
                            sharded_topk=sharded_topk)
        C2 = _probe_metrics(arch_id, cfg, shape, mesh, n_layers=L2,
                            global_batch=shape.global_batch,
                            sharded_topk=sharded_topk)
        for k in ("flops", "bytes", "coll"):
            slope = (C2[k] - C1[k]) / (L2 - L1)
            metrics[k] = max(0.0, C1[k] + slope * (L_eff - L1))
    # block-causal: probes ran dense attention; subtract the analytic
    # triangular saving from the extrapolated FLOPs (exact block count)
    if attn_impl == "block_causal" and "flops" in metrics:
        dense_flops, frac = _attn_flops_dense(cfg, shape)
        n_chips = mesh.devices.size
        metrics["flops"] = max(
            0.0, metrics["flops"] - dense_flops * (1 - frac) / n_chips)
        metrics["block_causal_correction"] = dense_flops * (1 - frac)
    # analytic dense-attention score-bytes (memory-term upper-bound caveat)
    if cfg.family not in ("ssm",) and cfg.padded_heads:
        S = shape.seq_len if shape.kind != "decode" else 1
        Sk = shape.seq_len
        per_dev_tokens = shape.global_batch * S / max(1, mesh.devices.size //
                                                      mesh.shape["model"])
        scores = per_dev_tokens * cfg.padded_heads * Sk * 4 * 3
        n_attn = cfg.n_layers if cfg.family != "hybrid" else \
            cfg.n_layers // cfg.hybrid_ssm_per_block
        metrics["attn_scores_bytes_analytic"] = scores * n_attn * \
            (3 if shape.kind == "train" else 1)
    return metrics


def run_cells(cells, mesh_kind: str, *, force=False, attn_impl="masked",
              tag="", probe=None, sharded_topk=True, loss_block=0,
              kv_int8=False):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if probe is None:
        probe = mesh_kind == "single"  # roofline table is single-pod only
    RESULTS.mkdir(parents=True, exist_ok=True)
    ok = fail = skip = 0
    for arch_id, shape_name in cells:
        name = f"{arch_id}__{shape_name}__{mesh_kind}" + \
            (f"__{tag}" if tag else "")
        out = RESULTS / f"{name}.json"
        applicable, why = cell_applicable(arch_id, shape_name)
        if not applicable:
            out.write_text(json.dumps(
                {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                 "skipped": why}, indent=1))
            obs.log("dryrun.skip", cell=name, why=why)
            skip += 1
            continue
        if out.exists() and not force:
            obs.log("dryrun.cached", cell=name)
            ok += 1
            continue
        obs.log("dryrun.lower", cell=name)
        try:
            extra = {"kv_cache_dtype": "int8"} if kv_int8 else None
            res = lower_cell(arch_id, shape_name, mesh, attn_impl=attn_impl,
                             sharded_topk=sharded_topk, loss_block=loss_block,
                             extra=extra)
            if probe:
                pm = probe_roofline(arch_id, shape_name, mesh,
                                    sharded_topk=sharded_topk,
                                    attn_impl=attn_impl,
                                    cfg_extra=extra)
                n_chips = res["n_chips"]
                cfg_r = get_config(arch_id)
                if extra:
                    cfg_r = cfg_r.with_(**{k: v for k, v in extra.items()
                                           if hasattr(cfg_r, k)})
                mem_analytic = hlo_analysis.analytic_memory_bytes(
                    cfg_r, SHAPES[shape_name], n_chips=n_chips,
                    tp=mesh.shape["model"],
                    num_microbatches=MICROBATCHES.get(arch_id, 4))
                roof = hlo_analysis.Roofline(
                    hlo_flops=pm["flops"] * n_chips,
                    hlo_bytes=pm["bytes"] * n_chips,
                    collective_bytes=pm["coll"],
                    n_chips=n_chips,
                    model_flops=res["roofline"]["model_flops"],
                    memory_bytes_analytic=mem_analytic)
                res["roofline_raw_scanned"] = res["roofline"]
                rd = roof.to_dict()
                rd["note"] = ("loop-corrected via unrolled probes; "
                              "memory term is a dense-attn upper bound")
                if "attn_scores_bytes_analytic" in pm:
                    rd["attn_scores_bytes_analytic"] = \
                        pm["attn_scores_bytes_analytic"]
                res["roofline"] = rd
            out.write_text(json.dumps(res, indent=1))
            r = res["roofline"]
            obs.log("dryrun.ok", cell=name, compile_s=res["compile_s"],
                    mem_gib=round(
                        res["memory"]["bytes_per_device"] / 2**30, 2),
                    bottleneck=r["bottleneck"],
                    roofline_frac=round(r["roofline_fraction"], 3))
            ok += 1
        except Exception as e:  # noqa: BLE001 — record, continue
            # structured error sidecar + counted failure (obs.log_exception
            # increments errors.total / errors.dryrun.cell_failed, so a
            # sweep's failures are countable in the registry snapshot, not
            # only greppable from .err files)
            out.with_suffix(".err").write_text(json.dumps(
                {"cell": name, "error": obs.exception_record(e)}, indent=1))
            obs.log_exception("dryrun.cell_failed", e, cell=name)
            obs.registry().counter(
                "dryrun.cell_failures", "dry-run cells that failed to "
                "lower/compile").inc()
            fail += 1
    obs.log("dryrun.done", ok=ok, fail=fail, skip=skip)
    return fail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attn-impl", default="masked")
    ap.add_argument("--sharded-topk", action="store_true", default=True)
    ap.add_argument("--no-sharded-topk", dest="sharded_topk",
                    action="store_false")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--loss-block", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    cells = [(a, s) for a in archs for s in shapes]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    rc = 0
    for mk in meshes:
        rc += run_cells(cells, mk, force=args.force,
                        attn_impl=args.attn_impl, tag=args.tag,
                        sharded_topk=args.sharded_topk,
                        loss_block=args.loss_block,
                        kv_int8=args.kv_int8)
    raise SystemExit(1 if rc else 0)


if __name__ == "__main__":
    main()
