"""Production training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --steps 200 \
      --smoke --ckpt-dir /tmp/ckpt [--resume]

Behaviour:
  * auto-resume from the newest VALID checkpoint (corrupt ones skipped);
  * checkpoint every --ckpt-every steps, atomic, k-retention;
  * the data-pipeline cursor and RNG state live inside the checkpoint, so
    a restart reproduces the exact batch sequence (bitwise resume — see
    tests/test_fault_tolerance.py);
  * --watchdog respawns the training child process on crash (simulated
    node failure), resuming from the latest checkpoint;
  * elastic: --mesh d,m restores any checkpoint onto a new mesh shape.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro import obs


def train_main(args) -> int:
    import jax
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.pipeline import PipelineState, TokenPipeline
    from repro.data.synthetic import human_like
    from repro.data.tokenizer import encode
    from repro.launch.mesh import local_mesh, make_mesh
    from repro.models.schema import init_params
    from repro.train.checkpoint import restore_latest, save_checkpoint
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_loop import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh((d, m), ("data", "model"))
    else:
        mesh = local_mesh()
    opt = AdamWConfig(learning_rate=args.lr, warmup_steps=20,
                      total_steps=args.steps,
                      grad_compress=args.grad_compress)

    corpus = encode(human_like("wiki", args.corpus_bytes, seed=1))
    pipe = TokenPipeline(corpus, global_batch=args.batch,
                         seq_len=args.seq_len)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, opt)
    state_like = {"params": params, "opt": opt_state,
                  "pipe": {"step": np.zeros((), np.int64)}}
    start = 0
    if args.ckpt_dir:
        restored, step = restore_latest(args.ckpt_dir, state_like)
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt"]
            start = int(restored["pipe"]["step"])
            pipe.state.step = start
            obs.log("train.resume", restored=step, continuing=start)

    step_fn = make_train_step(cfg, mesh, opt=opt,
                              num_microbatches=args.microbatches,
                              global_batch=args.batch,
                              loss_block=args.loss_block)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {"tokens": pipe.global_batch_array(step)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        pipe.advance()
        if step % args.log_every == 0:
            obs.log("train.step", step=step,
                    loss=round(float(metrics["loss"]), 4),
                    gnorm=round(float(metrics["grad_norm"]), 3),
                    elapsed_s=round(time.time() - t0, 1))
        if args.crash_at is not None and step == args.crash_at:
            # StreamHandler flushes per record, so this line survives the
            # hard exit below (os._exit skips interpreter buffers)
            obs.log("train.fault_injection", step=step)
            os._exit(42)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {
                "params": params, "opt": opt_state,
                "pipe": {"step": np.asarray(step + 1, np.int64)},
            })
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, {
            "params": params, "opt": opt_state,
            "pipe": {"step": np.asarray(args.steps, np.int64)},
        })
    obs.log("train.done", final_loss=round(float(metrics["loss"]), 4))
    return 0


def watchdog(args) -> int:
    """Respawn the trainer until it exits cleanly (node-failure recovery)."""
    attempts = 0
    argv = [a for a in sys.argv[1:] if a != "--watchdog"]
    while attempts < args.max_restarts + 1:
        rc = subprocess.call([sys.executable, "-m", "repro.launch.train",
                              *argv])
        if rc == 0:
            return 0
        attempts += 1
        obs.log_error("train.watchdog_restart", rc=rc, restart=attempts)
        # after a crash, never replay the same fault injection
        if "--crash-at" in argv:
            i = argv.index("--crash-at")
            argv = argv[:i] + argv[i + 2:]
        argv = [a for a in argv if not a.startswith("--crash-at=")]
    return 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--loss-block", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--corpus-bytes", type=int, default=1 << 20)
    ap.add_argument("--mesh", default=None, help="data,model e.g. 2,4")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault injection: hard-exit at this step")
    ap.add_argument("--watchdog", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args()
    # the trainer's operational log is its stdout contract: the watchdog
    # test greps the child's stdout for train.resume / train.done
    obs.configure(stream=sys.stdout)
    if args.watchdog:
        raise SystemExit(watchdog(args))
    raise SystemExit(train_main(args))


if __name__ == "__main__":
    main()
