"""Logical axis -> mesh axis mapping (the sharding policy).

One place decides how every parameter / activation / cache tensor is laid
out on the (pod, data, model) mesh; see DESIGN.md §4 for the table and the
divisibility fallbacks (non-divisible KV heads -> sequence-sharded caches,
small SSM head counts -> replicated inner dim, etc.).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes, mesh_degree
from repro.models.schema import param_axes


def _rules(cfg: ModelConfig, mesh, layout: str = "train") -> dict:
    """layout="train": FSDP (D rows over 'data') + TP — optimizer states
    shard with the params. layout="serve": weights RESIDENT, 1D TP only —
    FSDP would all-gather the full weight slice every decode step (measured
    1.85 GB/chip/step on qwen3-14b decode_32k) and serving has no optimizer
    states to amortize it. MoE expert weights keep D->'data' (2D: resident
    would not fit) — the serve MoE path psums D-partials instead of
    gathering (models/moe.py)."""
    tp = mesh_degree(mesh, "model")
    dp = mesh_degree(mesh, "data")
    ssm_ok = cfg.family in ("ssm", "hybrid") and \
        cfg.ssm_heads % tp == 0 and cfg.ssm_d_inner % tp == 0
    embed_rule = "data" if cfg.d_model % dp == 0 and dp > 1 else None
    if layout == "serve":
        embed_rule = None
    return {
        "embed": embed_rule,
        "expert_embed": "data" if cfg.d_model % dp == 0 and dp > 1 else None,
        "vocab_rows": None,
        "embed_head": None,
        "heads": "model" if (cfg.padded_heads * cfg.head_dim) % tp == 0 else None,
        "kv_heads": "model" if cfg.padded_kv_heads % tp == 0 else None,
        "mlp": "model" if cfg.d_ff % tp == 0 and cfg.d_ff else None,
        "vocab": "model" if cfg.padded_vocab % tp == 0 else None,
        "expert": "model" if cfg.n_experts % tp == 0 and cfg.n_experts else None,
        "ssm_inner": "model" if ssm_ok else None,
        "layers": None,
        None: None,
    }


def param_pspecs(cfg: ModelConfig, mesh, layout: str = "train"):
    """PartitionSpec tree matching init_params/abstract_params structure."""
    rules = _rules(cfg, mesh, layout)
    axes_tree = param_axes(cfg)
    return jax.tree_util.tree_map(
        lambda axes: P(*(rules[a] for a in axes)),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(cfg: ModelConfig, mesh, layout: str = "train"):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(cfg, mesh, layout))


def batch_pspecs(cfg: ModelConfig, mesh, *, global_batch: int):
    """Input batch specs. Batch dim shards over (pod, data) when divisible."""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    bspec = ba if ba and global_batch % nb == 0 else None
    specs = {"tokens": P(bspec, None)}
    if cfg.family == "vlm":
        specs["img_embeds"] = P(bspec, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(bspec, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, mesh, *, batch: int):
    """PartitionSpec tree matching init_cache structure for decode shapes.

    Policy: shard cache batch over (pod,data) when divisible; KV heads over
    'model' when divisible, else shard the sequence dim over 'model'.
    batch==1 (long-context): sequence dim takes 'data' (and 'model' if the
    heads don't divide) — flash-decode handles seq-sharded caches via its
    online-softmax combine.
    """
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    tp = mesh_degree(mesh, "model")
    b_ax = ba if ba and batch % nb == 0 else None
    kv_ok = cfg.padded_kv_heads % tp == 0
    seq_ax = []
    if b_ax is None and mesh_degree(mesh, "data") > 1:
        seq_ax.append("data")
        if "pod" in mesh.axis_names:
            seq_ax.insert(0, "pod")
    if not kv_ok:
        seq_ax.append("model")
    seq_ax = tuple(seq_ax) if seq_ax else None
    kv_spec = P(None, b_ax, seq_ax, "model" if kv_ok else None, None)

    specs = {"pos": P()}
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        specs["k"] = kv_spec
        specs["v"] = kv_spec
        # int8 KV supported on self-attn caches of dense-style families
        # (encdec keeps bf16: cross-attn cache is written once at prefill)
        if cfg.kv_cache_dtype == "int8" and cfg.family != "encdec":
            sc_spec = P(*tuple(kv_spec)[:4])
            specs["k_scale"] = sc_spec
            specs["v_scale"] = sc_spec
    if cfg.family == "encdec":
        specs["xk"] = kv_spec
        specs["xv"] = kv_spec
    if cfg.family in ("ssm", "hybrid"):
        ssm_ok = cfg.ssm_heads % tp == 0 and cfg.ssm_d_inner % tp == 0
        inner_ax = "model" if ssm_ok else None
        specs["conv"] = P(None, b_ax, None, inner_ax)
        specs["state"] = P(None, b_ax, inner_ax, None, None)
    if cfg.family == "hybrid":
        specs["k"] = kv_spec
        specs["v"] = kv_spec
    return specs


def logits_pspec(cfg: ModelConfig, mesh, *, global_batch: int):
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    bspec = ba if ba and global_batch % nb == 0 else None
    tp = mesh_degree(mesh, "model")
    return P(bspec, None, "model" if cfg.padded_vocab % tp == 0 else None)
