"""Byte-level tokenizer for the measured compression experiments.

Vocab layout: 0..255 = raw bytes, 256 = PAD, 257 = BOS (matches the
paper_predictors configs with vocab_size = 258). Lossless by construction
(identity on bytes), which makes bits-per-byte reporting exact — see
DESIGN.md §6 for why the measured runs use bytes rather than BPE.
"""
from __future__ import annotations

import numpy as np

VOCAB_SIZE = 258
PAD_ID = 256
BOS_ID = 257


def encode(data: bytes) -> np.ndarray:
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def decode(tokens: np.ndarray) -> bytes:
    t = np.asarray(tokens)
    t = t[t < 256]
    return t.astype(np.uint8).tobytes()
