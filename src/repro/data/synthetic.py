"""Synthetic corpora for the paper's experiments.

Two kinds of text:
  * "human-like" — procedurally generated English-ish prose from a large
    template/vocabulary pool with per-domain wordlists (wiki / code /
    math / clinical / web / science / novel / article — the paper's 8
    dataset categories). Deterministic given a seed; statistically
    human-like (entropy/byte ~ paper Table 2).
  * "LLM-generated" — sampled from a trained predictor LM at a given
    temperature (the paper's central setting: text produced BY a model is
    highly predictable FOR a model).
"""
from __future__ import annotations

import numpy as np

_DOMAIN_WORDS = {
    "wiki": ("the history of", "was established in", "is a city in",
             "population", "according to the census", "the region",
             "notable for", "culture and", "economy", "university",
             "founded", "century", "located in", "the municipality",
             "references", "the government", "during the war",
             "independence", "the river", "climate is"),
    "code": ("def", "return", "import numpy as np", "for i in range(",
             "if __name__ ==", "class", "self.", "print(", "lambda x:",
             "# compute the", "raise ValueError(", "try:", "except:",
             "while True:", "break", "assert", "np.zeros(", "result =",
             "value", "index"),
    "math": ("therefore", "the sum of", "equals", "let x be",
             "we have", "subtract", "multiply by", "the answer is",
             "dollars", "apples", "how many", "each day", "in total",
             "half of", "twice", "remainder", "per week", "costs",
             "solve for", "fraction"),
    "clinical": ("the patient", "was admitted", "presented with",
                 "history of", "diagnosis", "treatment with", "mg daily",
                 "discharged", "follow-up", "symptoms", "examination",
                 "laboratory", "no acute", "chronic", "hypertension",
                 "diabetes", "prescribed", "stable condition",
                 "recommended", "vital signs"),
    "web": ("this movie", "the plot", "I think", "really great",
            "the acting", "would recommend", "disappointing",
            "the director", "special effects", "the characters",
            "worth watching", "a masterpiece", "overrated", "the ending",
            "performances", "soundtrack", "script", "cinematography",
            "sequel", "rating"),
    "science": ("the experiment", "hypothesis", "the results show",
                "velocity", "the energy", "measured", "particles",
                "temperature", "pressure", "the equation", "constant",
                "observed", "quantum", "field", "force", "acceleration",
                "wavelength", "the system", "approximately", "theory"),
    "novel": ("she walked", "the morning", "he said", "quietly",
              "the old house", "remembered", "in the distance",
              "her eyes", "the journey", "suddenly", "whispered",
              "the mountains", "beneath", "a long time", "the sea",
              "shadows", "the road", "wondered", "smiled", "the night"),
    "article": ("we propose", "in this paper", "our method",
                "experimental results", "state-of-the-art", "baseline",
                "the model", "performance", "dataset", "we evaluate",
                "significantly", "approach", "in conclusion",
                "furthermore", "related work", "the algorithm",
                "we observe", "table shows", "outperforms", "accuracy"),
}

_FILLER = ("and", "of", "to", "in", "a", "is", "that", "it", "with", "as",
           "for", "was", "on", "are", "by", "at", "an", "be", "this",
           "which", "or", "from", "had", "not", "but", "what", "all",
           "were", "when", "we", "there", "can", "more", "if", "so")


def human_like(domain: str, n_bytes: int, seed: int = 0) -> bytes:
    """Markov-ish procedural text: domain phrases + fillers + punctuation.
    Entropy/byte lands near real English (~4.5 bits char-level)."""
    rng = np.random.default_rng(seed + hash(domain) % 2**16)
    words = _DOMAIN_WORDS[domain]
    out = []
    size = 0
    sentence_len = 0
    while size < n_bytes:
        r = rng.random()
        if r < 0.35:
            w = words[rng.integers(len(words))]
        elif r < 0.9:
            w = _FILLER[rng.integers(len(_FILLER))]
        else:
            w = "".join(chr(97 + rng.integers(26))
                        for _ in range(rng.integers(3, 9)))
        sentence_len += 1
        if sentence_len > rng.integers(8, 18):
            w += "." if domain != "code" else "\n"
            sentence_len = 0
        out.append(w)
        size += len(w) + 1
    text = " ".join(out)
    raw = text.encode()
    if len(raw) < n_bytes:  # join undercounts separators; pad with filler
        raw = raw + (b" " + b" ".join(
            _FILLER[i % len(_FILLER)].encode() for i in range(40)))
        raw = (raw * (n_bytes // max(1, len(raw)) + 1))
    return raw[:n_bytes]


DOMAINS = tuple(_DOMAIN_WORDS)

_OOD_WORDS = ("galvanize", "heuristic", "ephemeral", "quixotic", "zeitgeist",
              "labyrinthine", "mercurial", "obfuscate", "penumbra",
              "serendipity", "vignette", "juxtapose", "cacophony",
              "perfunctory", "recalcitrant", "vicissitude", "antediluvian",
              "grandiloquent", "pusillanimous", "sesquipedalian")


def human_like_ood(domain: str, n_bytes: int, seed: int = 0,
                   ood_frac: float = 0.25) -> bytes:
    """Human-like text with out-of-training-distribution lexical mass.
    Any finite training corpus leaves real human text with OOV content;
    the plain procedural generator unrealistically lacks it (it IS the
    training distribution). Used as the 'realistic human' condition in the
    Fig 9 experiment."""
    base = human_like(domain, n_bytes * 2, seed=seed).decode()
    rng = np.random.default_rng(seed + 999)
    words = base.split()
    mixed = " ".join(
        _OOD_WORDS[rng.integers(len(_OOD_WORDS))]
        if rng.random() < ood_frac else w for w in words)
    return mixed.encode()[:n_bytes]


def llm_generated(predictor, n_bytes: int, *, temperature=0.8, seed=0,
                  batch=8) -> bytes:
    """Sample `n_bytes` of byte-level text from a predictor LM — the
    paper's 'LLM-generated data'."""
    per = -(-n_bytes // batch)
    toks = predictor.generate(per, batch=batch, temperature=temperature,
                              seed=seed)
    from .tokenizer import decode
    return decode(toks.ravel())[:n_bytes]
