"""Deterministic, sharded, resumable data pipeline.

Design for 1000+ hosts:
  * the corpus is addressed as (shard, offset) with a fixed document->shard
    assignment; every host computes its own slice from (step, host_id) —
    no coordinator, no communication;
  * the pipeline cursor is a pure function of `step`, so checkpoint resume
    is exact: restoring `step` reproduces the identical batch sequence
    (tested bitwise in tests/test_fault_tolerance.py);
  * straggler mitigation: `reassign(lost_hosts)` re-splits the lost hosts'
    shard ranges among survivors deterministically (same decision on every
    survivor — again no coordination).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass
class PipelineState:
    step: int = 0
    active_hosts: tuple = ()   # host ids currently serving data

    def to_dict(self):
        return {"step": self.step, "active_hosts": list(self.active_hosts)}

    @staticmethod
    def from_dict(d):
        return PipelineState(step=int(d["step"]),
                             active_hosts=tuple(d["active_hosts"]))


class TokenPipeline:
    """Serves (global_batch, seq_len+1) int32 token batches from a flat
    token array (memory-mapped in production; in-memory here)."""

    def __init__(self, tokens: np.ndarray, *, global_batch: int,
                 seq_len: int, n_hosts: int = 1, host_id: int = 0,
                 seed: int = 0):
        self.tokens = np.asarray(tokens, dtype=np.int32)
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.seed = seed
        self.state = PipelineState(step=0,
                                   active_hosts=tuple(range(n_hosts)))
        n_windows = max(1, (self.tokens.size - 1) // seq_len)
        self._n_windows = n_windows

    # ------------------------------------------------------------ addressing
    def _window_ids(self, step: int) -> np.ndarray:
        """Deterministic global window assignment for `step` (all hosts
        agree without communication)."""
        rng = np.random.default_rng(self.seed + step)
        return rng.integers(0, self._n_windows, size=self.global_batch)

    def _host_slice(self, step: int) -> np.ndarray:
        """Rows of the global batch owned by this host under the current
        active-host set (lost hosts' rows re-split among survivors)."""
        hosts = self.state.active_hosts
        rows = np.arange(self.global_batch)
        owner = rows % len(hosts)
        return rows[np.asarray([hosts[o] for o in owner]) == self.host_id]

    def host_batch(self, step: Optional[int] = None) -> np.ndarray:
        """(rows_for_this_host, seq_len+1) int32."""
        step = self.state.step if step is None else step
        ids = self._window_ids(step)
        mine = self._host_slice(step)
        out = np.stack([
            self.tokens[i * self.seq_len:(i * self.seq_len) + self.seq_len + 1]
            for i in ids[mine]])
        return out

    def global_batch_array(self, step: Optional[int] = None) -> np.ndarray:
        """Full (global_batch, seq_len+1) — single-host mode / tests."""
        step = self.state.step if step is None else step
        ids = self._window_ids(step)
        return np.stack([
            self.tokens[i * self.seq_len:(i * self.seq_len) + self.seq_len + 1]
            for i in ids])

    def advance(self):
        self.state.step += 1

    # -------------------------------------------------------- fault handling
    def reassign(self, lost_hosts: Sequence[int]):
        """Straggler/failure mitigation: drop lost hosts; their batch rows
        are deterministically re-split among the survivors."""
        survivors = tuple(h for h in self.state.active_hosts
                          if h not in set(lost_hosts))
        if not survivors:
            raise RuntimeError("all hosts lost")
        self.state.active_hosts = survivors
