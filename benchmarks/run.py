"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` rows plus the full result tables, and
appends one schema-versioned record per bench to ``results/history.jsonl``
(the bench trajectory ``tools/bench_regress.py`` gates on — DESIGN.md §13).
Measured on this container's CPU with the small byte-level predictors
(paper's 1B-14B models scaled down; trends are the claims under test —
see EXPERIMENTS.md for the claim-by-claim comparison with the paper).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only name]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"
#: (name, us_per_call, derived) staged by _csv; main() drains the stage
#: into the history store after each bench (with that bench's registry).
ROWS: list[tuple[str, float, str]] = []


def _csv(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    console(f"{name},{us:.1f},{derived}")


def _compressor(pred, chunk=64, topk=32, batch=32):
    from repro.core import LLMCompressor
    return LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=batch)


def _ratio(pred, data: bytes, chunk=64, topk=32, verify=False):
    from repro.data.tokenizer import encode
    comp = _compressor(pred, chunk=chunk, topk=topk)
    toks = encode(data)
    t0 = time.time()
    blob, stats = comp.compress(toks)
    dt = time.time() - t0
    if verify:
        out = comp.decompress(blob)
        assert np.array_equal(out, toks), "LOSSLESS VIOLATION"
    return len(data) / len(blob), dt, stats


# ------------------------------------------------------- paper table analogs
def table2_information(quick=False):
    """Paper Table 2 + Fig 2: entropy / MI / n-gram redundancy of
    machine-gen vs human vs LLM-gen text."""
    from benchmarks.prep import human_dataset, llm_dataset
    from repro.core.entropy import analyze
    n = 4096 if quick else 12288
    structured = (b"ORDER|4231|PENDING|2024-01-01|ACME|1200.00|EA|\n" * 400)[:n]
    rows = {}
    t0 = time.time()
    rows["llm_generated"] = analyze(llm_dataset("wiki", n).decode("latin1"))
    rows["human_generated"] = analyze(human_dataset("wiki", n).decode("latin1"))
    rows["machine_structured"] = analyze(structured.decode("latin1"))
    console("\n== table2_information (entropy/byte, MI, top-10 n-gram coverage) ==")
    keys = list(next(iter(rows.values())))
    console(f"{'dataset':22s} " + " ".join(f"{k[:12]:>12s}" for k in keys))
    for name, r in rows.items():
        console(f"{name:22s} " + " ".join(f"{r[k]:12.3f}" for k in keys))
    _csv("table2_information", (time.time() - t0) * 1e6 / 3,
         f"llm_MI={rows['llm_generated']['mutual_info_bits']}")
    (RESULTS / "table2_information.json").write_text(json.dumps(rows, indent=1))
    return rows


def table3_traditional(quick=False):
    """Paper Table 3: traditional compressors on LLM-generated text."""
    from benchmarks.prep import llm_dataset
    from repro.core.baselines import run_baselines
    n = 4096 if quick else 8192
    doms = ("wiki", "code", "math")
    console("\n== table3_traditional (compression ratios) ==")
    out = {}
    t0 = time.time()
    for d in doms:
        out[d] = run_baselines(llm_dataset(d, n))
        console(f"{d:10s} " + " ".join(f"{k}={v:5.2f}" for k, v in out[d].items()))
    _csv("table3_traditional", (time.time() - t0) * 1e6 / len(doms),
         f"wiki_lzma={out['wiki']['lzma']}")
    (RESULTS / "table3_traditional.json").write_text(json.dumps(out, indent=1))
    return out


def table5_main(quick=False):
    """Paper Table 5: every method x every dataset category, including the
    LLM compressor ('ours'). Round-trip verified on one dataset."""
    from benchmarks.prep import DOMAINS, llm_dataset, predictor
    from repro.core.baselines import run_baselines
    n = 3072 if quick else 6144
    doms = DOMAINS[:4] if quick else DOMAINS
    pred = predictor("pred-base")
    console("\n== table5_main (ratios; ours = pred-base LLM compressor) ==")
    table = {}
    t0 = time.time()
    for i, d in enumerate(doms):
        data = llm_dataset(d, n)
        row = run_baselines(data)
        r, dt, stats = _ratio(pred, data, verify=(i == 0))
        row["ours_llm"] = round(r, 3)
        row["ours_bits_per_byte"] = round(8.0 / r, 3)
        table[d] = row
        console(f"{d:10s} " + " ".join(f"{k}={v:6.2f}" for k, v in row.items()))
    avg_ours = np.mean([r["ours_llm"] for r in table.values()])
    avg_gzip = np.mean([r["gzip"] for r in table.values()])
    _csv("table5_main", (time.time() - t0) * 1e6 / len(doms),
         f"ours_avg={avg_ours:.2f};gzip_avg={avg_gzip:.2f};"
         f"ours_over_gzip={avg_ours/avg_gzip:.2f}")
    (RESULTS / "table5_main.json").write_text(json.dumps(table, indent=1))
    return table


def fig_chunk_size(quick=False):
    """Paper §5.4: ratio vs chunk size (16..256), diminishing returns."""
    from benchmarks.prep import llm_dataset, predictor
    pred = predictor("pred-base")
    data = llm_dataset("wiki", 3072 if quick else 6144)
    chunks = (16, 32, 64) if quick else (16, 32, 64, 128, 256)
    console("\n== fig_chunk_size (ratio vs chunk) ==")
    t0 = time.time()
    out = {}
    for c in chunks:
        r, dt, _ = _ratio(pred, data, chunk=c)
        out[c] = round(r, 3)
        console(f"chunk={c:4d} ratio={r:.3f}")
    _csv("fig_chunk_size", (time.time() - t0) * 1e6 / len(chunks),
         ";".join(f"c{c}={v}" for c, v in out.items()))
    (RESULTS / "fig_chunk_size.json").write_text(
        json.dumps({str(k): v for k, v in out.items()}))
    return out


def fig_model_size(quick=False):
    """Paper §5.5 / Fig 6: ratio vs predictor size."""
    from benchmarks.prep import llm_dataset, predictor
    from repro.models.schema import count_params
    data = llm_dataset("wiki", 3072 if quick else 6144)
    names = ("pred-tiny", "pred-small") if quick else \
        ("pred-tiny", "pred-small", "pred-base")
    console("\n== fig_model_size (ratio vs params) ==")
    t0 = time.time()
    out = {}
    for n in names:
        pred = predictor(n)
        r, _, _ = _ratio(pred, data)
        out[n] = {"params": count_params(pred.cfg), "ratio": round(r, 3)}
        console(f"{n:12s} params={out[n]['params']:>10,d} ratio={r:.3f}")
    _csv("fig_model_size", (time.time() - t0) * 1e6 / len(names),
         ";".join(f"{k}={v['ratio']}" for k, v in out.items()))
    (RESULTS / "fig_model_size.json").write_text(json.dumps(out))
    return out


def fig_data_scale(quick=False):
    """Paper §5.6 / Fig 7: ratio vs dataset size (LLM ratio stays flat,
    dictionary methods drift slowly)."""
    from benchmarks.prep import llm_dataset, predictor
    from repro.core.baselines import gzip_ratio, lzma_ratio
    pred = predictor("pred-base")
    sizes = (2048, 4096) if quick else (2048, 4096, 8192, 16384)
    console("\n== fig_data_scale ==")
    t0 = time.time()
    out = {}
    for n in sizes:
        data = llm_dataset("wiki", n)
        r, _, _ = _ratio(pred, data)
        out[n] = {"ours": round(r, 3), "gzip": round(gzip_ratio(data), 3),
                  "lzma": round(lzma_ratio(data), 3)}
        console(f"n={n:6d} ours={out[n]['ours']:.3f} gzip={out[n]['gzip']:.3f} "
              f"lzma={out[n]['lzma']:.3f}")
    spread = max(v['ours'] for v in out.values()) - \
        min(v['ours'] for v in out.values())
    _csv("fig_data_scale", (time.time() - t0) * 1e6 / len(sizes),
         f"ours_spread={spread:.3f}")
    (RESULTS / "fig_data_scale.json").write_text(
        json.dumps({str(k): v for k, v in out.items()}))
    return out


def fig9_human_vs_llm(quick=False):
    """Paper Fig 9: the SAME model compresses LLM-generated text far better
    than human text, and the gap grows with chunk size."""
    from benchmarks.prep import human_dataset, llm_dataset, predictor
    from repro.data.synthetic import human_like_ood
    pred = predictor("pred-base")
    n = 3072 if quick else 6144
    gen = llm_dataset("web", n)
    hum = human_dataset("web", n, seed=5)          # in-training-distribution
    hum_ood = human_like_ood("web", n, seed=5)     # realistic (OOV mass)
    chunks = (16, 64) if quick else (16, 32, 64, 128)
    console("\n== fig9_human_vs_llm ==")
    t0 = time.time()
    out = {}
    for c in chunks:
        rg, _, _ = _ratio(pred, gen, chunk=c)
        rh, _, _ = _ratio(pred, hum, chunk=c)
        ro, _, _ = _ratio(pred, hum_ood, chunk=c)
        out[c] = {"llm_gen": round(rg, 3), "human_indist": round(rh, 3),
                  "human_ood": round(ro, 3),
                  "gap_indist": round(rg / rh, 3),
                  "gap_ood": round(rg / ro, 3)}
        console(f"chunk={c:4d} llm_gen={rg:.3f} human_indist={rh:.3f} "
              f"human_ood={ro:.3f} gap={rg/rh:.2f}/{rg/ro:.2f}x")
    _csv("fig9_human_vs_llm", (time.time() - t0) * 1e6 / len(chunks),
         ";".join(f"c{c}_gap={v['gap_indist']}/{v['gap_ood']}"
                  for c, v in out.items()))
    (RESULTS / "fig9_human_vs_llm.json").write_text(
        json.dumps({str(k): v for k, v in out.items()}))
    return out


def fig8_domain_models(quick=False):
    """Paper §5.7.2 / Fig 8: a domain-specialized predictor beats a similar-
    size general predictor on its own domain. The test corpus is NEUTRAL
    domain text (not generated by either competitor — the paper's datasets
    come from external GPT models)."""
    from benchmarks.prep import human_dataset, train_predictor
    from repro.serve.engine import ModelPredictor
    from repro.data.tokenizer import BOS_ID
    data = human_dataset("math", 3072 if quick else 6144, seed=41)
    console("\n== fig8_domain_models (math domain) ==")
    t0 = time.time()
    out = {}
    p_gen, cfg = train_predictor("pred-small")
    p_dom, cfg_d = train_predictor("pred-small", seed=3, domain_mix=("math",))
    for name, params, c in (("general-small", p_gen, cfg),
                            ("math-small", p_dom, cfg_d)):
        pred = ModelPredictor(params, c, bos_id=BOS_ID)
        r, _, _ = _ratio(pred, data)
        out[name] = round(r, 3)
        console(f"{name:14s} ratio={r:.3f}")
    _csv("fig8_domain_models", (time.time() - t0) * 1e6 / 2,
         f"general={out['general-small']};domain={out['math-small']}")
    (RESULTS / "fig8_domain_models.json").write_text(json.dumps(out))
    return out


def coder_throughput(quick=False):
    """Host entropy-coder + CDF-pipeline throughput (the system's
    TPU/host interface cost): reference AC vs. batched interleaved rANS
    at the production decode-batch size (see benchmarks/coder_bench.py
    for the full B-sweep)."""
    from repro.core import ac, rans
    from repro.core.cdf import pmf_to_cdf, quantize_pmf, topk_quantized_jit
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    n = 20_000 if quick else 60_000
    pmf = rng.dirichlet(np.ones(256) * 0.3)
    cdf = pmf_to_cdf(np.asarray(quantize_pmf(jnp.asarray(pmf), 16)))
    syms = rng.choice(256, n, p=pmf)
    t0 = time.time()
    enc = ac.ArithmeticEncoder()
    for s in syms:
        enc.encode(int(s), cdf)
    blob = enc.finish()
    t_enc = time.time() - t0
    t0 = time.time()
    dec = ac.ArithmeticDecoder(blob)
    out = [dec.decode(cdf) for _ in range(n)]
    t_dec = time.time() - t0
    assert out == list(syms)
    # batched rANS: same total token count spread over B=64 streams
    B = 64
    bsyms = syms[:n - n % B].reshape(B, -1)
    bcdf = np.broadcast_to(cdf, (B,) + cdf.shape)
    t0 = time.time()
    renc = rans.BatchedRansEncoder(B)
    for t in range(bsyms.shape[1]):
        renc.put_symbols(bsyms[:, t], bcdf, 16)
    rblobs = renc.finish()
    r_enc = time.time() - t0
    t0 = time.time()
    rdec = rans.BatchedRansDecoder(rblobs)
    rout = np.empty_like(bsyms)
    for t in range(bsyms.shape[1]):
        rout[:, t] = rdec.get(bcdf, 16)
    r_dec = time.time() - t0
    assert np.array_equal(rout, bsyms)
    rn = bsyms.size
    speedup = (rn / (r_enc + r_dec)) / (n / (t_enc + t_dec))
    lg = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    topk_quantized_jit(lg, 64, 16)  # warm
    t0 = time.time()
    for _ in range(20):
        topk_quantized_jit(lg, 64, 16)[0].block_until_ready()
    t_cdf = (time.time() - t0) / 20
    console("\n== coder_throughput ==")
    console(f"AC encode {n/t_enc/1e3:.0f} ksym/s | decode {n/t_dec/1e3:.0f} "
          f"ksym/s | rANS(B=64) encode {rn/r_enc/1e3:.0f} ksym/s | decode "
          f"{rn/r_dec/1e3:.0f} ksym/s ({speedup:.1f}x) | "
          f"topk-CDF (64x4096) {t_cdf*1e3:.2f} ms/call")
    _csv("coder_throughput", t_enc / n * 1e6,
         f"enc_ksym_s={n/t_enc/1e3:.0f};dec_ksym_s={n/t_dec/1e3:.0f};"
         f"rans_enc_ksym_s={rn/r_enc/1e3:.0f};"
         f"rans_dec_ksym_s={rn/r_dec/1e3:.0f};rans_speedup={speedup:.1f}")
    return {"enc_sym_s": n / t_enc, "dec_sym_s": n / t_dec,
            "rans_enc_sym_s": rn / r_enc, "rans_dec_sym_s": rn / r_dec}


def service_throughput(quick=False):
    """Continuous-batching service vs naive grouped decode on ragged jobs
    (chunk counts 1..2B) — the ROADMAP's many-concurrent-users shape.
    Full sweep + the >= 1.5x CI gate live in benchmarks/service_bench.py."""
    from benchmarks.service_bench import run_bench, run_mixed
    t0 = time.time()
    if quick:
        res = run_bench(n_jobs=12, slots=4, chunk=16)
        mixed = run_mixed(slots=4, chunk=16)
    else:
        res = run_bench()
        mixed = run_mixed()
    res.update(mixed)
    _csv("service_throughput", (time.time() - t0) * 1e6 / res["n_jobs"],
         f"jobs_per_s={res['service_jobs_per_s']:.2f};"
         f"wall_speedup={res['wall_speedup']:.2f};"
         f"step_speedup={res['step_speedup']:.2f};"
         f"occupancy={res['occupancy']:.2f}")
    (RESULTS / "service_throughput.json").write_text(json.dumps(res, indent=1))
    return res


def decompress_throughput(quick=False):
    """Speculative (draft/verify/accept) vs lock-step batched decode on
    argmax-following text — DESIGN.md §9's tentpole. The >= 2x wall and
    dispatch-ratio CI gates live in benchmarks/decompress_bench.py."""
    from benchmarks.decompress_bench import run_bench
    if quick:
        res = run_bench(n_jobs=2, tokens=1024, slots=4, dispatch_ms=0.5)
    else:
        res = run_bench()
    _csv("decompress_throughput",
         1e6 / max(1e-9, res["spec_tok_per_s"]),
         f"wall_speedup={res['wall_speedup']:.2f};"
         f"dispatch_ratio={res['dispatch_ratio']:.2f};"
         f"tok_per_s={res['spec_tok_per_s']:.0f}")
    (RESULTS / "decompress_throughput.json").write_text(
        json.dumps(res, indent=1))
    return res


def telemetry_overhead(quick=False):
    """DESIGN.md §10 + §13 gates: running the service decode bench with
    the metrics registry enabled must cost < 2% wall time over disabled,
    and with a timeline recorder installed <= 10% (telemetry is always
    byte-inert; this bounds its *time* cost too). benchmarks/run.py
    exits non-zero when either gate fails."""
    from benchmarks.service_bench import run_overhead
    t0 = time.time()
    if quick:
        res = run_overhead(n_jobs=12, slots=4, chunk=16, repeats=3)
    else:
        res = run_overhead()
    _csv("telemetry_overhead", (time.time() - t0) * 1e6,
         f"overhead_pct={res['overhead'] * 100:.2f};"
         f"timeline_pct={res['timeline_overhead'] * 100:.2f};"
         f"pass={res['gate_pass']}")
    (RESULTS / "telemetry_overhead.json").write_text(
        json.dumps(res, indent=1))
    return res


def router_routing(quick=False):
    """DESIGN.md §11 gate: adaptive per-chunk codec routing loses at
    most 2% to the better of pure-LLM / fallback-only on EVERY traffic
    segment, and beats both on mixed traffic (where neither strategy
    wins every chunk). All strategies measured as v5 containers, so
    index overhead cancels. Full table + CLI gate live in
    benchmarks/router_bench.py."""
    from benchmarks.router_bench import run_bench
    t0 = time.time()
    res = run_bench(seg_bytes=1024 if quick else 8192)
    console("\n== router_routing (v5 ratios per traffic segment) ==")
    for name, s in res["segments"].items():
        console(f"{name:16s} llm={s['llm']:.3f} fb={s['fallback']:.3f} "
              f"routed={s['routed']:.3f} "
              f"{'ok' if s['pass'] else 'FAIL'}")
    mixed = res["segments"]["mixed_traffic"]
    _csv("router_routing", (time.time() - t0) * 1e6 / len(res["segments"]),
         f"mixed_routed={mixed['routed']};mixed_llm={mixed['llm']};"
         f"mixed_fb={mixed['fallback']};pass={res['gate_pass']}")
    (RESULTS / "router_routing.json").write_text(json.dumps(res, indent=1))
    return res


def context_ratio(quick=False):
    """DESIGN.md §12 gates: carried-context v6 archives must beat
    context-free chunking by >= 1.10x on the order-K corpus, and the
    radix prefix cache must cut shared-prefix prefill lane-steps by
    >= 1.3x with byte-identical output. Full sweep + CLI gate live in
    benchmarks/context_bench.py."""
    from benchmarks.context_bench import run_prefill_bench, run_ratio_bench
    t0 = time.time()
    if quick:
        ratio = run_ratio_bench(n_tokens=512)
        prefill = run_prefill_bench(n_jobs=6, prefix_len=48)
    else:
        ratio = run_ratio_bench()
        prefill = run_prefill_bench()
    res = {"ratio": ratio, "prefill": prefill,
           "gate_pass": ratio["gate_pass"] and prefill["gate_pass"]}
    console("\n== context_ratio (carried v6 vs context-free; prefix cache) ==")
    console(f"carried gain {ratio['ratio_gain']:.3f}x "
          f"(floor {ratio['ratio_floor']}x) | prefill savings "
          f"{prefill['prefill_savings']:.2f}x "
          f"(floor {prefill['prefill_floor']}x, "
          f"{prefill['cache_hits']} hits)")
    _csv("context_ratio", (time.time() - t0) * 1e6,
         f"gain={ratio['ratio_gain']:.3f};"
         f"prefill_savings={prefill['prefill_savings']:.2f};"
         f"cache_hits={prefill['cache_hits']};pass={res['gate_pass']}")
    (RESULTS / "context_ratio.json").write_text(json.dumps(res, indent=1))
    return res


ALL = [table2_information, table3_traditional, table5_main, fig_chunk_size,
       fig_model_size, fig_data_scale, fig9_human_vs_llm, fig8_domain_models,
       coder_throughput, service_throughput, decompress_throughput,
       telemetry_overhead, router_routing, context_ratio]


def main() -> None:
    from repro import obs
    from repro.obs.bench_history import BenchHistory, BenchRecord
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--history", default=str(RESULTS / "history.jsonl"),
                    help="bench-trajectory JSONL this run appends to")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    hist = BenchHistory(args.history)
    t0 = time.time()
    gate_failures = []
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        # each bench runs against a fresh process-global registry; its
        # compact snapshot (compressor/rans/draft counters, span-derived
        # phase breakdown) rides the bench's history record
        reg = obs.MetricsRegistry(name=fn.__name__)
        prev = obs.set_registry(reg)
        n_before = len(ROWS)
        try:
            out = fn(quick=args.quick)
        finally:
            obs.set_registry(prev)
        for name, us, derived in ROWS[n_before:]:
            hist.append(BenchRecord.build(name, us, derived, registry=reg,
                                          quick=args.quick))
        if isinstance(out, dict) and out.get("gate_pass") is False:
            gate_failures.append(fn.__name__)
    console(f"\n# total {time.time()-t0:.0f}s")
    console("\n# rows appended to " + str(hist.path))
    for name, us, derived in ROWS:
        console(f"{name},{us:.1f},{derived}")
    if gate_failures:
        console(f"FAIL: benchmark gate(s): {', '.join(gate_failures)}",
                err=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
