"""Aggregate dry-run cell JSONs into the §Roofline / §Dry-run tables.

With ``--measured FILE`` (a JSON object mapping ``"arch/shape"`` to a
measured per-step wall time in seconds) the summary additionally emits
the **attainment** column — ``t_star / measured``, the fraction of the
binding compute/memory/collective bound each config actually achieves
(DESIGN.md §13; the ROADMAP's "as fast as the hardware allows" signal).

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
  PYTHONPATH=src python -m benchmarks.roofline --measured steps.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

ARCH_ORDER = ["llava_next_34b", "mamba2_130m", "qwen3_moe_235b_a22b",
              "granite_moe_1b_a400m", "qwen3_14b", "deepseek_7b",
              "h2o_danube_3_4b", "qwen3_1_7b", "zamba2_7b",
              "whisper_large_v3"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "") -> dict:
    out = {}
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "")
    for p in sorted(RESULTS.glob(f"*{suffix}.json")):
        d = json.loads(p.read_text())
        out[(d["arch"], d["shape"])] = d
    return out


def _render(hdr: list, rows: list, md: bool) -> str:
    widths = [max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
              for i, h in enumerate(hdr)]
    sep = " | " if md else "  "
    if md:
        lines = ["| " + sep.join(h.ljust(w)
                                 for h, w in zip(hdr, widths)) + " |",
                 "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
        for row in rows:
            lines.append("| " + sep.join(c.ljust(w)
                                         for c, w in zip(row, widths)) + " |")
    else:
        lines = [sep.join(h.ljust(w) for h, w in zip(hdr, widths))]
        for row in rows:
            lines.append(sep.join(c.ljust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def fmt_table(cells: dict, md=False) -> str:
    hdr = ["arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
           "bottleneck", "useful", "roofline", "mem/dev(GiB)"]
    rows = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None:
                continue
            if "skipped" in d:
                rows.append([a, s, "-", "-", "-", "SKIP", "-", "-", "-"])
                continue
            r = d["roofline"]
            mem = d["memory"]["bytes_per_device"] / 2**30
            rows.append([
                a, s, f"{r['t_compute_s']:.3f}", f"{r['t_memory_s']:.3f}",
                f"{r['t_collective_s']:.3f}", r["bottleneck"],
                f"{r['useful_flops_ratio']:.2f}",
                f"{r['roofline_fraction']:.3f}", f"{mem:.2f}"])
    return _render(hdr, rows, md)


def cell_t_star(r: dict) -> float:
    """Binding roofline bound for a stored cell's roofline dict —
    recorded directly by newer cells, derived for pre-§13 artifacts."""
    if "t_star_s" in r:
        return float(r["t_star_s"])
    return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])


def attainment_rows(cells: dict, measured: dict) -> list:
    """(arch, shape, t_star, measured_s, attainment, bottleneck) per
    cell that has a measured step time. ``measured`` maps
    ``"arch/shape"`` -> wall seconds per step."""
    out = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None or "skipped" in d:
                continue
            m = measured.get(f"{a}/{s}")
            if m is None or not m > 0:
                continue
            r = d["roofline"]
            t_star = cell_t_star(r)
            out.append((a, s, t_star, float(m),
                        t_star / float(m) if t_star else 0.0,
                        r["bottleneck"]))
    return out


def attainment_table(cells: dict, measured: dict, md=False) -> str:
    hdr = ["arch", "shape", "t_star(s)", "measured(s)", "attainment",
           "bottleneck"]
    rows = [[a, s, f"{t:.4f}", f"{m:.4f}", f"{att:.3f}", bn]
            for a, s, t, m, att, bn in attainment_rows(cells, measured)]
    return _render(hdr, rows, md)


def summarize(mesh="single", md=False, tag="", measured=None):
    cells = load(mesh, tag)
    console(fmt_table(cells, md=md))
    ok = [d for d in cells.values() if "skipped" not in d]
    if not ok:
        return
    worst = min(ok, key=lambda d: d["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda d: d["roofline"]["t_collective_s"] /
               max(1e-12, max(d["roofline"]["t_compute_s"],
                              d["roofline"]["t_memory_s"])))
    console(f"\ncells: {len(cells)} ({len(ok)} compiled, "
            f"{len(cells)-len(ok)} skipped)")
    console(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
            f"({worst['roofline']['roofline_fraction']:.4f})")
    console(f"most collective-bound: {coll['arch']}/{coll['shape']}")
    if measured:
        rows = attainment_rows(cells, measured)
        console("\nmeasured vs roofline:")
        console(attainment_table(cells, measured, md=md))
        if rows:
            best = max(rows, key=lambda r: r[4])
            worst_a = min(rows, key=lambda r: r[4])
            console(f"attainment: best {best[0]}/{best[1]} ({best[4]:.3f}), "
                    f"worst {worst_a[0]}/{worst_a[1]} ({worst_a[4]:.3f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--measured", default="",
                    help="JSON file: {'arch/shape': step_seconds} -> "
                         "adds the attainment table")
    args = ap.parse_args()
    measured = None
    if args.measured:
        measured = json.loads(pathlib.Path(args.measured).read_text())
    summarize(args.mesh, args.md, args.tag, measured=measured)


if __name__ == "__main__":
    main()
