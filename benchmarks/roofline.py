"""Aggregate dry-run cell JSONs into the §Roofline / §Dry-run tables.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

ARCH_ORDER = ["llava_next_34b", "mamba2_130m", "qwen3_moe_235b_a22b",
              "granite_moe_1b_a400m", "qwen3_14b", "deepseek_7b",
              "h2o_danube_3_4b", "qwen3_1_7b", "zamba2_7b",
              "whisper_large_v3"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "") -> dict:
    out = {}
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "")
    for p in sorted(RESULTS.glob(f"*{suffix}.json")):
        d = json.loads(p.read_text())
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_table(cells: dict, md=False) -> str:
    hdr = ["arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
           "bottleneck", "useful", "roofline", "mem/dev(GiB)"]
    rows = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None:
                continue
            if "skipped" in d:
                rows.append([a, s, "-", "-", "-", "SKIP", "-", "-", "-"])
                continue
            r = d["roofline"]
            mem = d["memory"]["bytes_per_device"] / 2**30
            rows.append([
                a, s, f"{r['t_compute_s']:.3f}", f"{r['t_memory_s']:.3f}",
                f"{r['t_collective_s']:.3f}", r["bottleneck"],
                f"{r['useful_flops_ratio']:.2f}",
                f"{r['roofline_fraction']:.3f}", f"{mem:.2f}"])
    widths = [max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
              for i, h in enumerate(hdr)]
    sep = " | " if md else "  "
    lines = [sep.join(h.ljust(w) for h, w in zip(hdr, widths))]
    if md:
        lines.insert(0, "| " + lines[0] + " |")
        lines[0] = "| " + sep.join(h.ljust(w) for h, w in zip(hdr, widths)) + " |"
        lines = [lines[0],
                 "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
        for row in rows:
            lines.append("| " + sep.join(c.ljust(w)
                                         for c, w in zip(row, widths)) + " |")
    else:
        for row in rows:
            lines.append(sep.join(c.ljust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def summarize(mesh="single", md=False, tag=""):
    cells = load(mesh, tag)
    print(fmt_table(cells, md=md))
    ok = [d for d in cells.values() if "skipped" not in d]
    if not ok:
        return
    worst = min(ok, key=lambda d: d["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda d: d["roofline"]["t_collective_s"] /
               max(1e-12, max(d["roofline"]["t_compute_s"],
                              d["roofline"]["t_memory_s"])))
    print(f"\ncells: {len(cells)} ({len(ok)} compiled, "
          f"{len(cells)-len(ok)} skipped)")
    print(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.4f})")
    print(f"most collective-bound: {coll['arch']}/{coll['shape']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    summarize(args.mesh, args.md, args.tag)


if __name__ == "__main__":
    main()
