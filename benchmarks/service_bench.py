"""Continuous-batching service benchmark: jobs/sec on ragged traffic.

Compares the slot scheduler (repro.service) against the naive grouped
decode path (LLMCompressor.decompress per job) on a RAGGED workload —
jobs whose chunk counts span 1..2B, with partial final chunks. The
grouped path runs every group to its longest member and leaves lanes
empty in each job's final group; the scheduler refills finished slots
from the queue on the next step, so its model-step count approaches
total_tokens / B.

Asserted metric: **jobs/sec** (the ISSUE's throughput criterion) —
measured margin is ~5-10x, far above the 1.5x floor, so CI timing noise
cannot flip it. The deterministic model-step speedup is reported
alongside; on a uniform 1..2B-chunk workload its structural ceiling is
E[ceil(k/B)]*B/E[k] ~= 1.4x (occupancy 0.99 vs ~0.70), and it
*understates* the service's edge: the grouped path additionally pays a
jit recompile per distinct group shape with a real model, which the
model-free table predictor here does not charge it for. Exits non-zero
below the floor, so CI regresses loudly (same convention as
coder_bench.py).

  PYTHONPATH=src python benchmarks/service_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

SPEEDUP_FLOOR = 1.5
OVERHEAD_LIMIT = 0.02          # telemetry-enabled slowdown budget (§10)
TIMELINE_LIMIT = 0.10          # timeline-recording slowdown budget (§13)
OVERHEAD_ABS_SLACK_S = 0.010   # absolute per-leg jitter allowance


class TablePredictor:
    """Deterministic model-free predictor (next-token logits from a fixed
    (V, V) table) with a decode_step counter — isolates scheduling from
    model cost, and the step counter is the dispatch count a real
    accelerator would pay."""

    def __init__(self, vocab_size=64, seed=0):
        self.vocab_size = int(vocab_size)
        self.bos_id = self.vocab_size - 1
        rng = np.random.default_rng(seed)
        self._table = (rng.standard_normal(
            (self.vocab_size, self.vocab_size)) * 2.0).astype(np.float32)
        self.n_steps = 0

    def score_chunks(self, tokens):
        tokens = np.asarray(tokens, np.int32)
        prev = np.concatenate(
            [np.full((tokens.shape[0], 1), self.bos_id, np.int32),
             tokens[:, :-1]], axis=1)
        return self._table[prev]

    def begin_decode(self, batch):
        return None

    def decode_step(self, state, prev_tokens):
        self.n_steps += 1
        return self._table[np.asarray(prev_tokens, np.int32)], state

    # speculative decode hooks (decompress_bench.py): one verify forward
    # scores all K+1 positions; counts as ONE model dispatch, which is
    # exactly the economy speculation buys on a real accelerator
    def verify_steps(self, state, seq):
        self.n_steps += 1
        return self._table[np.asarray(seq, np.int32)], state

    def rollback(self, snapshots, accepted):
        return snapshots


def ragged_workload(rng, n_jobs: int, slots: int, chunk: int):
    """Job sizes spanning 1 token .. 2B chunks (the ISSUE's acceptance
    workload): every job ends in a partial chunk with high probability."""
    sizes = [1 + int(rng.integers(0, 2 * slots * chunk))
             for _ in range(n_jobs)]
    return [rng.integers(0, 60, n).astype(np.int32) for n in sizes]


def run_bench(n_jobs=24, slots=8, chunk=32, topk=8, seed=0, log=console):
    from repro.core import LLMCompressor
    from repro.service import CompressionService

    rng = np.random.default_rng(seed)
    datas = ragged_workload(rng, n_jobs, slots, chunk)
    total_tokens = sum(d.size for d in datas)
    total_chunks = sum(max(1, -(-d.size // chunk)) for d in datas)

    pred = TablePredictor()
    comp = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=slots, container_version=4)
    blobs = [comp.compress(d)[0] for d in datas]

    # ---- naive: one grouped decompress per job, sequentially
    pred.n_steps = 0
    t0 = time.time()
    for b, d in zip(blobs, datas):
        out = comp.decompress(b)
        assert np.array_equal(out, d), "LOSSLESS VIOLATION (grouped)"
    naive_dt = time.time() - t0
    naive_steps = pred.n_steps

    # ---- service: all jobs share one slot machine
    svc = CompressionService(pred, slots=slots, chunk_size=chunk, topk=topk)
    pred.n_steps = 0
    t0 = time.time()
    handles = [svc.submit_decompress(b) for b in blobs]
    for h, d in zip(handles, datas):
        assert np.array_equal(h.result(), d), "LOSSLESS VIOLATION (service)"
    svc_dt = time.time() - t0
    svc_steps = pred.n_steps
    assert svc_steps == svc.stats.model_steps

    step_speedup = naive_steps / max(1, svc_steps)
    wall_speedup = naive_dt / max(1e-9, svc_dt)
    log(f"workload: {n_jobs} jobs, {total_chunks} chunks, "
        f"{total_tokens} tokens, B={slots}, C={chunk}")
    log(f"naive grouped : {naive_steps:6d} model steps  "
        f"{n_jobs / naive_dt:7.2f} jobs/s  ({naive_dt:.2f}s)")
    log(f"slot scheduler: {svc_steps:6d} model steps  "
        f"{n_jobs / svc_dt:7.2f} jobs/s  ({svc_dt:.2f}s)  "
        f"occupancy {svc.stats.occupancy:.2f}")
    log(f"step speedup {step_speedup:.2f}x | wall speedup {wall_speedup:.2f}x")
    return {
        "n_jobs": n_jobs, "slots": slots, "chunk": chunk,
        "naive_steps": naive_steps, "service_steps": svc_steps,
        "naive_jobs_per_s": n_jobs / naive_dt,
        "service_jobs_per_s": n_jobs / svc_dt,
        "step_speedup": step_speedup, "wall_speedup": wall_speedup,
        "occupancy": svc.stats.occupancy,
    }


def run_mixed(slots=8, chunk=32, topk=8, seed=1, log=console):
    """Mixed-direction traffic demo: compress and decompress jobs share
    the same batch; verified lossless. Reported, not asserted — the
    speedup claim is the decode comparison above."""
    from repro.core import LLMCompressor
    from repro.service import CompressionService

    rng = np.random.default_rng(seed)
    datas = ragged_workload(rng, 10, slots, chunk)
    pred = TablePredictor()
    comp = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=slots, container_version=4)
    blobs = [comp.compress(d)[0] for d in datas[:5]]
    svc = CompressionService(pred, slots=slots, chunk_size=chunk, topk=topk)
    t0 = time.time()
    hc = [svc.submit_compress(d) for d in datas[5:]]
    hd = [svc.submit_decompress(b) for b in blobs]
    for h, d in zip(hd, datas[:5]):
        assert np.array_equal(h.result(), d)
    for h, d in zip(hc, datas[5:]):
        blob, _ = h.result()
        assert np.array_equal(comp.decompress(blob), d)
    dt = time.time() - t0
    log(f"mixed traffic : 5 compress + 5 decompress jobs in {dt:.2f}s, "
        f"{svc.stats.model_steps} steps, occupancy "
        f"{svc.stats.occupancy:.2f}")
    return {"mixed_steps": svc.stats.model_steps,
            "mixed_occupancy": svc.stats.occupancy}


def run_overhead(n_jobs=24, slots=8, chunk=32, topk=8, repeats=5, seed=0,
                 log=console):
    """Telemetry-overhead gate (DESIGN.md §10): the same ragged decode
    workload through two services — registry enabled vs disabled —
    interleaved, min-of-repeats (min is the noise-robust estimator for a
    deterministic workload) — plus a third leg with a timeline recorder
    installed (DESIGN.md §13: every-step scheduler spans + event ring
    writes). Decoded tokens are compared against the originals every
    repeat on all legs: telemetry must never change output bytes.
    Budgets: enabled <= disabled * (1 + 2%) + 10ms absolute slack;
    recording <= *enabled* * (1 + 10%) + the same slack — the recorder
    requires the registry, so its budget bounds the marginal cost of
    the timeline on top of telemetry (the budgets compose: disabled ->
    recording is bounded by both chained together). The timeline leg is
    judged on the MEDIAN of per-round recording/enabled ratios: adjacent
    legs share one drift regime, so the ratio cancels the low-frequency
    CPU noise that min-of-repeats cannot (each min may come from a
    different regime). Override with $REPRO_TELEMETRY_OVERHEAD_MAX /
    $REPRO_TIMELINE_OVERHEAD_MAX."""
    import os
    import statistics

    from repro import obs
    from repro.core import LLMCompressor
    from repro.service import CompressionService

    rng = np.random.default_rng(seed)
    datas = ragged_workload(rng, n_jobs, slots, chunk)
    pred = TablePredictor()
    comp = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=slots, container_version=4)
    blobs = [comp.compress(d)[0] for d in datas]

    def leg(enabled, record=False):
        svc = CompressionService(
            pred, slots=slots, chunk_size=chunk, topk=topk,
            trace=obs.TimelineRecorder() if record else None)
        svc.registry.enabled = enabled
        t0 = time.perf_counter()
        handles = [svc.submit_decompress(b) for b in blobs]
        outs = [h.result() for h in handles]
        dt = time.perf_counter() - t0
        if record:
            svc.close()             # uninstall the recorder before the
        for o, d in zip(outs, datas):    # next (untraced) leg runs
            assert np.array_equal(o, d), \
                f"LOSSLESS VIOLATION (telemetry enabled={enabled})"
        return dt

    inf = float("inf")
    best = {"disabled": inf, "enabled": inf, "recording": inf}
    ratios = []
    leg(True)                       # warm all paths outside the clocks
    leg(False)
    leg(True, record=True)
    for _ in range(repeats):        # interleaved: drift-fair
        best["disabled"] = min(best["disabled"], leg(False))
        t_ena = leg(True)
        t_rec = leg(True, record=True)
        best["enabled"] = min(best["enabled"], t_ena)
        best["recording"] = min(best["recording"], t_rec)
        ratios.append(t_rec / max(1e-9, t_ena))
    limit = float(os.environ.get("REPRO_TELEMETRY_OVERHEAD_MAX",
                                 OVERHEAD_LIMIT))
    tl_limit = float(os.environ.get("REPRO_TIMELINE_OVERHEAD_MAX",
                                    TIMELINE_LIMIT))
    overhead = best["enabled"] / max(1e-9, best["disabled"]) - 1.0
    tl_overhead = statistics.median(ratios) - 1.0
    ok = best["enabled"] <= best["disabled"] * (1.0 + limit) \
        + OVERHEAD_ABS_SLACK_S
    tl_ok = tl_overhead <= tl_limit \
        + OVERHEAD_ABS_SLACK_S / max(1e-9, best["enabled"])
    log(f"telemetry overhead: enabled {best['enabled'] * 1e3:.1f}ms vs "
        f"disabled {best['disabled'] * 1e3:.1f}ms -> {overhead * 100:+.2f}% "
        f"(budget {limit * 100:.0f}%) {'PASS' if ok else 'FAIL'}")
    log(f"timeline overhead: recording {best['recording'] * 1e3:.1f}ms vs "
        f"enabled {best['enabled'] * 1e3:.1f}ms, median round ratio "
        f"{tl_overhead * 100:+.2f}% (budget {tl_limit * 100:.0f}%) "
        f"{'PASS' if tl_ok else 'FAIL'}")
    return {"enabled_s": best["enabled"], "disabled_s": best["disabled"],
            "recording_s": best["recording"],
            "overhead": overhead, "limit": limit,
            "timeline_overhead": tl_overhead, "timeline_limit": tl_limit,
            "repeats": repeats,
            "n_jobs": n_jobs, "slots": slots, "chunk": chunk,
            "gate_pass": ok and tl_ok}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for the CI fast job")
    ap.add_argument("--overhead", action="store_true",
                    help="also run the telemetry-overhead gate")
    args = ap.parse_args()
    if args.smoke:
        res = run_bench(n_jobs=16, slots=4, chunk=16)
    else:
        res = run_bench()
    run_mixed(slots=4 if args.smoke else 8,
              chunk=16 if args.smoke else 32)
    console(f"service_throughput,"
            f"{1e6 / max(1e-9, res['service_jobs_per_s']):.1f},"
            f"step_speedup={res['step_speedup']:.2f};"
            f"occupancy={res['occupancy']:.2f};"
            f"jobs_per_s={res['service_jobs_per_s']:.2f}")
    if res["wall_speedup"] < SPEEDUP_FLOOR:
        console(f"FAIL: jobs/sec speedup {res['wall_speedup']:.2f}x < "
                f"{SPEEDUP_FLOOR}x on ragged workload", err=True)
        return 1
    console(f"PASS: jobs/sec speedup {res['wall_speedup']:.2f}x >= "
            f"{SPEEDUP_FLOOR}x (model steps: {res['step_speedup']:.2f}x, "
            f"occupancy {res['occupancy']:.2f})")
    if args.overhead:
        if args.smoke:
            ores = run_overhead(n_jobs=12, slots=4, chunk=16, repeats=3)
        else:
            ores = run_overhead()
        if not ores["gate_pass"]:
            console(f"FAIL: telemetry overhead {ores['overhead'] * 100:.2f}%"
                    f" (budget {ores['limit'] * 100:.0f}%) / timeline "
                    f"{ores['timeline_overhead'] * 100:.2f}% (budget "
                    f"{ores['timeline_limit'] * 100:.0f}%)", err=True)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
