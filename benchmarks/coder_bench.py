"""Host entropy-coder micro-benchmark: reference AC vs. batched rANS.

The model runs on the accelerator; the host coder is what bounds
end-to-end tokens/s (ROADMAP north star). This benchmark isolates that
cost: encode+decode throughput of the two backends over identical
quantized 16-bit CDF sequences at decode-batch sizes B ∈ {1, 16, 64}.

The AC is a per-stream Python loop, so its throughput is flat in B; the
interleaved rANS coder advances all B stream states with a handful of
numpy ufuncs per position, so its per-token cost falls ~linearly with B.

  PYTHONPATH=src python benchmarks/coder_bench.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (same convention as
benchmarks/run.py) plus a human-readable table, and exits non-zero if
batched rANS at B=64 fails the >= 5x encode+decode speedup criterion —
so CI regresses loudly, not silently.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

PRECISION = 16
ALPHABET = 33            # top-K=32 + escape slot: the production shape
BATCHES = (1, 16, 64)


def _rand_cdfs(rng, n_pos, alphabet, precision):
    """(n_pos, alphabet+1) int64 quantized CDFs, total == 2**precision."""
    pmf = rng.random((n_pos, alphabet)) ** 4 + 1e-6      # peaky, LLM-like
    budget = (1 << precision) - alphabet
    q = np.floor(pmf / pmf.sum(-1, keepdims=True) * budget).astype(np.int64) + 1
    q[np.arange(n_pos), q.argmax(-1)] += (1 << precision) - q.sum(-1)
    cdfs = np.zeros((n_pos, alphabet + 1), np.int64)
    np.cumsum(q, axis=-1, out=cdfs[:, 1:])
    return cdfs


def _sample(rng, cdfs):
    """One symbol per position, drawn from its quantized distribution."""
    total = cdfs[0, -1]
    u = rng.integers(0, total, cdfs.shape[0])
    return (np.sum(cdfs[:, :-1] <= u[:, None], axis=1) - 1).astype(np.int64)


def bench_ac(cdfs, syms, B):
    """AC codes the B streams one after another (its only mode)."""
    from repro.core import ac
    T = cdfs.shape[1]
    t0 = time.perf_counter()
    blobs = []
    for b in range(B):
        enc = ac.ArithmeticEncoder()
        for t in range(T):
            enc.encode(int(syms[b, t]), cdfs[b, t])
        blobs.append(enc.finish())
    t_enc = time.perf_counter() - t0
    t0 = time.perf_counter()
    for b in range(B):
        dec = ac.ArithmeticDecoder(blobs[b])
        out = [dec.decode(cdfs[b, t]) for t in range(T)]
        assert out == list(syms[b]), "AC round-trip failure"
    t_dec = time.perf_counter() - t0
    return t_enc, t_dec, sum(len(x) for x in blobs)


def bench_rans(cdfs, syms, B):
    """Interleaved rANS: one vectorized coder step per position."""
    from repro.core import rans
    T = cdfs.shape[1]
    t0 = time.perf_counter()
    enc = rans.BatchedRansEncoder(B)
    for t in range(T):
        enc.put_symbols(syms[:, t], cdfs[:, t], PRECISION)
    blobs = enc.finish()
    t_enc = time.perf_counter() - t0
    t0 = time.perf_counter()
    dec = rans.BatchedRansDecoder(blobs)
    out = np.empty((B, T), np.int64)
    for t in range(T):
        out[:, t] = dec.get(cdfs[:, t], PRECISION)
    t_dec = time.perf_counter() - t0
    assert np.array_equal(out, syms), "rANS round-trip failure"
    return t_enc, t_dec, sum(len(x) for x in blobs)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny streams: correctness + CSV shape only")
    ap.add_argument("--tokens", type=int, default=0,
                    help="override tokens per stream")
    args = ap.parse_args()
    T = args.tokens or (200 if args.smoke else 4000)
    rng = np.random.default_rng(0)

    console(f"# coder_bench: alphabet={ALPHABET} precision={PRECISION} "
          f"tokens/stream={T}")
    console(f"{'B':>4} {'ac_ksym/s':>10} {'rans_ksym/s':>12} {'speedup':>8} "
          f"{'ac_B':>8} {'rans_B':>8}")
    csv_rows = []
    speedup_64 = 0.0
    for B in BATCHES:
        cdfs = np.stack([_rand_cdfs(rng, T, ALPHABET, PRECISION)
                         for _ in range(B)])
        syms = np.stack([_sample(rng, cdfs[b]) for b in range(B)])
        ac_enc, ac_dec, ac_bytes = bench_ac(cdfs, syms, B)
        rn_enc, rn_dec, rn_bytes = bench_rans(cdfs, syms, B)
        n = B * T
        ac_ks = n / (ac_enc + ac_dec) / 1e3
        rn_ks = n / (rn_enc + rn_dec) / 1e3
        speedup = rn_ks / ac_ks
        if B == 64:
            speedup_64 = speedup
        console(f"{B:>4} {ac_ks:>10.0f} {rn_ks:>12.0f} {speedup:>7.1f}x "
              f"{ac_bytes:>8} {rn_bytes:>8}")
        csv_rows.append(
            f"coder_bench_B{B},{(ac_enc + ac_dec + rn_enc + rn_dec) / n * 1e6:.2f},"
            f"ac_ksym_s={ac_ks:.0f};rans_ksym_s={rn_ks:.0f};"
            f"speedup={speedup:.1f}")
    console("\n# CSV (name,us_per_call,derived)")
    for row in csv_rows:
        console(row)
    from repro import obs
    reg = obs.registry()
    console(f"# registry: rans.streams_flushed="
          f"{reg.value('rans.streams_flushed')} rans.stream_bytes="
          f"{reg.value('rans.stream_bytes')}")
    if args.smoke:
        return 0
    if speedup_64 < 5.0:
        console(f"FAIL: rANS speedup at B=64 is {speedup_64:.1f}x < 5x",
              err=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
