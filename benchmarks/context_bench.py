"""Carried-context ratio + prefix-cache prefill benchmark (DESIGN.md §12).

Two CI gates for the v6 context engine:

* **carried ratio**: on a context-sensitive corpus, a carried v6
  archive (``context_window=K``) must be at least ``RATIO_FLOOR`` times
  smaller than the context-free v6 archive of the same geometry. The
  corpus is sampled from an order-K table model — next-token logits
  depend on the last K tokens — so a fresh chunk start mispredicts its
  first K tokens (the BOS-padded history differs from the generation
  history) while a carried chunk sees the exact context the generator
  had. This is the paper's conversation-log regime: chunking loses
  cross-boundary context, recipes buy it back.
* **prefill savings**: on a shared-template workload (many jobs
  declaring the same shared prefix), the scheduler with the radix
  prefix cache must spend at least ``PREFILL_FLOOR`` times fewer
  prefill lane-steps than with the cache disabled, with hits > 0 and
  byte-identical archives. Each avoided lane-step is one decode_step a
  real accelerator would have paid.

Both gates are deterministic (model-free table predictors, fixed
seeds) — a failure means the engine regressed, not the data.

  PYTHONPATH=src python benchmarks/context_bench.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
convention) and exits non-zero when either gate fails.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

RATIO_FLOOR = 1.10      # carried vs context-free container size
PREFILL_FLOOR = 1.3     # cache-off vs cache-on prefill lane-steps
K = 8                   # model order == carry window
CHUNK = 32
SIGMA = 5.0             # logit scale: sharp when context is right


class OrderKPredictor:
    """Order-K table model: logits are the sum of K per-offset (V, V)
    tables indexed by the last K tokens (BOS-padded). Teacher-forced and
    incremental paths share ``_logits`` — one accumulation order — so
    they agree bit-exactly with no jitted model involved."""

    def __init__(self, k=K, vocab=64, seed=0, sigma=SIGMA):
        self.vocab_size = int(vocab)
        self.bos_id = self.vocab_size - 1
        self.K = int(k)
        rng = np.random.default_rng(seed)
        self._tables = (rng.standard_normal((self.K, vocab, vocab))
                        * (sigma / np.sqrt(self.K))).astype(np.float32)

    def _logits(self, hist):
        """hist: (B, K) token window, most recent last."""
        out = np.zeros((hist.shape[0], self.vocab_size), np.float32)
        for j in range(self.K):
            out += self._tables[j][hist[:, self.K - 1 - j]]
        return out

    def score_chunks(self, tokens):
        tokens = np.asarray(tokens, np.int32)
        B, T = tokens.shape
        hist = np.full((B, self.K), self.bos_id, np.int32)
        out = np.empty((B, T, self.vocab_size), np.float32)
        for t in range(T):
            out[:, t] = self._logits(hist)
            hist = np.concatenate([hist[:, 1:], tokens[:, t:t + 1]], axis=1)
        return out

    def begin_decode(self, batch):
        # state = the K-1 tokens before the one decode_step is fed
        return np.full((batch, self.K - 1), self.bos_id, np.int32)

    def decode_step(self, state, prev_tokens):
        prev = np.asarray(prev_tokens, np.int32).reshape(-1, 1)
        hist = np.concatenate([state, prev], axis=1)
        return self._logits(hist), hist[:, 1:]


def orderk_corpus(pred: OrderKPredictor, n: int, seed=1) -> np.ndarray:
    """Softmax-sample ``n`` tokens from the model's own distribution —
    the LLM-generated-text regime where next-token coding wins."""
    rng = np.random.default_rng(seed)
    hist = np.full((1, pred.K), pred.bos_id, np.int32)
    out = np.empty(n, np.int32)
    for t in range(n):
        lg = pred._logits(hist)[0].astype(np.float64)
        p = np.exp(lg - lg.max())
        out[t] = rng.choice(pred.vocab_size, p=p / p.sum())
        hist = np.concatenate([hist[:, 1:], [[out[t]]]], axis=1)
    return out


class TablePredictor:
    """Order-1 table model with the prefix-cache hooks (stateless, so a
    lane snapshot is trivial) — isolates the prefill-savings measurement
    from model cost; the scheduler's prefill lane-step counter is the
    dispatch count a real accelerator would pay."""

    def __init__(self, vocab=64, seed=0):
        self.vocab_size = int(vocab)
        self.bos_id = self.vocab_size - 1
        rng = np.random.default_rng(seed)
        self._table = (rng.standard_normal((vocab, vocab)) * 2.0).astype(
            np.float32)

    def score_chunks(self, tokens):
        tokens = np.asarray(tokens, np.int32)
        prev = np.concatenate(
            [np.full((tokens.shape[0], 1), self.bos_id, np.int32),
             tokens[:, :-1]], axis=1)
        return self._table[prev]

    def begin_decode(self, batch):
        return None

    def decode_step(self, state, prev_tokens):
        return self._table[np.asarray(prev_tokens, np.int32)], state

    def snapshot_slot(self, state, lane):
        return ("snap",)

    def restore_slot(self, state, snapshot, mask):
        return state


def _self_tokens(pred, n, seed):
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.int32)
    prev = pred.bos_id
    for i in range(n):
        lg = pred._table[prev].astype(np.float64)
        p = np.exp(lg - lg.max())
        prev = out[i] = rng.choice(pred.vocab_size, p=p / p.sum())
    return out


# ----------------------------------------------------------- carried ratio
def run_ratio_bench(n_tokens=1024, stripes=4):
    from repro.core import LLMCompressor, RECIPE_CARRY, read_index

    gen = OrderKPredictor()
    toks = orderk_corpus(gen, n_tokens)
    kw = dict(chunk_size=CHUNK, decode_batch=4, topk=0, codec="rans",
              container_version=6)

    t0 = time.time()
    fresh_blob, _ = LLMCompressor(OrderKPredictor(), **kw).compress(toks)
    t_fresh = time.time() - t0
    t0 = time.time()
    carried_blob, _ = LLMCompressor(OrderKPredictor(), context_window=K,
                                    context_stripes=stripes,
                                    **kw).compress(toks)
    t_carried = time.time() - t0

    info = read_index(carried_blob)
    assert any(e.recipe_kind == RECIPE_CARRY for e in info.entries)
    # losslessness of both, full + ranged, on fresh decoder objects
    dec = LLMCompressor(OrderKPredictor(), **kw)
    assert np.array_equal(dec.decompress(fresh_blob), toks)
    assert np.array_equal(dec.decompress(carried_blob), toks)
    mid = info.n_chunks // 2
    part = dec.decompress_range(carried_blob, mid, mid + 1)
    assert np.array_equal(part, toks[mid * CHUNK:(mid + 1) * CHUNK])

    gain = len(fresh_blob) / len(carried_blob)
    return {
        "n_tokens": int(toks.size), "n_chunks": info.n_chunks,
        "fresh_bytes": len(fresh_blob), "carried_bytes": len(carried_blob),
        "ratio_gain": gain, "ratio_floor": RATIO_FLOOR,
        "t_fresh_s": t_fresh, "t_carried_s": t_carried,
        "gate_pass": bool(gain >= RATIO_FLOOR),
    }


# --------------------------------------------------------- prefill savings
def run_prefill_bench(n_jobs=8, prefix_len=64, job_tokens=48, slots=4):
    from repro.service import CompressionService

    sp = _self_tokens(TablePredictor(), prefix_len, seed=77)
    jobs = [_self_tokens(TablePredictor(), job_tokens, seed=100 + i)
            for i in range(n_jobs)]

    def run(cache_on):
        svc = CompressionService(TablePredictor(), slots=slots,
                                 chunk_size=16, topk=8)
        if not cache_on:
            svc.scheduler.prefix_cache = None
        t0 = time.time()
        handles = [svc.submit_compress(t, shared_prefix=sp) for t in jobs]
        blobs = [h.result()[0] for h in handles]
        return svc, blobs, time.time() - t0

    svc_on, blobs_on, t_on = run(True)
    svc_off, blobs_off, t_off = run(False)
    assert blobs_on == blobs_off, "prefix cache changed archive bytes"
    cache = svc_on.snapshot()["prefix_cache"]
    on_steps = int(svc_on.stats.prefill_steps)
    off_steps = int(svc_off.stats.prefill_steps)
    savings = off_steps / max(1, on_steps)
    return {
        "n_jobs": n_jobs, "prefix_len": prefix_len,
        "prefill_steps_on": on_steps, "prefill_steps_off": off_steps,
        "prefill_savings": savings, "prefill_floor": PREFILL_FLOOR,
        "cache_hits": cache["hits"], "cache_misses": cache["misses"],
        "tokens_reused": cache["tokens_reused"],
        "wall_on_s": t_on, "wall_off_s": t_off,
        "gate_pass": bool(savings >= PREFILL_FLOOR and cache["hits"] > 0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus / few jobs; same gates")
    args = ap.parse_args()

    if args.smoke:
        ratio = run_ratio_bench(n_tokens=512, stripes=4)
        prefill = run_prefill_bench(n_jobs=6, prefix_len=48)
    else:
        ratio = run_ratio_bench()
        prefill = run_prefill_bench()

    console("\n== context_ratio (carried vs context-free v6) ==")
    console(f"corpus {ratio['n_tokens']} tokens / {ratio['n_chunks']} chunks: "
          f"fresh {ratio['fresh_bytes']}B carried {ratio['carried_bytes']}B "
          f"-> {ratio['ratio_gain']:.3f}x "
          f"(floor {RATIO_FLOOR}x, "
          f"{'ok' if ratio['gate_pass'] else 'FAIL'})")
    console(f"prefix cache: {prefill['cache_hits']} hits / "
          f"{prefill['cache_misses']} misses, "
          f"{prefill['tokens_reused']} tokens reused; prefill steps "
          f"{prefill['prefill_steps_off']} -> {prefill['prefill_steps_on']} "
          f"= {prefill['prefill_savings']:.2f}x "
          f"(floor {PREFILL_FLOOR}x, "
          f"{'ok' if prefill['gate_pass'] else 'FAIL'})")
    console(f"context_ratio,{ratio['t_carried_s'] * 1e6:.1f},"
          f"gain={ratio['ratio_gain']:.3f};pass={ratio['gate_pass']}")
    console(f"context_prefill,{prefill['wall_on_s'] * 1e6:.1f},"
          f"savings={prefill['prefill_savings']:.2f};"
          f"hits={prefill['cache_hits']};pass={prefill['gate_pass']}")
    if not (ratio["gate_pass"] and prefill["gate_pass"]):
        console("FAIL: context gate", err=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
