"""Mixed-traffic codec-routing benchmark + CI gate (DESIGN.md §11).

The router's contract is economic: on EVERY traffic segment — model-
friendly, human-like, adversarial-random — the routed v5 container's
ratio must be at least ``max(pure-LLM, fallback-only) - 2%``. The 2%
slack absorbs probe noise; structurally the routed container is the
per-chunk minimum of both strategies at identical v5 geometry, so a
gate failure means the router's policy (not the data) regressed.

All three strategies are measured as v5 containers so index overhead is
identical and ratios compare codec choice alone:

* ``llm``      — ``route="llm"``: every chunk entropy-coded,
* ``fallback`` — ``route=<best dictionary codec>``: no chunk touches
  the model (zstd when the optional package is importable, else lzma;
  raw store is always an implicit candidate),
* ``routed``   — ``route="auto"``: probe + realized-size comparison.

The predictor is a deterministic model-free table (same construction as
the golden-container tests): next-byte logits depend only on the
previous byte, so the benchmark needs no trained weights, runs in CI
smoke mode in seconds, and its "LLM-generated" segment is sampled from
the table itself — the regime where the paper's ratios live.

  PYTHONPATH=src python benchmarks/router_bench.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
convention) and exits non-zero when the gate fails on any segment.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

CHUNK = 64
SLACK = 0.98        # routed >= max(llm, fallback) * SLACK, per segment


class _TablePredictor:
    """Byte-level table model (vocab 258: bytes + PAD + BOS); logits for
    position t depend only on token t-1, so teacher-forced and
    incremental scoring agree bit-exactly with no jitted model."""

    def __init__(self, seed=0):
        self.vocab_size = 258
        self.bos_id = 257
        rng = np.random.default_rng(seed)
        self._table = (rng.standard_normal((258, 258)) * 2.0).astype(
            np.float32)

    def score_chunks(self, tokens):
        tokens = np.asarray(tokens, np.int32)
        prev = np.concatenate(
            [np.full((tokens.shape[0], 1), self.bos_id, np.int32),
             tokens[:, :-1]], axis=1)
        return self._table[prev]

    def begin_decode(self, batch):
        return None

    def decode_step(self, state, prev_tokens):
        return self._table[np.asarray(prev_tokens, np.int32)], state


def _llm_generated(pred, n, seed=1):
    """Bytes softmax-sampled from the predictor's own table — the
    paper's LLM-generated-text regime, where the entropy path wins."""
    rng = np.random.default_rng(seed)
    out = bytearray()
    prev = pred.bos_id
    for _ in range(n):
        logits = pred._table[prev][:256].astype(np.float64)
        p = np.exp(logits - logits.max())
        prev = int(rng.choice(256, p=p / p.sum()))
        out.append(prev)
    return bytes(out)


def _human_like(n, seed=2):
    """Markov word-salad: real byte statistics the dictionary codecs
    exploit but the (random-table) model has never seen."""
    rng = np.random.default_rng(seed)
    words = [w.encode() for w in (
        "the model the paper the chunk codec stream token entropy rate "
        "routing fallback store index footer decode probe margin next "
        "prediction compression container golden").split()]
    out = bytearray()
    while len(out) < n:
        out += words[int(rng.integers(0, len(words)))] + b" "
    return bytes(out[:n])


def _random_bytes(n, seed=3):
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


def _fallback_codec():
    from repro.core import available_byte_codecs
    return "zstd" if "zstd" in available_byte_codecs() else "lzma"


def _ratio(pred, data, route, router=None):
    from repro.core import LLMCompressor
    from repro.data.tokenizer import encode
    comp = LLMCompressor(pred, chunk_size=CHUNK, topk=32, decode_batch=16,
                         container_version=5, route=route, router=router)
    toks = encode(data)
    t0 = time.perf_counter()
    blob, _ = comp.compress(toks)
    dt = time.perf_counter() - t0
    assert np.array_equal(comp.decompress(blob), toks), \
        "LOSSLESS VIOLATION"
    return len(data) / len(blob), dt


def run_bench(seg_bytes=4096):
    pred = _TablePredictor()
    fb = _fallback_codec()
    segments = {
        "llm_generated": _llm_generated(pred, seg_bytes),
        "human_text": _human_like(seg_bytes),
        "random_bytes": _random_bytes(seg_bytes),
    }
    # the mixed-traffic stream interleaves all three regimes — the shape
    # the router exists for: no single strategy wins every chunk
    segments["mixed_traffic"] = b"".join(
        segments[k][i * seg_bytes // 4:(i + 1) * seg_bytes // 4]
        for i in range(4) for k in ("llm_generated", "human_text",
                                    "random_bytes"))
    out = {"fallback_codec": fb, "segments": {}, "gate_pass": True}
    for name, data in segments.items():
        r_llm, t_llm = _ratio(pred, data, "llm")
        r_fb, _ = _ratio(pred, data, fb)
        r_auto, t_auto = _ratio(pred, data, "auto")
        floor = max(r_llm, r_fb) * SLACK
        ok = r_auto >= floor
        out["segments"][name] = {
            "llm": round(r_llm, 3), "fallback": round(r_fb, 3),
            "routed": round(r_auto, 3), "floor": round(floor, 3),
            "probe_overhead": round(t_auto / max(t_llm, 1e-9), 3),
            "pass": ok,
        }
        out["gate_pass"] = out["gate_pass"] and ok
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small segments: correctness + gate shape only")
    ap.add_argument("--bytes", type=int, default=0,
                    help="override bytes per segment")
    args = ap.parse_args()
    n = args.bytes or (1024 if args.smoke else 8192)
    res = run_bench(seg_bytes=n)
    console(f"# router_bench: chunk={CHUNK} seg_bytes={n} "
          f"fallback={res['fallback_codec']}")
    console(f"{'segment':16s} {'llm':>7} {'fallback':>9} {'routed':>7} "
          f"{'floor':>7} {'probe_ovh':>9}  gate")
    rows = []
    for name, s in res["segments"].items():
        console(f"{name:16s} {s['llm']:>7.3f} {s['fallback']:>9.3f} "
              f"{s['routed']:>7.3f} {s['floor']:>7.3f} "
              f"{s['probe_overhead']:>8.2f}x  "
              f"{'ok' if s['pass'] else 'FAIL'}")
        rows.append(f"router_bench_{name},0.0,"
                    f"llm={s['llm']};fb={s['fallback']};"
                    f"routed={s['routed']};pass={s['pass']}")
    console("\n# CSV (name,us_per_call,derived)")
    for row in rows:
        console(row)
    if not res["gate_pass"]:
        console("FAIL: routed ratio fell below max(llm, fallback) - 2% "
              "on at least one segment", err=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
