"""Build (and cache) the measured-experiment assets: trained byte-level
predictor LMs and the human-like / LLM-generated corpora.

Everything lands in results/bench_cache/ keyed by config; re-runs are
no-ops. The predictors are the paper's "LLMs" scaled to this CPU container
(same dense llama-family; see configs/paper_predictors.py).
"""
from __future__ import annotations

import pathlib
import time

import numpy as np

CACHE = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench_cache"

PREDICTORS = ("pred-tiny", "pred-small", "pred-base")
TRAIN_STEPS = {"pred-tiny": 260, "pred-small": 260, "pred-base": 220,
               "pred-large": 160}
DOMAINS = ("wiki", "code", "math", "clinical", "web", "science", "novel",
           "article")


def _cfg(name):
    from repro.configs import paper_predictors as pp
    return {"pred-tiny": pp.PRED_TINY, "pred-small": pp.PRED_SMALL,
            "pred-base": pp.PRED_BASE, "pred-large": pp.PRED_LARGE}[name]


def train_predictor(name: str, *, steps=None, seed=0, domain_mix=DOMAINS,
                    corpus_bytes=1 << 20, log=print):
    """Train a predictor on a mixed human-like corpus; cache the params."""
    import jax
    from repro.data.synthetic import human_like
    from repro.data.tokenizer import encode
    from repro.data.pipeline import TokenPipeline
    from repro.launch.mesh import local_mesh
    from repro.models.schema import init_params
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_loop import make_train_step
    from repro.train.checkpoint import restore_latest, save_checkpoint

    cfg = _cfg(name)
    steps = steps or TRAIN_STEPS[name]
    ckpt_dir = CACHE / f"{name}-s{seed}"
    params_like = init_params(cfg, jax.random.PRNGKey(seed))
    restored, step = restore_latest(ckpt_dir, {"params": params_like})
    if restored is not None and step >= steps:
        return restored["params"], cfg

    corpus = b"".join(
        human_like(d, corpus_bytes // len(domain_mix), seed=seed + i)
        for i, d in enumerate(domain_mix))
    toks = encode(corpus)
    pipe = TokenPipeline(toks, global_batch=16, seq_len=192, seed=seed)
    opt = AdamWConfig(learning_rate=3e-3, warmup_steps=20, total_steps=steps)
    params = params_like
    opt_state = init_opt_state(params, opt)
    step_fn = make_train_step(cfg, local_mesh(), opt=opt, global_batch=16)
    t0 = time.time()
    for s in range(steps):
        batch = {"tokens": pipe.global_batch_array(s)}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if s % 50 == 0:
            log(f"  [{name}] step {s} loss {float(m['loss']):.3f} "
                f"({time.time()-t0:.0f}s)")
    save_checkpoint(ckpt_dir, steps, {"params": params})
    log(f"  [{name}] trained {steps} steps, final loss "
        f"{float(m['loss']):.3f} in {time.time()-t0:.0f}s")
    return params, cfg


def predictor(name: str, *, seed=0):
    """Trained ModelPredictor (cached)."""
    from repro.serve.engine import ModelPredictor
    from repro.data.tokenizer import BOS_ID
    params, cfg = train_predictor(name, seed=seed)
    return ModelPredictor(params, cfg, bos_id=BOS_ID)


def llm_dataset(domain: str, n_bytes: int = 6144, *, gen_model="pred-base",
                temperature=0.55, seed=0, doc_len=384) -> bytes:
    """Cached 'LLM-generated' dataset: the gen_model continues a domain
    prompt — the paper's LLM-generated text, per category.

    * temperature 0.55: scaled to the paper's predictability regime — its
      1-14B generators emit ~0.35-0.55 bits/byte under their own scoring;
      a ~5M predictor needs a lower temperature to land in a comparable
      regime (EXPERIMENTS.md §Claims, scaling note).
    * fixed `doc_len` per generated document, corpus = concatenation of
      independent documents (a real corpus is many documents; one long
      stream from a small model drifts off-distribution and the measured
      "dataset scale" effect becomes generator drift, not compressor
      behaviour).
    """
    path = CACHE / (f"gen3-{gen_model}-{domain}-{n_bytes}-t{temperature}"
                    f"-d{doc_len}-s{seed}.bin")
    if path.exists():
        return path.read_bytes()
    from repro.data.synthetic import human_like
    from repro.data.tokenizer import encode
    pred = predictor(gen_model, seed=0)
    n_docs = -(-n_bytes // doc_len)
    plen = 128
    # DISTINCT prompt per document (a shared prompt is dictionary-compressor
    # candy and unrepresentative of a real generated corpus)
    prompts = np.stack([encode(human_like(domain, plen, seed=seed + 77 + i))
                        for i in range(n_docs)])
    gen_len = doc_len - plen
    toks = pred.generate(gen_len, batch=n_docs, temperature=temperature,
                         seed=seed + hash(domain) % 1000, prompt=prompts,
                         vocab_limit=256)
    # document = prompt + continuation: the compressor scores the
    # continuation with the same context the generator saw
    docs = np.concatenate([prompts, toks], axis=1)
    data = docs.ravel().astype(np.uint8).tobytes()[:n_bytes]
    CACHE.mkdir(parents=True, exist_ok=True)
    path.write_bytes(data)
    return data


def human_dataset(domain: str, n_bytes: int = 6144, seed: int = 0) -> bytes:
    from repro.data.synthetic import human_like
    return human_like(domain, n_bytes, seed=seed)


def build_all(log=print):
    for name in PREDICTORS:
        log(f"[prep] predictor {name}")
        train_predictor(name, log=log)
    for d in DOMAINS:
        log(f"[prep] dataset {d}")
        llm_dataset(d)


if __name__ == "__main__":
    build_all()
