"""Speculative vs lock-step decompression benchmark (DESIGN.md §9).

Workload: LLM-LIKE text from the deterministic table predictor — each
token follows the table's argmax with probability q (else uniform
random), mirroring the low-entropy, locally-repetitive streams the paper
targets (greedy / low-temperature LLM output is overwhelmingly the
model's top pick, which is the paper's compressibility premise). On this data the self-draft proposer (suffix match over the
decoded prefix) keeps the verify chain alive, so one verify forward
retires several positions that lock-step decoding would spend one model
dispatch each on.

Two asserted gates (exit non-zero below either — same CI convention as
coder_bench.py / service_bench.py):

* **model dispatches**: speculative decode must issue <= 1/2 the model
  calls of lock-step — deterministic, timing-noise-free;
* **wall throughput**: >= 2x tokens/sec with a fixed per-dispatch
  latency charged to the (otherwise free) table predictor. Real
  accelerators pay exactly this: a step costs dispatch overhead + a
  forward whose FLOPs are identical either way, so dispatch count IS
  the wall-clock story, and charging it makes the measurement honest on
  a model-free predictor.

Round trips are verified byte-identically across BOTH codecs every run:
rANS containers through the speculative path, legacy AC containers
through the grouped fallback (draft_k must be inert there).

  PYTHONPATH=src python benchmarks/decompress_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path[:0] = ["src", "."]

from repro.obs import console  # noqa: E402

from benchmarks.service_bench import TablePredictor  # noqa: E402

SPEEDUP_FLOOR = 2.0
DISPATCH_FLOOR = 2.0


class LatencyPredictor(TablePredictor):
    """TablePredictor charging a fixed latency per model dispatch (one
    decode_step OR one verify forward — the verify scan is a single
    fused program on a real accelerator, which is the entire point)."""

    def __init__(self, dispatch_s=0.0, **kw):
        super().__init__(**kw)
        self.dispatch_s = float(dispatch_s)

    def _charge(self):
        if self.dispatch_s:
            t1 = time.perf_counter() + self.dispatch_s
            while time.perf_counter() < t1:   # busy-wait: sleep() jitter
                pass                          # swamps ms-scale charges

    def decode_step(self, state, prev_tokens):
        self._charge()
        return super().decode_step(state, prev_tokens)

    def verify_steps(self, state, seq):
        self._charge()
        return super().verify_steps(state, seq)


def predictable_workload(pred, rng, n_jobs, n_tokens, q):
    """Argmax-following token streams: compressible AND draftable."""
    argmax = pred._table.argmax(axis=-1)
    datas = []
    for _ in range(n_jobs):
        toks = np.zeros(n_tokens, np.int32)
        prev = pred.bos_id
        for i in range(n_tokens):
            t = int(argmax[prev]) if rng.random() < q \
                else int(rng.integers(0, 60))
            toks[i] = t
            prev = t
        datas.append(toks)
    return datas


def run_bench(n_jobs=4, tokens=2048, slots=8, chunk=128, topk=8, draft_k=6,
              q=0.98, dispatch_ms=1.0, seed=0, log=console):
    from repro.core import LLMCompressor

    pred = LatencyPredictor()
    rng = np.random.default_rng(seed)
    datas = predictable_workload(pred, rng, n_jobs, tokens, q)
    total = sum(d.size for d in datas)

    comp = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=slots, container_version=4)
    blobs = [comp.compress(d)[0] for d in datas]
    ratio = 2 * total / sum(len(b) for b in blobs)    # 2B tokens -> bytes

    spec = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                         decode_batch=slots, container_version=4,
                         draft_k=draft_k)
    comp.decompress(blobs[0])     # warm both decode paths (jit compiles
    spec.decompress(blobs[0])     # happen once, outside the clocks)
    pred.dispatch_s = dispatch_ms * 1e-3

    # ---- lock-step grouped decode
    pred.n_steps = 0
    t0 = time.time()
    for b, d in zip(blobs, datas):
        out = comp.decompress(b)
        assert np.array_equal(out, d), "LOSSLESS VIOLATION (lock-step)"
    lock_dt = time.time() - t0
    lock_steps = pred.n_steps

    # ---- speculative decode, same containers
    pred.n_steps = 0
    t0 = time.time()
    for b, d in zip(blobs, datas):
        out = spec.decompress(b)
        assert np.array_equal(out, d), "LOSSLESS VIOLATION (speculative)"
    spec_dt = time.time() - t0
    spec_steps = pred.n_steps

    # ---- AC-codec round trip (grouped fallback; draft_k inert)
    pred.dispatch_s = 0.0
    ac = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                       decode_batch=slots, codec="ac")
    ac_spec = LLMCompressor(pred, chunk_size=chunk, topk=topk,
                            decode_batch=slots, codec="ac",
                            draft_k=draft_k)
    ac_blob, _ = ac.compress(datas[0])
    assert np.array_equal(ac_spec.decompress(ac_blob), datas[0]), \
        "LOSSLESS VIOLATION (AC codec)"

    dispatch_ratio = lock_steps / max(1, spec_steps)
    wall_speedup = lock_dt / max(1e-9, spec_dt)
    log(f"workload: {n_jobs} jobs x {tokens} tokens, q={q}, B={slots}, "
        f"C={chunk}, K={draft_k}, dispatch={dispatch_ms:.1f}ms, "
        f"ratio={ratio:.1f}x")
    log(f"lock-step  : {lock_steps:6d} dispatches  "
        f"{total / lock_dt:9.0f} tok/s  ({lock_dt:.2f}s)")
    log(f"speculative: {spec_steps:6d} dispatches  "
        f"{total / spec_dt:9.0f} tok/s  ({spec_dt:.2f}s)")
    log(f"dispatch ratio {dispatch_ratio:.2f}x | "
        f"wall speedup {wall_speedup:.2f}x")
    return {
        "n_jobs": n_jobs, "tokens": tokens, "slots": slots, "chunk": chunk,
        "draft_k": draft_k, "q": q, "dispatch_ms": dispatch_ms,
        "lock_steps": lock_steps, "spec_steps": spec_steps,
        "lock_tok_per_s": total / lock_dt,
        "spec_tok_per_s": total / spec_dt,
        "dispatch_ratio": dispatch_ratio, "wall_speedup": wall_speedup,
        "compression_ratio": ratio,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for the CI fast job")
    args = ap.parse_args()
    if args.smoke:
        res = run_bench(n_jobs=2, tokens=1024, slots=4, dispatch_ms=0.5)
    else:
        res = run_bench()
    console(f"decompress_throughput,{1e6 / max(1e-9, res['spec_tok_per_s']):.3f},"
          f"wall_speedup={res['wall_speedup']:.2f};"
          f"dispatch_ratio={res['dispatch_ratio']:.2f};"
          f"tok_per_s={res['spec_tok_per_s']:.0f}")
    from repro import obs
    reg = obs.registry()     # spec path records into the global registry
    offered = reg.value("spec.drafted_tokens")
    acc = reg.value("spec.drafted_accepted")
    if offered:
        console(f"# registry: spec.rounds={reg.value('spec.rounds')} "
              f"spec.rollbacks={reg.value('spec.rollbacks')} "
              f"draft_acceptance={acc / offered:.3f}")
    ok = True
    if res["dispatch_ratio"] < DISPATCH_FLOOR:
        console(f"FAIL: dispatch ratio {res['dispatch_ratio']:.2f}x < "
              f"{DISPATCH_FLOOR}x", err=True)
        ok = False
    if res["wall_speedup"] < SPEEDUP_FLOOR:
        console(f"FAIL: wall speedup {res['wall_speedup']:.2f}x < "
              f"{SPEEDUP_FLOOR}x", err=True)
        ok = False
    if ok:
        console(f"PASS: speculative decode {res['wall_speedup']:.2f}x wall, "
              f"{res['dispatch_ratio']:.2f}x dispatches "
              f">= {SPEEDUP_FLOOR}x / {DISPATCH_FLOOR}x floors")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
